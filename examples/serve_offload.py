"""End-to-end serving driver (the paper's kind of workload).

Runs the SAME burst twice — without offloading (the FlagEmbedding-style
baseline) and with WindVE CPU offloading — and prints the concurrency and
cost deltas (the paper's Table 1 experiment, on the real threaded engine).

With ``--three-tier`` the offload run adds a second, slower CPU pool: the
topology is just one more ``TierSpec`` in the list, no engine changes.

    PYTHONPATH=src python examples/serve_offload.py --queries 56
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.core.cost_model import peak_saving, throughput_uplift
from repro.core.routing import CPU, NPU, TierSpec
from repro.core.simulator import DeviceModel
from repro.core.windve import JaxEmbedderBackend, ModeledBackend, WindVE
from repro.data.workload import make_queries
from repro.models import embedder


def run_engine(heter: bool, n_queries: int, cfg, params, slo: float,
               three_tier: bool = False):
    # a fast modeled NPU + the real (slow, 1-core) host CPU embedder
    npu = ModeledBackend(DeviceModel("npu", beta=0.05, b=0.01, a=0.0),
                         embed_dim=cfg.d_model)
    tiers = [TierSpec(NPU, int((slo - 0.05) / 0.01), backend=npu)]
    if heter:
        tiers.append(TierSpec(CPU, 2,
                              backend=JaxEmbedderBackend(cfg, params,
                                                         max_tokens=32)))
    if heter and three_tier:
        # a little-core pool: modeled 2x slower than the big-core embedder
        little = ModeledBackend(DeviceModel("cpu-little", beta=0.1, b=0.12,
                                            a=0.0), embed_dim=cfg.d_model)
        tiers.append(TierSpec("CPU-little", 2, backend=little))
    engine = WindVE(tiers=tiers)
    queries = make_queries(n_queries, cfg.vocab_size, length=24)
    t0 = time.monotonic()
    futs = [engine.submit(payload=q, length=24) for q in queries]
    for f in futs:
        if f is not None:
            f.result(timeout=60)
    wall = time.monotonic() - t0
    stats = engine.stats
    engine.shutdown()
    return stats, wall, engine.max_concurrency


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=56)
    ap.add_argument("--slo", type=float, default=0.5)
    ap.add_argument("--three-tier", action="store_true",
                    help="offload run uses NPU + big-core + little-core CPU")
    args = ap.parse_args()

    cfg = get_config("bge-large-zh-v1.5").smoke()
    params = embedder.init_embedder(jax.random.PRNGKey(0), cfg)

    base, wall_b, c_base = run_engine(False, args.queries, cfg, params,
                                      args.slo)
    wind, wall_w, c_wind = run_engine(True, args.queries, cfg, params,
                                      args.slo, three_tier=args.three_tier)

    print(f"baseline (no offload): C={c_base} accepted={base.accepted} "
          f"rejected={base.rejected} wall={wall_b:.2f}s")
    print(f"WindVE   (offload):    C={c_wind} accepted={wind.accepted} "
          f"rejected={wind.rejected} wall={wall_w:.2f}s "
          f"per-device={wind.per_device}")
    extra = c_wind - c_base
    print(f"concurrency +{throughput_uplift(c_base, extra)*100:.1f}%  "
          f"peak-provisioned cost saving "
          f"{peak_saving(c_base, extra)*100:.1f}%")


if __name__ == "__main__":
    main()
