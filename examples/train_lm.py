"""Train a small LM end to end (data stream -> remat'd train step -> AdamW
-> checkpoint), using the same step builder the 72B production config lowers
through in the dry-run.

    PYTHONPATH=src python examples/train_lm.py --steps 100
"""
import argparse

from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/windve_lm.npz")
    args = ap.parse_args()
    _, _, losses = train(args.arch, args.steps, args.batch, args.seq,
                         smoke=True, ckpt=args.ckpt, lr=1e-3, log_every=10)
    print(f"first-10 mean loss {sum(losses[:10])/10:.3f} -> "
          f"last-10 mean loss {sum(losses[-10:])/10:.3f}")


if __name__ == "__main__":
    main()
