"""Serve an ASSIGNED architecture (token generation) through WindVE, with
online queue-depth re-calibration — the paper's technique applied beyond
embeddings (DESIGN.md §4), plus the beyond-paper adaptive estimator.

    PYTHONPATH=src python examples/serve_llm.py --arch stablelm-1.6b
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.core.adaptive import OnlineCalibrator, attach
from repro.core.llm_backend import LMGenerateBackend
from repro.core.routing import CPU, NPU, TierSpec
from repro.core.simulator import DeviceModel
from repro.core.windve import ModeledBackend, WindVE
from repro.data.workload import make_queries
from repro.models import api


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--queries", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--slo", type=float, default=30.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    print(f"[serve-llm] {cfg.name}: generation backend on host CPU")

    # CPU pool REALLY generates tokens; NPU pool modeled (no TPU here)
    cpu_be = LMGenerateBackend(cfg, params, max_prompt=24,
                               max_new_tokens=args.new_tokens)
    npu_be = ModeledBackend(DeviceModel("tpu-pool", beta=0.05, b=0.01, a=0.0),
                            embed_dim=args.new_tokens)
    engine = WindVE(tiers=[TierSpec(NPU, 6, backend=npu_be),
                           TierSpec(CPU, 2, backend=cpu_be)])

    # beyond-paper: adapt depths online from live latencies, fed through the
    # engine's batch-completion hook
    cal = OnlineCalibrator(slo_s=args.slo, min_points=2)
    attach(engine, cal, refit_every=4)

    queries = make_queries(args.queries, cfg.vocab_size, length=16)
    t0 = time.monotonic()
    futs = [engine.submit(payload=q, length=16) for q in queries]
    outs = [f.result(timeout=300) for f in futs if f is not None]
    wall = time.monotonic() - t0

    s = engine.stats
    print(f"[serve-llm] {len(outs)} generations in {wall:.2f}s  "
          f"rejected(BUSY)={s.rejected}  per-device={s.per_device}")
    sample = next((o for o in outs if o.dtype.kind in "iu"), outs[0])
    print(f"[serve-llm] sample continuation token ids: {list(map(int, sample))}")
    print(f"[serve-llm] NPU depth after adaptation: "
          f"{engine.qm.queues[NPU].depth} (started 6); "
          f"observations: {cal.n_observations(NPU)}")
    engine.shutdown()


if __name__ == "__main__":
    main()
