"""Quickstart: WindVE in ~40 lines.

Builds a bge-style embedder (reduced), detects devices, calibrates queue
depths with the linear-regression estimator, and serves a burst of queries
through the CPU-NPU collaborative engine — Algorithm 1 + Eq. 12 end to end.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_config
from repro.core.device_detector import DeviceInventory, detect
from repro.core.estimator import estimate_depth
from repro.core.routing import CPU, NPU, CascadePolicy, TierSpec
from repro.core.simulator import PAPER_DEVICES, profile_fn_for
from repro.core.windve import JaxEmbedderBackend, ModeledBackend, WindVE
from repro.data.workload import make_queries
from repro.models import embedder


def main() -> None:
    # 1. model: the paper's bge-large-zh-v1.5 family (reduced for CPU)
    cfg = get_config("bge-large-zh-v1.5").smoke()
    params = embedder.init_embedder(jax.random.PRNGKey(0), cfg)

    # 2. device detector (Algorithm 2): one modeled NPU + this host's CPU
    det = detect(DeviceInventory(npus=1, cpus=1))
    print(f"detector: main={det.device_main} aux={det.device_auxiliary}")

    # 3. queue depths via the linear-regression estimator (Eq. 12)
    npu_dev = PAPER_DEVICES["tesla-v100/bge"]
    c_npu, fit = estimate_depth(profile_fn_for(npu_dev), slo_s=1.0)
    print(f"estimator: alpha={fit.alpha:.4f} beta={fit.beta:.3f} "
          f"-> C_NPU={c_npu}")

    # 4. the engine: a TierSpec list + the paper's cascade policy
    #    (Algorithm 1 dispatch, per-tier worker threads)
    engine = WindVE(tiers=[
        TierSpec(NPU, c_npu,
                 backend=ModeledBackend(npu_dev, embed_dim=cfg.d_model)),
        TierSpec(CPU, 2,
                 backend=JaxEmbedderBackend(cfg, params, max_tokens=32)),
    ], policy=CascadePolicy())

    # 5. a burst of queries
    queries = make_queries(c_npu + 4, cfg.vocab_size, length=24)
    futs = [engine.submit(payload=q, length=24) for q in queries]
    embs = [f.result(timeout=60) for f in futs if f is not None]
    print(f"accepted={engine.stats.accepted} rejected={engine.stats.rejected} "
          f"embedding dim={embs[0].shape[0]}")
    print(f"per-device: {engine.stats.per_device}  p50={engine.stats.p(50):.3f}s")
    engine.shutdown()


if __name__ == "__main__":
    main()
