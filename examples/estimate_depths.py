"""Queue-depth estimation walkthrough (paper §4.2.2 + Fig. 4 + Table 3).

Profiles each calibrated device at a handful of concurrency points, fits
Eq. 12, derives the SLO-constrained queue depth, and compares against the
exhaustive stress test — showing the estimator's profiling-cost advantage.

    PYTHONPATH=src python examples/estimate_depths.py --slo 2.0
"""
import argparse

from repro.core.estimator import (estimate_depth, fine_tune_depth,
                                  stress_test_depth)
from repro.core.simulator import PAPER_DEVICES, profile_fn_for


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slo", type=float, default=1.0)
    ap.add_argument("--model", choices=["bge", "jina"], default="bge")
    args = ap.parse_args()

    print(f"SLO = {args.slo}s, model = {args.model}")
    print(f"{'device':20s} {'alpha':>8s} {'beta':>6s} {'reg':>5s} "
          f"{'stress':>7s} {'fine':>5s} {'profiles reg/stress':>20s}")
    for key, dev in PAPER_DEVICES.items():
        if not key.endswith("/" + args.model):
            continue
        calls = {"n": 0}

        def profile(c, _d=dev):
            calls["n"] += 1
            return profile_fn_for(_d, seed=9)(c)

        est, fit = estimate_depth(profile, args.slo)
        n_est = calls["n"]
        stress = stress_test_depth(profile, args.slo, step=8)
        n_stress = calls["n"] - n_est
        fine = fine_tune_depth(profile, args.slo, start=max(est, 1), radius=16)
        print(f"{key.split('/')[0]:20s} {fit.alpha:8.4f} {fit.beta:6.3f} "
              f"{est:5d} {stress:7d} {fine:5d} {n_est:>9d}/{n_stress}")


if __name__ == "__main__":
    main()
