"""§Roofline reporting: reads the dry-run JSONL records and emits the
per-(arch x shape) roofline terms as benchmark rows.

Run ``PYTHONPATH=src python -m repro.launch.dryrun`` first (or use the
checked-in experiments/dryrun_16x16.jsonl)."""
from __future__ import annotations

import json
import os

from benchmarks.common import Row, emit

DEFAULT_PATHS = ("experiments/dryrun_16x16.jsonl", "experiments/dryrun.jsonl")
OPT_PATH = "experiments/dryrun_16x16_opt.jsonl"


def load_records(path: str | None = None):
    paths = [path] if path else list(DEFAULT_PATHS)
    recs = {}
    for p in paths:
        if p and os.path.exists(p):
            for line in open(p):
                r = json.loads(line)
                recs[(r["arch"], r["shape"], r.get("mesh", "?"))] = r
    return recs


def run() -> list[Row]:
    rows: list[Row] = []
    recs = load_records()
    if not recs:
        return [("roofline/no-dryrun-data", 0.0,
                 "run `python -m repro.launch.dryrun` first")]
    for (arch, shape, mesh), r in sorted(recs.items()):
        if mesh != "16x16":
            continue
        name = f"roofline/{arch}/{shape}"
        if "skipped" in r:
            rows.append((name, 0.0, f"SKIP: {r['skipped']}"))
            continue
        if "error" in r:
            rows.append((name, 0.0, "ERROR (see dryrun log)"))
            continue
        rf = r["roofline"]
        step_us = max(rf["compute_s"], rf["memory_s"], rf["collective_s"]) * 1e6
        rows.append((name, step_us,
                     f"dom={rf['dominant']} comp={rf['compute_s']*1e3:.1f}ms "
                     f"mem={rf['memory_s']*1e3:.1f}ms "
                     f"coll={rf['collective_s']*1e3:.1f}ms "
                     f"useful={rf['useful_ratio']:.2f}"))
    # optimized-preset deltas (§Perf) when available
    opt = load_records(OPT_PATH) if os.path.exists(OPT_PATH) else {}
    for (arch, shape, mesh), r in sorted(opt.items()):
        if "roofline" not in r:
            continue
        base = recs.get((arch, shape, "16x16"))
        if base is None or "roofline" not in base:
            continue
        rf, bf = r["roofline"], base["roofline"]
        dom_b = max(bf["compute_s"], bf["memory_s"], bf["collective_s"])
        dom_o = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        rows.append((f"roofline-opt/{arch}/{shape}", dom_o * 1e6,
                     f"dominant {dom_b*1e3:.1f}ms -> {dom_o*1e3:.1f}ms "
                     f"({dom_b/max(dom_o,1e-12):.1f}x) "
                     f"[{r.get('opt','')}]"))
    return rows


if __name__ == "__main__":
    emit(run())
