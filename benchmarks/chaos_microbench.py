"""Chaos test: serve THROUGH a mid-run tier failure, on both drivers.

WindVE's deployment-cost story (Eq. 12) assumes every provisioned tier
keeps serving; this bench injects the opposite — the primary tier goes DOWN
for a wall-clock window in the middle of a paced query stream — and asserts
the fault-tolerance layer turns that outage into failover, not into hung or
wrong answers:

* engine — two REAL ``JaxEmbedderBackend`` tiers sharing one set of
  weights; the primary is wrapped in ``FaultyBackend`` with a down window.
  Its circuit breaker must trip (failures stop hammering the dead tier),
  retried queries must fail over to the healthy tier, and >= 99% of
  accepted, in-deadline queries must serve embeddings that match a
  fault-free golden run (cosine >= 0.999 — loaded once, never re-minted
  mid-assert).  After the window the half-open probe must RE-CLOSE the
  breaker (recovery, measured as time from window end to re-close);
* DES — the same topology shape, fault window, breaker, and retry policy
  on simulated time via ``FaultModel``.  The DES-measured
  served-through-failure fraction must reproduce the engine's within a
  factor band — that is what makes the simulator a trustworthy sizing tool
  for clusters that fail (ROADMAP item 3 under faults).

Self-asserting (CI runs ``--smoke``; a raise exits non-zero) and emits
machine-readable ``BENCH_chaos.json``.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional

import numpy as np

from benchmarks.common import Row, emit, write_bench_json
from repro.core.faults import FaultModel, FaultSchedule, FaultyBackend
from repro.core.health import CLOSED, CircuitBreaker
from repro.core.routing import CPU, NPU, Query, RetryPolicy, TierSpec
from repro.core.simulator import DeviceModel, ServingSimulator
from repro.core.windve import JaxEmbedderBackend, WindVE

MAX_TOKENS = 48
QUERY_LEN = 32
DOWN = (0.7, 1.6)          # the primary tier's outage window (seconds)
GAP_S = 0.03               # paced arrivals: one query per 30 ms
BREAKER_KW = dict(failure_threshold=2, cooldown_s=0.25)
RETRY = RetryPolicy(max_retries=4, backoff_s=0.005)
DEADLINE_S = 8.0


def cosine(a: np.ndarray, b: np.ndarray) -> float:
    a, b = np.asarray(a, np.float64).ravel(), np.asarray(b, np.float64).ravel()
    na, nb = np.linalg.norm(a), np.linalg.norm(b)
    return float(a @ b / (na * nb)) if na and nb else 0.0


def engine_leg(cfg, params, payloads: List[np.ndarray], golden):
    """Paced open-loop serve with the primary tier failing mid-run."""
    primary = FaultyBackend(
        JaxEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS),
        schedule=FaultSchedule((DOWN,)))
    fallback = JaxEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS)
    # warm every (trace) batch size the run can produce BEFORE the clock
    # starts: a mid-run jit retrace would stretch the outage window
    for be in (primary.inner, fallback):
        for bs in (1, 2, 3, 4):
            be.embed_batch([Query(qid=0, payload=payloads[0],
                                  length=QUERY_LEN)] * bs)
    breaker = CircuitBreaker(**BREAKER_KW)
    tiers = [TierSpec(NPU, 4, backend=primary, max_batch=4, breaker=breaker),
             TierSpec(CPU, 8, backend=fallback, max_batch=4,
                      breaker=CircuitBreaker(**BREAKER_KW))]
    ve = WindVE(tiers=tiers, retry=RETRY, default_deadline_s=DEADLINE_S)
    try:
        primary.elapsed()                    # pin the fault clock to t0
        t0 = time.monotonic()
        futs, sub_t = [], []
        done_t: Dict[int, float] = {}
        reclose_t: Optional[float] = None
        for p in payloads:
            target = t0 + len(futs) * GAP_S
            time.sleep(max(0.0, target - time.monotonic()))
            i = len(futs)
            sub_t.append(time.monotonic() - t0)
            f = ve.submit(payload=p, length=QUERY_LEN)
            if f is not None:
                f.add_done_callback(
                    lambda _f, i=i: done_t.setdefault(
                        i, time.monotonic() - t0))
            futs.append(f)
            if reclose_t is None and sub_t[-1] > DOWN[1] \
                    and breaker.state == CLOSED:
                reclose_t = sub_t[-1]
        served: Dict[int, np.ndarray] = {}
        failures = 0
        for i, f in enumerate(futs):
            if f is None:
                continue                     # BUSY — never accepted
            try:
                served[i] = np.asarray(f.result(timeout=60))
            except Exception:
                failures += 1
        stats = ve.stats
        # snapshot the paced run's counters BEFORE the recovery poll below
        # adds probe traffic of its own.  Client-level accepted = futures
        # handed out (Telemetry.accepted counts per-tier admissions, which
        # re-count every retry re-dispatch)
        accepted = sum(1 for f in futs if f is not None)
        misses = sum(stats.deadline_misses.values())
        backend_errors = sum(stats.backend_errors.values())
        retries = sum(stats.retries.values())
        # the breaker may re-close only after the last submit: probe it
        poll_deadline = time.monotonic() + 5.0
        while reclose_t is None and time.monotonic() < poll_deadline:
            f = ve.submit(payload=payloads[0], length=QUERY_LEN)
            if f is not None:
                try:
                    f.result(timeout=10)
                except Exception:
                    pass
            if breaker.state == CLOSED:
                reclose_t = time.monotonic() - t0
            time.sleep(0.02)
        ok = sum(1 for i, e in served.items()
                 if cosine(e, golden[payloads[i].tobytes()]) >= 0.999)
        during = [i for i, s in enumerate(sub_t)
                  if DOWN[0] <= s <= DOWN[1] and i in served and i in done_t]
        failover_lats = [done_t[i] - sub_t[i] for i in during]
        return {
            "accepted": accepted,
            "served": len(served),
            "served_ok": ok,
            "failed": failures,
            "deadline_misses": misses,
            "trips": sum(stats.breaker_trips.values()),
            "recoveries": sum(stats.breaker_recoveries.values()),
            "backend_errors": backend_errors,
            "retries": retries,
            "breaker_state": breaker.state,
            "recovery_s": (reclose_t - DOWN[1]) if reclose_t else float("nan"),
            "n_during": len(during),
            "failover_p95_s": float(np.percentile(failover_lats, 95))
            if failover_lats else float("nan"),
        }
    finally:
        ve.shutdown()


def des_leg(n: int):
    """Same topology shape / fault window / breaker / retry on sim time."""
    fast = DeviceModel("npu", beta=0.004, b=0.001, a=0.0)
    slow = DeviceModel("cpu", beta=0.008, b=0.002, a=0.0)
    tiers = [TierSpec(NPU, 4, model=fast, max_batch=4,
                      breaker=CircuitBreaker(**BREAKER_KW)),
             TierSpec(CPU, 8, model=slow, max_batch=4,
                      breaker=CircuitBreaker(**BREAKER_KW))]
    sim = ServingSimulator(tiers=tiers, slo_s=1.0, retry=RETRY,
                           deadline_s=DEADLINE_S,
                           faults={NPU: FaultModel(
                               schedule=FaultSchedule((DOWN,)),
                               fail_latency_s=0.001)})
    res = sim.run([(i * GAP_S, QUERY_LEN) for i in range(n)])
    return res, [t.breaker.state for t in tiers]


def run(smoke: bool = False) -> list[Row]:
    import jax

    from repro.configs import get_config
    from repro.models import embedder
    from repro.data.workload import make_queries

    cfg = get_config("bge-large-zh-v1.5").smoke()
    params = embedder.init_embedder(jax.random.PRNGKey(0), cfg)

    n = 72 if smoke else 120
    payloads = make_queries(n, cfg.vocab_size, length=QUERY_LEN, seed=3)
    rows: list[Row] = []

    # ---- golden embeddings: ONE fault-free pass, loaded (dict lookups)
    # below, never re-minted while asserting ------------------------------
    oracle = JaxEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS)
    golden = {}
    for i, p in enumerate(payloads):
        [emb] = oracle.embed_batch([Query(qid=i, payload=p,
                                          length=QUERY_LEN)])
        golden[p.tobytes()] = np.asarray(emb)

    # ---- engine: serve through the outage -------------------------------
    eng = engine_leg(cfg, params, list(payloads), golden)
    in_deadline = eng["accepted"] - eng["deadline_misses"]
    eng_frac = eng["served_ok"] / max(1, in_deadline)
    rows.append(("chaos/engine-served", 0.0,
                 f"accepted={eng['accepted']} served_ok={eng['served_ok']} "
                 f"failed={eng['failed']} misses={eng['deadline_misses']} "
                 f"frac={eng_frac:.3f} (>=0.99 required)"))
    rows.append(("chaos/engine-failover", eng["failover_p95_s"] * 1e6,
                 f"p95 e2e through outage; {eng['n_during']} arrivals "
                 f"during the {DOWN} window, retries={eng['retries']} "
                 f"backend_errors={eng['backend_errors']}"))
    rows.append(("chaos/engine-breaker", 0.0,
                 f"trips={eng['trips']} recoveries={eng['recoveries']} "
                 f"final={eng['breaker_state']} "
                 f"recovery={eng['recovery_s']:.2f}s after window end"))

    # ---- DES: the same outage on simulated time -------------------------
    res, states = des_leg(n)
    # client-level accepted, like the engine leg: arrivals minus BUSY
    # (Telemetry.accepted re-counts retry re-dispatches)
    des_in_deadline = n - res.rejected - sum(res.deadline_misses.values())
    des_frac = res.n_completed / max(1, des_in_deadline)
    ratio = eng_frac / max(des_frac, 1e-9)
    rows.append(("chaos/des-served", 0.0,
                 f"accepted={n - res.rejected} completed={res.n_completed} "
                 f"failed={res.failed} frac={des_frac:.3f} "
                 f"trips={sum(res.breaker_trips.values())} "
                 f"recoveries={sum(res.breaker_recoveries.values())}"))
    rows.append(("chaos/parity", 0.0,
                 f"engine/des served-through-failure ratio={ratio:.3f} "
                 f"(must be within [0.67, 1.5])"))

    write_bench_json("chaos", rows, metrics={
        "engine_served_frac": eng_frac,
        "engine_failover_p95_s": eng["failover_p95_s"],
        "engine_recovery_s": eng["recovery_s"],
        "engine_trips": eng["trips"],
        "engine_recoveries": eng["recoveries"],
        "engine_retries": eng["retries"],
        "engine_backend_errors": eng["backend_errors"],
        "des_served_frac": des_frac,
        "des_trips": sum(res.breaker_trips.values()),
        "des_recoveries": sum(res.breaker_recoveries.values()),
        "served_frac_ratio": ratio,
        "down_window_s": DOWN[1] - DOWN[0],
    })

    # regression guards — benchmarks.run turns a raise into exit code 1
    assert eng["backend_errors"] > 0, \
        "the outage window injected no failures: the chaos run proved nothing"
    assert eng_frac >= 0.99, \
        f"only {eng_frac:.1%} of in-deadline queries served golden-parity " \
        f"embeddings through the outage (>=99% required)"
    assert eng["trips"] >= 1, "the primary tier's breaker never tripped"
    assert eng["recoveries"] >= 1 and eng["breaker_state"] == CLOSED, \
        f"breaker did not re-close after recovery " \
        f"(state={eng['breaker_state']}, recoveries={eng['recoveries']})"
    assert sum(res.breaker_trips.values()) >= 1, \
        "the DES fault model never tripped the breaker"
    assert 0.67 <= ratio <= 1.5, \
        f"DES does not reproduce the engine served-through-failure " \
        f"fraction: engine={eng_frac:.3f} des={des_frac:.3f} ratio={ratio:.2f}"
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small fast run (CI)")
    args = ap.parse_args()
    emit(run(smoke=args.smoke))
