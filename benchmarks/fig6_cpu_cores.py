"""Fig. 6: scalability with CPU core count (Xeon E5-2690 pool).

Paper claims: below ~44 cores the CPU brings no benefit at 1s SLO; the
boundary drops to ~36 cores at 2s; more cores help until memory-bandwidth
saturation."""
from __future__ import annotations

from benchmarks.common import Row, emit, time_us
from repro.core.affinity import NumaTopology, numa_crossings, plan_affinity
from repro.core.estimator import fine_tune_depth
from repro.core.simulator import PAPER_DEVICES, cpu_core_scaled, profile_fn_for

CORES = (16, 28, 36, 44, 64, 96)


def cpu_depth_at(cores: int, slo: float) -> int:
    base = PAPER_DEVICES["xeon-e5-2690/bge"]
    dev = cpu_core_scaled(base, cores=cores, full_cores=44)
    return fine_tune_depth(profile_fn_for(dev), slo, start=30, radius=29)


def run() -> list[Row]:
    rows: list[Row] = []
    for slo in (1.0, 2.0):
        series = []
        for cores in CORES:
            us = time_us(lambda c=cores, s=slo: cpu_depth_at(c, s))
            dc = cpu_depth_at(cores, slo)
            series.append((cores, dc))
            rows.append((f"fig6/cores{cores}@{slo:.0f}s", us,
                         f"additional={dc}"))
        boundary = next((c for c, d in series if d > 0), None)
        rows.append((f"fig6/benefit-boundary@{slo:.0f}s", 0.0,
                     f"first-useful-cores={boundary} "
                     f"(paper: {44 if slo == 1.0 else 36})"))
    # §4.4 affinity: the 128-core Kunpeng box plan is NUMA-clean
    topo = NumaTopology(128, 4)
    cores = plan_affinity(topo, 32)
    rows.append(("fig6/affinity-plan-32c", 0.0,
                 f"reverse-from={cores[0]} numa-crossings="
                 f"{numa_crossings(topo, cores)} (paper: reverse, 0)"))
    return rows


if __name__ == "__main__":
    emit(run())
