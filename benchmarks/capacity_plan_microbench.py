"""Capacity planning under overload and failure: admission + brownout + DES.

WindVE's deployment-cost story (Eqs. 5-6) prices a topology assuming the
load it was sized for; this bench prices what happens when the load is
WRONG — a flash crowd several times the calibrated capacity, and an NPU
that keeps dying mid-crowd — and asserts the overload-control stack earns
its keep on three fronts:

* **overload A/B/C** — the same flash-crowd trace served by (a) accept-all
  (unbounded queues, the no-control baseline), (b) reject-only (calibrated
  Eq. 12 depths, queue-full BUSY), and (c) SLO-aware admission + brownout.
  Admission+brownout must deliver STRICTLY higher SLO attainment than
  reject-only AND strictly fewer deadline misses than accept-all — shedding
  the predictably-late arrivals beats both queuing everything and shedding
  blindly;
* **cost curve** — the planner sweeps >= 3 topologies (npu-only, npu+cpu,
  npu+cpu-w8a8) plus an MTTF-outage arm against flash-crowd and diurnal
  traces and writes cost-per-million-ACCEPTED-queries; the fault-free
  curve must be strictly monotone decreasing across the sweep order and
  the outage arm must be strictly MORE expensive per accepted query than
  its fault-free twin (failures burn capacity; they must never make an
  arm look cheaper);
* **parity** — a same-instant burst through identical admission/brownout
  controllers on the threaded engine (pinned-GIL submit) and the DES must
  produce counter-for-counter identical dispatch/rejection/brownout
  telemetry: overload control lives in the shared core, not per driver.

Self-asserting (CI runs ``--smoke``; a raise exits non-zero) and emits
machine-readable ``BENCH_capacity_plan.json``.
"""
from __future__ import annotations

import argparse
import sys
from typing import Dict, List

from benchmarks.common import Row, emit, write_bench_json
from repro.core.admission import AdmissionController
from repro.core.faults import FaultModel, FaultSchedule
from repro.core.health import BrownoutController
from repro.core.planner import PlanArm, PlanPoint, best, calibrated_tiers, \
    evaluate
from repro.core.routing import RetryPolicy, TierSpec
from repro.core.simulator import DeviceModel, ServingSimulator, \
    diurnal_trace, quantized_model
from repro.core.windve import ModeledBackend, WindVE
from repro.data.workload import flash_crowd_trace

SLO_S = 1.0
DEADLINE_S = 2.0
REJECT_COST = 0.5


def _models() -> Dict[str, DeviceModel]:
    """Eq. 12 curves: NPU t(C)=0.05+0.01C (depth 95 at the 1s SLO),
    CPU t(C)=0.10+0.05C (depth 18) — the paper's fast/slow split."""
    return {"NPU": DeviceModel("npu", beta=0.05, b=0.01, a=0.0),
            "CPU": DeviceModel("cpu", beta=0.10, b=0.05, a=0.0)}


def _arm(name: str, models, price: float, quantized=(), controlled=True,
         faults=None, retry=None) -> PlanArm:
    tiers, fits = calibrated_tiers(models, SLO_S, quantized=quantized)
    return PlanArm(
        name, tiers=tiers, price_per_s=price,
        admission=AdmissionController(fits=fits, slo_s=SLO_S,
                                      reject_cost=REJECT_COST)
        if controlled else None,
        brownout=BrownoutController() if controlled else None,
        deadline_s=DEADLINE_S, faults=faults or {}, retry=retry)


def overload_leg(trace) -> Dict[str, PlanPoint]:
    """A/B/C on identical hardware: the control stack is the only delta."""
    mdl = _models()
    cal, _ = calibrated_tiers(mdl, SLO_S, quantized={"CPU"})
    # accept-all: same devices and batch bound, but queues never say no
    unbounded = [TierSpec(t.name, 10 ** 6, model=t.model, max_batch=t.depth,
                          quantized=t.quantized) for t in cal]
    arms = [
        PlanArm("accept-all", tiers=unbounded, price_per_s=10.5,
                deadline_s=DEADLINE_S),
        _arm("reject-only", _models(), 10.5, quantized=("CPU",),
             controlled=False),
        _arm("admission+brownout", _models(), 10.5, quantized=("CPU",)),
    ]
    return {a.name: evaluate(a, trace, slo_s=SLO_S, trace_name="flash")
            for a in arms}


def cost_curve_leg(trace, dtrace, horizon_s: float) -> List[PlanPoint]:
    """The planner's unit-economics sweep, outage arm last."""
    w8a8 = lambda: {"NPU": _models()["NPU"],
                    "CPU": quantized_model(_models()["CPU"], 0.6)}
    sched = FaultSchedule.from_mttf(mttf_s=8.0, mttr_s=2.0,
                                    horizon_s=horizon_s, seed=7)
    arms = [
        _arm("npu-only", {"NPU": _models()["NPU"]}, 10.0),
        _arm("npu+cpu", _models(), 10.5),
        _arm("npu+cpu-w8a8", w8a8(), 10.5, quantized=("CPU",)),
        _arm("npu+cpu-w8a8+outage", w8a8(), 10.5, quantized=("CPU",),
             faults={"NPU": FaultModel(schedule=sched, fail_latency_s=0.05)},
             retry=RetryPolicy(max_retries=1, backoff_s=0.0)),
    ]
    pts = [evaluate(a, trace, slo_s=SLO_S, trace_name="flash") for a in arms]
    # diurnal coverage: the winning fault-free topology must also hold the
    # SLO on a day curve that stays under capacity (sizing is two-sided:
    # survive the crowd, don't over-reject the ordinary day)
    pts.append(evaluate(arms[2], dtrace, slo_s=SLO_S, trace_name="diurnal"))
    return pts


def parity_leg():
    """Identical controllers, identical burst, both drivers."""
    T0, T1 = "T0", "T1"
    N, DEPTH = 12, 6

    def models():
        # flat curves double as exact LatencyFits for the controller
        return {T0: DeviceModel(T0, beta=0.1, b=0.0, a=0.0),
                T1: DeviceModel(T1, beta=0.15, b=0.0, a=0.0)}

    def controllers(m):
        # watermark=0.5 opens 3 of 6 slots per tier: a 12-burst must see
        # exactly 6 admission rejections; ewma_alpha=1 makes the brownout
        # stage a pure function of instantaneous utilization (clock-free)
        adm = AdmissionController(fits=m, slo_s=100.0,
                                  reject_cost=REJECT_COST, watermark=0.5)
        bro = BrownoutController(degraded_at=0.3, shedding_at=0.6,
                                 ewma_alpha=1.0, hysteresis=0.05)
        return adm, bro

    def counters(t) -> Dict[str, object]:
        return {"dispatched": dict(t.dispatched), "rejected": t.rejected,
                "completed": t.n_completed,
                "rejections": {k: v for k, v in t.rejections.items() if v},
                "brownout": dict(t.brownout_transitions), "failed": t.failed}

    m = models()
    adm, bro = controllers(m)
    sim = ServingSimulator(
        tiers=[TierSpec(T0, DEPTH, model=m[T0]),
               TierSpec(T1, DEPTH, model=m[T1], quantized=True)],
        slo_s=100.0, admission=adm, brownout=bro)
    des = counters(sim.run([(0.0, 16)] * N))

    m2 = models()
    adm2, bro2 = controllers(m2)
    ve = WindVE(
        tiers=[TierSpec(T0, DEPTH, backend=ModeledBackend(m2[T0],
                                                          embed_dim=4)),
               TierSpec(T1, DEPTH, backend=ModeledBackend(m2[T1],
                                                          embed_dim=4),
                        quantized=True)],
        admission=adm2, brownout=bro2)
    old = sys.getswitchinterval()
    sys.setswitchinterval(5.0)   # pin the burst: workers drain a static
    try:                         # backlog exactly like same-instant arrivals
        futs = [ve.submit(length=16) for _ in range(N)]
    finally:
        sys.setswitchinterval(old)
    done = failed = 0
    for f in futs:
        if f is None:
            continue
        try:
            f.result(timeout=10)
            done += 1
        except Exception:
            failed += 1
    eng = counters(ve.stats)
    ve.shutdown()
    return des, eng, done, failed


def run(smoke: bool = False) -> list[Row]:
    if smoke:
        t1 = flash_crowd_trace(12, 30.0, 6.0, 3, 6, seed=3)
        t2 = flash_crowd_trace(20, 60.0, 6.0, 5, 12, seed=5)
        dtr = diurnal_trace(20, 20.0, 80.0, seed=11)
        horizon = 20.0
    else:
        t1 = flash_crowd_trace(20, 30.0, 6.0, 5, 10, seed=3)
        t2 = flash_crowd_trace(40, 60.0, 6.0, 10, 25, seed=5)
        dtr = diurnal_trace(40, 20.0, 80.0, seed=11)
        horizon = 40.0
    rows: list[Row] = []

    # ---- A/B/C: same hardware, three control stacks ----------------------
    ab = overload_leg(t1)
    for p in ab.values():
        rows.append((f"capacity/overload-{p.arm}", 0.0,
                     f"attainment={p.slo_attainment:.3f} "
                     f"misses={p.deadline_misses} accepted={p.accepted} "
                     f"shed={sum(p.rejections.values())} of "
                     f"{p.arrivals} arrivals"))

    # ---- cost curve: the planner sweep -----------------------------------
    pts = cost_curve_leg(t2, dtr, horizon)
    flash_pts = [p for p in pts if p.trace == "flash"]
    for p in pts:
        rows.append((f"capacity/plan-{p.arm}@{p.trace}", 0.0,
                     f"cost_per_m_accepted={p.cost_per_m_accepted:.0f} "
                     f"attainment={p.slo_attainment:.3f} "
                     f"accepted={p.accepted} failed={p.failed}"))
    pick = best(flash_pts, min_attainment=0.3)
    rows.append(("capacity/plan-best", 0.0,
                 f"{pick.arm}: cheapest accepted query at >=0.3 attainment "
                 f"({pick.cost_per_m_accepted:.0f} per million)"))

    # ---- parity: one control stack, two drivers --------------------------
    des, eng, done, failed = parity_leg()
    rows.append(("capacity/parity-des", 0.0,
                 f"dispatched={des['dispatched']} "
                 f"rejections={des['rejections']} brownout={des['brownout']}"))
    rows.append(("capacity/parity-engine", 0.0,
                 f"dispatched={eng['dispatched']} "
                 f"rejections={eng['rejections']} brownout={eng['brownout']} "
                 f"client done={done} admission-rejected={failed}"))

    adm_p, rej_p, all_p = (ab["admission+brownout"], ab["reject-only"],
                           ab["accept-all"])
    by_arm = {p.arm: p for p in flash_pts}
    write_bench_json("capacity_plan", rows, metrics={
        "overload_attainment_accept_all": all_p.slo_attainment,
        "overload_attainment_reject_only": rej_p.slo_attainment,
        "overload_attainment_admission": adm_p.slo_attainment,
        "overload_misses_accept_all": all_p.deadline_misses,
        "overload_misses_admission": adm_p.deadline_misses,
        "admission_rejections": adm_p.rejections.get("admission", 0),
        "brownout_transitions": sum(
            adm_p.brownout_transitions.values()),
        "plan_points": [p.row() for p in pts],
        "plan_best_arm": pick.arm,
        "cpm_npu_only": by_arm["npu-only"].cost_per_m_accepted,
        "cpm_npu_cpu": by_arm["npu+cpu"].cost_per_m_accepted,
        "cpm_w8a8": by_arm["npu+cpu-w8a8"].cost_per_m_accepted,
        "cpm_w8a8_outage":
            by_arm["npu+cpu-w8a8+outage"].cost_per_m_accepted,
        "parity_ok": des == eng,
    })

    # regression guards — benchmarks.run turns a raise into exit code 1
    assert adm_p.slo_attainment > rej_p.slo_attainment, \
        f"admission+brownout must beat reject-only on SLO attainment " \
        f"({adm_p.slo_attainment:.3f} vs {rej_p.slo_attainment:.3f})"
    assert adm_p.deadline_misses < all_p.deadline_misses, \
        f"admission+brownout must miss fewer deadlines than accept-all " \
        f"({adm_p.deadline_misses} vs {all_p.deadline_misses})"
    assert adm_p.rejections.get("admission", 0) > 0, \
        "the flash crowd triggered no admission rejections: the overload " \
        "leg proved nothing"
    assert sum(adm_p.brownout_transitions.values()) >= 1, \
        "the flash crowd never drove a brownout stage transition"
    cpms = [by_arm[a].cost_per_m_accepted
            for a in ("npu-only", "npu+cpu", "npu+cpu-w8a8")]
    assert cpms[0] > cpms[1] > cpms[2], \
        f"fault-free cost curve is not strictly monotone decreasing: {cpms}"
    assert by_arm["npu+cpu-w8a8+outage"].cost_per_m_accepted > cpms[2], \
        "the MTTF-outage arm looks CHEAPER per accepted query than its " \
        "fault-free twin — failures are being counted as delivered capacity"
    dpt = next(p for p in pts if p.trace == "diurnal")
    assert dpt.slo_attainment >= 0.95, \
        f"the winning topology over-rejects an under-capacity day curve " \
        f"(diurnal attainment {dpt.slo_attainment:.3f})"
    assert des == eng, \
        f"engine and DES disagree on admission/brownout counters:\n" \
        f"  des={des}\n  eng={eng}"
    assert failed == eng["rejections"].get("admission", 0), \
        "every admission rejection must surface as a failed client future"
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small fast run (CI)")
    args = ap.parse_args()
    emit(run(smoke=args.smoke))
