"""Table 3: queue depths via linear regression vs stress test vs fine-tune.

Derived cell mirrors the paper's three-row structure per device x SLO, with
the published values in brackets.  Timing compares the COST of the two
procedures: the estimator needs |probe_points| profiling runs, the stress
test needs C_max/step runs — the paper's efficiency argument, measured."""
from __future__ import annotations

from benchmarks.common import Row, emit, time_us
from repro.core.estimator import (estimate_depth, fine_tune_depth,
                                  stress_test_depth)
from repro.core.simulator import PAPER_DEVICES, profile_fn_for

PAPER = {
    # device: {slo: (regression, stress, fine-tuned)}
    "tesla-v100/bge": {1.0: (40, 40, 44), 2.0: (96, 88, 96)},
    "xeon-e5-2690/bge": {1.0: (8, 6, 8), 2.0: (20, 18, 22)},
    "atlas-300i-duo/bge": {1.0: (84, 80, 84), 2.0: (195, 176, 172)},
    "kunpeng-920/bge": {1.0: (2, 2, 2), 2.0: (15, 12, 8)},
}


def run() -> list[Row]:
    rows: list[Row] = []
    for dev, slos in PAPER.items():
        d = PAPER_DEVICES[dev]
        for slo, (p_reg, p_st, p_ft) in slos.items():
            profile_calls = {"n": 0}

            def p(c, _d=d):
                profile_calls["n"] += 1
                return profile_fn_for(_d, seed=2)(c)

            est, fit = estimate_depth(p, slo)
            est_calls = profile_calls["n"]
            st = stress_test_depth(p, slo, step=8)
            stress_calls = profile_calls["n"] - est_calls
            ft = fine_tune_depth(p, slo, start=max(est, 1), radius=16)
            us = time_us(lambda: estimate_depth(profile_fn_for(d), slo))
            rows.append((
                f"table3/{dev.split('/')[0]}@{slo:.0f}s", us,
                f"reg={est} stress={st} ft={ft} "
                f"(paper: {p_reg}/{p_st}/{p_ft}) "
                f"profiles: {est_calls} vs {stress_calls} runs"))
    return rows


if __name__ == "__main__":
    emit(run())
