"""Table 3: queue depths via linear regression vs stress test vs fine-tune.

Derived cell mirrors the paper's three-row structure per device x SLO, with
the published values in brackets.  Timing compares the COST of the two
procedures: the estimator needs |probe_points| profiling runs, the stress
test needs C_max/step runs — the paper's efficiency argument, measured.

Beyond the paper's table, two A/B families land in
``BENCH_table3_queue_depth.json``:

* ``--devices`` rows — Eq. 12 depth calibrated on the FAN-OUT service
  curve (``simulator.FanOutModel``: per-device pow2 chunks + gather
  overhead) for 1..8 devices, with the closed-form
  ``cost_model.fanout_depth`` cross-check and the realized scaling
  efficiency;
* ``--policy`` rows — DES A/B of cascade vs latency-predictive dispatch at
  EQUAL concurrency (same depths, same diurnal Poisson trace): the
  predictive policy prices each tier's calibrated curve at its live
  backlog, so p95 e2e latency drops while accept/reject stay comparable.
"""
from __future__ import annotations

import argparse

from benchmarks.common import Row, emit, time_us, write_bench_json
from repro.core.cost_model import fanout_depth, fanout_efficiency
from repro.core.estimator import (estimate_depth, fanout_probe_points,
                                  fine_tune_depth, stress_test_depth)
from repro.core.routing import (CPU, NPU, CascadePolicy, PredictivePolicy,
                                TierSpec)
from repro.core.simulator import (PAPER_DEVICES, ServingSimulator,
                                  diurnal_trace, profile_fn_for,
                                  sharded_model)

PAPER = {
    # device: {slo: (regression, stress, fine-tuned)}
    "tesla-v100/bge": {1.0: (40, 40, 44), 2.0: (96, 88, 96)},
    "xeon-e5-2690/bge": {1.0: (8, 6, 8), 2.0: (20, 18, 22)},
    "atlas-300i-duo/bge": {1.0: (84, 80, 84), 2.0: (195, 176, 172)},
    "kunpeng-920/bge": {1.0: (2, 2, 2), 2.0: (15, 12, 8)},
}

FANOUT_BETA_S = 0.005       # modeled per-execution scatter/gather unit cost
AB_SECONDS = 90             # diurnal trace length for the policy A/B
AB_BASE_RATE = 10.0
AB_PEAK_RATE = 34.0         # ~75% of (44 NPU + 8 CPU) peak capacity: the
                            # regime where dispatch choice matters — at full
                            # saturation every policy just fills both queues
AB_NPU_MAX_BATCH = 16       # per-batch execution bound (compile/memory cap)
                            # — backlog beyond it waits MULTIPLE service
                            # rounds, which the cascade ignores and the
                            # backlog-priced predictive policy routes around


def fanout_depth_rows(devices=(1, 2, 4, 8), slo: float = 1.0,
                      npu_key: str = "tesla-v100/bge"):
    """Eq. 12 depth vs device fan-out; returns (rows, metrics)."""
    base = PAPER_DEVICES[npu_key]
    rows: list[Row] = []
    metrics: dict = {}
    d1 = None
    for n in devices:
        model = sharded_model(base, n, fanout_beta_s=FANOUT_BETA_S)
        us = time_us(lambda m=model, n_=n: estimate_depth(
            profile_fn_for(m), slo, probe_points=fanout_probe_points(n_)))
        d, fit = estimate_depth(profile_fn_for(model), slo,
                                probe_points=fanout_probe_points(n))
        if n == 1:
            d1 = d
        closed = fanout_depth(base.b, base.beta, n, slo,
                              overhead_s=getattr(model, "overhead_s", 0.0)) \
            if base.a == 0.0 else None
        # efficiency needs the 1-device baseline; without it, omit the
        # metric rather than writing a non-spec NaN into the BENCH json
        eff = fanout_efficiency(d, d1, n) if d1 else None
        rows.append((
            f"table3/fanout-{npu_key.split('/')[0]}@{n}dev", us,
            f"reg={d} eff={f'{eff:.2f}' if eff is not None else '--'} "
            f"alpha={fit.alpha*1e3:.2f}ms beta={fit.beta*1e3:.0f}ms"
            + (f" closed-form={closed}" if closed is not None else "")))
        metrics[f"fanout_depth_{n}dev"] = d
        if eff is not None:
            metrics[f"fanout_efficiency_{n}dev"] = round(eff, 4)
    return rows, metrics


def policy_ab(slo: float = 1.0, seed: int = 0,
              policies=("cascade", "predictive")):
    """DES A/B at equal concurrency: same depths, same Poisson trace.

    Returns ``{policy_name: Telemetry.summary() dict}``.
    """
    npu = PAPER_DEVICES["tesla-v100/bge"]
    cpu = PAPER_DEVICES["xeon-e5-2690/bge"]
    arrivals = diurnal_trace(AB_SECONDS, AB_BASE_RATE, AB_PEAK_RATE,
                             seed=seed)
    mk = {
        "cascade": lambda: CascadePolicy(),
        # the DES's predictive fits ARE the device models (the calibrated
        # curves the online calibrator would converge to)
        "predictive": lambda: PredictivePolicy(fits={NPU: npu, CPU: cpu}),
    }
    out = {}
    for name in policies:
        tiers = [TierSpec(NPU, 44, model=npu, max_batch=AB_NPU_MAX_BATCH),
                 TierSpec(CPU, 8, model=cpu)]
        sim = ServingSimulator(tiers=tiers, slo_s=slo, seed=seed,
                               policy=mk[name]())
        out[name] = sim.run(list(arrivals)).summary()
    return out


def run(devices=(1, 2, 4, 8), policies=("cascade", "predictive")
        ) -> list[Row]:
    rows: list[Row] = []
    for dev, slos in PAPER.items():
        d = PAPER_DEVICES[dev]
        for slo, (p_reg, p_st, p_ft) in slos.items():
            profile_calls = {"n": 0}

            def p(c, _d=d):
                profile_calls["n"] += 1
                return profile_fn_for(_d, seed=2)(c)

            est, fit = estimate_depth(p, slo)
            est_calls = profile_calls["n"]
            st = stress_test_depth(p, slo, step=8)
            stress_calls = profile_calls["n"] - est_calls
            ft = fine_tune_depth(p, slo, start=max(est, 1), radius=16)
            us = time_us(lambda: estimate_depth(profile_fn_for(d), slo))
            rows.append((
                f"table3/{dev.split('/')[0]}@{slo:.0f}s", us,
                f"reg={est} stress={st} ft={ft} "
                f"(paper: {p_reg}/{p_st}/{p_ft}) "
                f"profiles: {est_calls} vs {stress_calls} runs"))

    # --- fan-out A/B: depth calibration on the sharded service curve
    frows, metrics = fanout_depth_rows(devices=devices)
    rows.extend(frows)

    # --- policy A/B: cascade vs predictive at equal concurrency (DES)
    ab = policy_ab(policies=policies)
    for name, s in ab.items():
        rows.append((
            f"table3/policy-{name}", s["p99_s"] * 1e6,
            f"p50={s['p50_s']:.3f}s p99={s['p99_s']:.3f}s "
            f"accepted={s['accepted']} rejected={s['rejected']} "
            f"violations={s['violations']}"))
        metrics[f"{name}_p50_s"] = round(s["p50_s"], 4)
        metrics[f"{name}_p99_s"] = round(s["p99_s"], 4)
        metrics[f"{name}_accepted"] = s["accepted"]
        metrics[f"{name}_violations"] = s["violations"]
    if {"cascade", "predictive"} <= set(ab):
        # the acceptance A/B (tier-1 test asserts the same inequality):
        # latency-predictive dispatch beats the cascade's e2e tail at
        # equal concurrency
        c95 = ab["cascade"]["p95_s"]
        p95 = ab["predictive"]["p95_s"]
        metrics["cascade_p95_s"] = round(c95, 4)
        metrics["predictive_p95_s"] = round(p95, 4)
        metrics["predictive_p95_speedup"] = round(c95 / p95, 3) if p95 else 0.0
        assert p95 < c95, (
            f"predictive p95 {p95:.3f}s did not beat cascade {c95:.3f}s")
    write_bench_json("table3_queue_depth", rows, metrics=metrics)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", default="1,2,4,8",
                    help="fan-out device counts for the depth A/B rows")
    ap.add_argument("--policy", default="cascade,predictive",
                    help="dispatch policies for the DES A/B rows")
    args = ap.parse_args()
    emit(run(devices=tuple(int(d) for d in args.devices.split(",")),
             policies=tuple(args.policy.split(","))))


if __name__ == "__main__":
    main()
