"""Table 2: WindVE vs plain PyTorch serving on the jina model."""
from __future__ import annotations

from benchmarks.common import Row, emit, finetuned_depths, time_us
from repro.core.cost_model import peak_saving, throughput_uplift
from repro.core.routing import CPU, NPU, TierSpec
from repro.core.simulator import PAPER_DEVICES, ServingSimulator

PAPER_ROWS = {
    ("tesla-v100/jina", "xeon-e5-2690/jina", 1.0): (48, 11, 22.9),
    ("tesla-v100/jina", "xeon-e5-2690/jina", 2.0): (112, 30, 26.7),
    ("atlas-300i-duo/jina", "kunpeng-920/jina", 1.0): (128, 6, 4.6),
    ("atlas-300i-duo/jina", "kunpeng-920/jina", 2.0): (256, 20, 7.8),
}


def run() -> list[Row]:
    rows: list[Row] = []
    for (nk, ck, slo), (p_n, p_c, p_imp) in PAPER_ROWS.items():
        dn, dc = finetuned_depths(nk, ck, slo)
        npu, cpu = PAPER_DEVICES[nk], PAPER_DEVICES[ck]
        us = time_us(lambda: ServingSimulator(
            tiers=[TierSpec(NPU, dn, model=npu), TierSpec(CPU, dc, model=cpu)],
            slo_s=slo).run_burst(dn + dc), repeats=3)
        imp = throughput_uplift(dn, dc) * 100
        save = peak_saving(dn, dc) * 100
        name = f"table2/{nk.split('/')[0]}+{ck.split('/')[0]}@{slo:.0f}s"
        rows.append((name, us,
                     f"C={dn}+{dc} improve={imp:.1f}% save={save:.1f}% "
                     f"(paper: {p_n}+{p_c} {p_imp}%)"))
    return rows


if __name__ == "__main__":
    emit(run())
