# One module per paper table/figure.  Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import argparse
import importlib
import sys
import traceback

MODULES = [
    "benchmarks.table1_bge",        # Table 1: bge concurrency vs FlagEmbedding
    "benchmarks.table2_jina",       # Table 2: jina concurrency vs PyTorch
    "benchmarks.table3_queue_depth",  # Table 3: estimator vs stress test
    "benchmarks.fig4_fitting",      # Fig. 4: latency-concurrency fits
    "benchmarks.fig5_query_length",  # Fig. 5: query-length scalability
    "benchmarks.fig6_cpu_cores",    # Fig. 6: CPU-core scalability
    "benchmarks.engine_microbench",  # real engine on this host
    "benchmarks.bucketing_microbench",  # shape bucketing vs fixed padding
    "benchmarks.sharded_embed_microbench",  # device mesh fan-out + bf16
    "benchmarks.quant_embed_microbench",    # int8 weight-only CPU tier
    "benchmarks.cache_microbench",  # zero-cost exact-match cache tier
    "benchmarks.chaos_microbench",  # fault tolerance: serve through outage
    "benchmarks.capacity_plan_microbench",  # overload control + planner
    "benchmarks.multihost_microbench",  # replica-aware routing A/B
    "benchmarks.roofline_table",    # §Roofline from the dry-run artifacts
]

# fast subset for CI: tables 1-3 catch dispatch-semantics drift between
# engine and simulator (they run entirely on the DES); the bucketing
# microbench self-asserts its padded-waste / recompile / equality floors so
# hot-path padding regressions fail the build
SMOKE_MODULES = [
    "benchmarks.table1_bge",
    "benchmarks.table2_jina",
    "benchmarks.table3_queue_depth",
    "benchmarks.bucketing_microbench",
]

# NOTE: multihost_microbench runs in CI as a dedicated step under
# XLA_FLAGS=--xla_force_host_platform_device_count=8 (like the sharded
# bench) so the replica-mesh carving leg sees a real multi-device pool


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on module name")
    ap.add_argument("--smoke", action="store_true",
                    help="fast jax-free subset (CI: paper tables 1-3)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = False
    for modname in (SMOKE_MODULES if args.smoke else MODULES):
        if args.only and args.only not in modname:
            continue
        try:
            mod = importlib.import_module(modname)
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception:
            failed = True
            print(f"{modname},0.0,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
