"""Fig. 5: scalability with input query length (V100 + Xeon).

Paper claims: longer queries shrink both pools' concurrency; at 1s SLO the
CPU's additional concurrency hits 0 by length 500; at 2s it survives (~2)."""
from __future__ import annotations

from benchmarks.common import Row, emit, time_us
from repro.core.estimator import fine_tune_depth
from repro.core.simulator import PAPER_DEVICES, profile_fn_for

LENGTHS = (75, 150, 300, 500)


def depths_at(length: int, slo: float):
    npu = PAPER_DEVICES["tesla-v100/bge"]
    cpu = PAPER_DEVICES["xeon-e5-2690/bge"]
    pn = profile_fn_for(npu, length=length)
    pc = profile_fn_for(cpu, length=length)
    dn = fine_tune_depth(pn, slo, start=100, radius=60)
    dc = fine_tune_depth(pc, slo, start=30, radius=29)
    return dn, dc


def run() -> list[Row]:
    rows: list[Row] = []
    for slo in (1.0, 2.0):
        series = []
        for ln in LENGTHS:
            us = time_us(lambda l=ln, s=slo: depths_at(l, s))
            dn, dc = depths_at(ln, slo)
            series.append((ln, dn, dc))
            rows.append((f"fig5/len{ln}@{slo:.0f}s", us,
                         f"original={dn} additional={dc}"))
        # paper claims encoded as derived checks
        lens, dns, dcs = zip(*series)
        mono = all(a >= b for a, b in zip(dns, dns[1:])) and \
            all(a >= b for a, b in zip(dcs, dcs[1:]))
        rows.append((f"fig5/monotone-degradation@{slo:.0f}s", 0.0,
                     f"holds={mono} (paper: holds)"))
        if slo == 1.0:
            rows.append(("fig5/cpu-dies-at-500@1s", 0.0,
                         f"additional={series[-1][2]} (paper: 0)"))
        else:
            rows.append(("fig5/cpu-survives-at-500@2s", 0.0,
                         f"additional={series[-1][2]} (paper: 2)"))
    return rows


if __name__ == "__main__":
    emit(run())
