"""Table 1: WindVE vs FlagEmbedding (no offload) concurrency on bge.

Four columns: (V100+Xeon, Atlas+Kunpeng) x (1s, 2s).  Derived value =
"C_NPU+C_CPU improvement% (paper: X%)" so drift vs the published row is
visible.  Timing = DES wall time for the burst experiment."""
from __future__ import annotations

import dataclasses

from benchmarks.common import Row, emit, finetuned_depths, time_us
from repro.core.cost_model import peak_saving, throughput_uplift
from repro.core.routing import CPU, NPU, TierSpec
from repro.core.simulator import PAPER_DEVICES, ServingSimulator

PAPER_ROWS = {
    ("tesla-v100/bge", "xeon-e5-2690/bge", 1.0): (44, 8, 18.2),
    ("tesla-v100/bge", "xeon-e5-2690/bge", 2.0): (96, 22, 22.3),
    ("atlas-300i-duo/bge", "kunpeng-920/bge", 1.0): (84, 1, 1.2),
    ("atlas-300i-duo/bge", "kunpeng-920/bge", 2.0): (172, 8, 4.7),
}


def run() -> list[Row]:
    rows: list[Row] = []
    for (nk, ck, slo), (p_n, p_c, p_imp) in PAPER_ROWS.items():
        dn, dc = finetuned_depths(nk, ck, slo)
        # depths are calibrated against the noisy profiles; the burst check
        # runs on nominal latency (the paper fine-tunes collaboratively too)
        npu = dataclasses.replace(PAPER_DEVICES[nk], noise_std=0.0)
        cpu = dataclasses.replace(PAPER_DEVICES[ck], noise_std=0.0)

        def burst():
            base = ServingSimulator(tiers=[TierSpec(NPU, dn, model=npu)],
                                    slo_s=slo).run_burst(dn + dc + 8)
            wind = ServingSimulator(tiers=[TierSpec(NPU, dn, model=npu),
                                           TierSpec(CPU, dc, model=cpu)],
                                    slo_s=slo).run_burst(dn + dc + 8)
            return base, wind

        us = time_us(burst, repeats=3)
        base, wind = burst()
        imp = throughput_uplift(dn, dc) * 100
        save = peak_saving(dn, dc) * 100
        name = f"table1/{nk.split('/')[0]}+{ck.split('/')[0]}@{slo:.0f}s"
        rows.append((name, us,
                     f"C={dn}+{dc} improve={imp:.1f}% save={save:.1f}% "
                     f"burst: {base.accepted}->{wind.accepted} accepted "
                     f"viol={wind.violations} (paper: {p_n}+{p_c} {p_imp}%)"))
    return rows


if __name__ == "__main__":
    emit(run())
