"""Zero-cost cache tier A/B: exact-match embedding cache on a Zipf trace.

Real query streams are heavily skewed; a cache hit is a query served at
~zero latency and zero FLOPs, which raises effective concurrency past
anything a faster backend can buy.  This bench drives the SAME
deterministic Zipf-skewed repeat-query trace
(``repro.data.workload.zipf_queries``, alpha ~ 1.1, >= 50% repeat rate)
through cache-on vs cache-off topologies at identical arrival rates, on
BOTH drivers of the shared scheduling core:

* engine — the real ``JaxEmbedderBackend`` served closed-loop; warm-trace
  per-query p50 must COLLAPSE >= 2x with the cache on (hits resolve their
  future at dispatch), and every hit must serve the bitwise-identical
  embedding the cache-off run computed for the same tokens;
* DES — the same skewed key stream at a fixed arrival rate against a
  calibrated device model whose depth the load saturates: the cache tier
  absorbs the hot keys, so ACCEPTED concurrency rises (fewer BUSY
  rejections at the identical trace) and ``Telemetry.summary()`` reports
  the hit rate;
* zero-skew control — an all-distinct trace (no repeats to exploit): the
  consulted-but-always-missing cache (lookup + admission on every query)
  must cost <= 5% warm serve time vs cache-off.

Self-asserting (CI runs ``--smoke``; a raise exits non-zero) and emits
machine-readable ``BENCH_cache.json``.
"""
from __future__ import annotations

import argparse
import time
from typing import List

import numpy as np

from benchmarks.common import Row, emit, write_bench_json
from repro.core.cache import cache_tier
from repro.core.routing import CPU, TierSpec
from repro.core.simulator import DeviceModel, ServingSimulator
from repro.core.windve import JaxEmbedderBackend, WindVE
from repro.data.workload import make_queries, zipf_queries

MAX_TOKENS = 64
QUERY_LEN = 48
ZIPF_ALPHA = 1.1


def serve_closed_loop(engine: WindVE, payloads: List[np.ndarray]):
    """Serve one query at a time (identical arrival pattern either leg);
    returns (per-query latencies [s], served embeddings)."""
    lats, embs = [], []
    for p in payloads:
        t0 = time.perf_counter()
        fut = engine.submit(payload=p, length=len(p))
        emb = fut.result(timeout=120)
        lats.append(time.perf_counter() - t0)
        embs.append(np.asarray(emb))
    return lats, embs


def engine_leg(backend, payloads, warm, cache_entries: int):
    tiers = [TierSpec(CPU, 10 ** 6, backend=backend)]
    if cache_entries:
        tiers.insert(0, cache_tier(cache_entries))
    ve = WindVE(tiers=tiers)
    try:
        serve_closed_loop(ve, warm)          # jit + (cache-on) cache warm
        lats, embs = serve_closed_loop(ve, payloads)
        return lats, embs, ve.stats
    finally:
        ve.shutdown()


def des_leg(keys: List[int], rate_qps: float, depth: int,
            cache_entries: int):
    """The identical skewed arrival stream, cache on/off, against a device
    whose SLO-safe depth the arrival rate saturates."""
    dev = DeviceModel("npu", beta=0.05, b=0.01, a=0.0)
    tiers = [TierSpec("NPU", depth, model=dev, max_batch=depth)]
    if cache_entries:
        tiers.insert(0, cache_tier(cache_entries))
    sim = ServingSimulator(tiers=tiers, slo_s=1.0)
    arrivals = [(i / rate_qps, QUERY_LEN, int(k)) for i, k in enumerate(keys)]
    return sim.run(arrivals)


def run(smoke: bool = False) -> list[Row]:
    import jax

    from repro.configs import get_config
    from repro.models import embedder

    cfg = get_config("bge-large-zh-v1.5").smoke()
    params = embedder.init_embedder(jax.random.PRNGKey(0), cfg)
    backend = JaxEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS)

    n = 64 if smoke else 160
    unique = 12 if smoke else 24
    rows: list[Row] = []

    # ---- the skewed trace (>= 50% theoretical repeat rate by construction:
    # at most `unique` first occurrences in n draws) --------------------------
    trace = zipf_queries(n, cfg.vocab_size, alpha=ZIPF_ALPHA, unique=unique,
                         seed=0, length=QUERY_LEN)
    distinct = {p.tobytes() for p in trace}
    repeat_rate = 1.0 - len(distinct) / len(trace)
    rows.append(("cache/trace", 0.0,
                 f"n={n} unique<={unique} alpha={ZIPF_ALPHA} "
                 f"repeat_rate={repeat_rate:.1%} (>=50% required)"))

    # ---- engine A/B: warm p50 collapse + bitwise-identical hits ------------
    warm = list({p.tobytes(): p for p in trace}.values())   # each key once
    off_lats, off_embs, off_stats = engine_leg(backend, trace, warm, 0)
    on_lats, on_embs, on_stats = engine_leg(backend, trace, warm, 4 * unique)
    p50_off = float(np.percentile(off_lats, 50))
    p50_on = float(np.percentile(on_lats, 50))
    p50_speedup = p50_off / max(p50_on, 1e-9)
    bitwise_ok = all(np.array_equal(a, b)
                     for a, b in zip(off_embs, on_embs))
    hit_rate_engine = on_stats.cache_hit_rate()
    rows.append(("cache/warm-p50-off", p50_off * 1e6,
                 f"closed-loop {n} queries, no cache"))
    rows.append(("cache/warm-p50-on", p50_on * 1e6,
                 f"hit_rate={hit_rate_engine:.1%} "
                 f"p50_collapse={p50_speedup:.1f}x (>=2x required)"))
    rows.append(("cache/bitwise", 0.0,
                 f"served-on == served-off bitwise for all {n}: "
                 f"{bitwise_ok} (exact-match contract)"))

    # ---- zero-skew control: all-distinct trace, cache consulted in vain ----
    n0 = 32 if smoke else 64
    zs_warm = make_queries(n0, cfg.vocab_size, length=QUERY_LEN, seed=5)
    # per-query medians, legs ALTERNATING order per rep, min ratio of 3:
    # the lookup cost under test is ~us against a ~ms serve, so worker-
    # wakeup scheduling drift between two sequential legs dwarfs it.  A
    # real regression (e.g. an O(n) scan snuck into the lookup) inflates
    # every rep regardless of order, so the min still catches it.
    ratios = []
    for rep in range(3):
        fresh = make_queries(n0, cfg.vocab_size, length=QUERY_LEN,
                             seed=100 + rep)
        legs = [0, 4 * n0] if rep % 2 == 0 else [4 * n0, 0]
        med = {}
        for entries in legs:
            lats, _, _ = engine_leg(backend, fresh, zs_warm, entries)
            med[entries] = float(np.median(lats))
        ratios.append(med[4 * n0] / max(med[0], 1e-9))
    zero_skew_overhead = min(ratios)
    rows.append(("cache/zero-skew-overhead", 0.0,
                 f"all-distinct warm serve: on/off={zero_skew_overhead:.3f} "
                 f"(<=1.05 required)"))

    # ---- DES A/B: accepted concurrency at identical arrival rate ----------
    rng = np.random.default_rng(0)
    pz = np.arange(1, unique + 1, dtype=float) ** -ZIPF_ALPHA
    pz /= pz.sum()
    keys = rng.choice(unique, size=4 * n, p=pz)
    depth, rate = 4, 50.0
    res_off = des_leg(list(keys), rate, depth, 0)
    res_on = des_leg(list(keys), rate, depth, 4 * unique)
    hit_rate_des = res_on.cache_hit_rate()
    rows.append(("cache/des-accepted", 0.0,
                 f"accepted on={res_on.accepted} off={res_off.accepted} "
                 f"rejected on={res_on.rejected} off={res_off.rejected} "
                 f"hit_rate={hit_rate_des:.1%} (on must accept more)"))

    write_bench_json("cache", rows, metrics={
        "repeat_rate": repeat_rate,
        "warm_p50_off_s": p50_off,
        "warm_p50_on_s": p50_on,
        "warm_p50_speedup": p50_speedup,
        "bitwise_equal": float(bitwise_ok),
        "zero_skew_overhead": zero_skew_overhead,
        "hit_rate_engine": hit_rate_engine,
        "hit_rate_des": hit_rate_des,
        "cache_staleness_p50_s": on_stats.cache_staleness(50),
        "des_accepted_on": res_on.accepted,
        "des_accepted_off": res_off.accepted,
        "des_rejected_on": res_on.rejected,
        "des_rejected_off": res_off.rejected,
    })

    # regression guards — benchmarks.run turns a raise into exit code 1
    assert repeat_rate >= 0.5, \
        f"Zipf trace repeat rate {repeat_rate:.1%} < 50%"
    assert p50_speedup >= 2.0, \
        f"warm p50 collapse {p50_speedup:.2f}x < 2x " \
        f"(off={p50_off*1e3:.2f}ms on={p50_on*1e3:.2f}ms)"
    assert bitwise_ok, "cache-on served embeddings diverged from cache-off"
    assert zero_skew_overhead <= 1.05, \
        f"zero-skew cache overhead {zero_skew_overhead:.3f} > 1.05"
    assert res_on.accepted > res_off.accepted, \
        f"cache did not raise accepted concurrency: " \
        f"{res_on.accepted} vs {res_off.accepted}"
    # BOTH drivers must surface the hit rate through Telemetry.summary()
    assert on_stats.summary()["cache_hit_rate"] > 0.4
    assert res_on.summary()["cache_hit_rate"] > 0.4
    assert "cache_hit_rate" not in res_off.summary()   # cache-less: unchanged
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small fast run (CI)")
    args = ap.parse_args()
    emit(run(smoke=args.smoke))
