"""Multi-host, multi-replica serving: replica-aware routing earns its keep.

WindVE's Eq. 12 calibration prices ONE device pool; this bench scales the
story out to an H x R replica topology and asserts three things the
multi-replica layer must deliver before it ships:

* **routing A/B** — the same flash-crowd trace over identical hardware
  (2 hosts x 2 replicas, one replica DEGRADED to a non-pow2 6-device
  fan-out), served by (a) replica-oblivious round-robin and (b) the
  predictive policy priced with per-replica Eq. 12 fits
  (``estimator.replica_fits``).  Predictive must deliver a STRICTLY lower
  p95: knowing one replica is slow is the whole point of replica-level
  fits;
* **degraded planning** — a one-host-down pool and a non-pow2 fan-out must
  both stay plannable end-to-end: ``FanOutModel`` chunk plans floor to the
  largest pow2 (compile-cache buckets survive degradation), the surviving
  half-pool still carves into replica meshes, and the DES serves the trace
  through the degraded topology to finite latencies;
* **fault parity** — a seeded :class:`FaultPlan` pinned to one replica of
  the set must produce counter-for-counter identical per-replica telemetry
  (retries, backend errors, breaker trips, failover dispatches) on the
  threaded engine and the DES — replica failure accounting lives in the
  shared core, not per driver.

Self-asserting (CI runs ``--smoke``; a raise exits non-zero) and emits
machine-readable ``BENCH_multihost.json``.
"""
from __future__ import annotations

import argparse
import sys
from typing import Dict, List, Tuple

from benchmarks.common import Row, emit, write_bench_json
from repro.core.estimator import replica_fits
from repro.core.faults import FaultModel, FaultPlan, FaultyBackend
from repro.core.health import CircuitBreaker
from repro.core.routing import (PredictivePolicy, RetryPolicy,
                                RoundRobinPolicy, TierSpec, replica_name,
                                replicate)
from repro.core.simulator import (DeviceModel, FanOutModel,
                                  ServingSimulator, sharded_model)
from repro.core.windve import ModeledBackend, WindVE
from repro.data.workload import flash_crowd_trace

HOSTS, REPLICAS = 2, 2
# batches of 8 rows: a full pow2 mesh runs one row per device, the
# degraded 6-device replica must double up (ceil rows) — device loss is
# only visible to routing when chunks outgrow the surviving devices
DEPTH, MAX_BATCH = 16, 8
DEGRADED = replica_name("NPU", 0, 0)     # the 6-device straggler replica
HEALTHY_DEVICES, DEGRADED_DEVICES = 8, 6
FANOUT_BETA_S = 0.001


def _base() -> DeviceModel:
    # Eq. 12 curve per device pool: t(C) = 0.03 + 0.012 C
    return DeviceModel("npu", beta=0.03, b=0.012, a=0.0)


def replica_models() -> Dict[str, object]:
    """Per-replica service models: replica h0r0 lost two of its eight
    devices (non-pow2 fan-out — the degraded planning path), the rest run
    the full pow2 mesh."""
    specs = replicate(TierSpec("NPU", DEPTH), HOSTS, REPLICAS)
    out: Dict[str, object] = {}
    for t in specs:
        devs = DEGRADED_DEVICES if t.name == DEGRADED else HEALTHY_DEVICES
        out[t.name] = sharded_model(_base(), devs,
                                    fanout_beta_s=FANOUT_BETA_S)
    return out


def routing_ab(trace) -> Tuple[Dict[str, object], Dict[str, object]]:
    """Same trace, same hardware, two policies: replica-aware predictive
    (per-replica fits) vs replica-oblivious round-robin."""

    def leg(policy):
        models = replica_models()
        tiers = [TierSpec(t.name, DEPTH, model=models[t.name],
                          max_batch=MAX_BATCH, replica_of=t.replica_of,
                          host=t.host)
                 for t in replicate(TierSpec("NPU", DEPTH), HOSTS, REPLICAS)]
        sim = ServingSimulator(tiers=tiers, slo_s=100.0, policy=policy)
        res = sim.run(trace)
        return {"p95": res.p(95), "p50": res.p(50),
                "completed": res.n_completed, "rejected": res.rejected,
                "dispatched": dict(res.dispatched),
                "rollup": res.replica_rollup()}

    fits = replica_fits(replica_models(), probe_points=(1, 4, 16, 64))
    pred = leg(PredictivePolicy(fits=fits))
    rr = leg(RoundRobinPolicy())
    return pred, rr


def degraded_planning(trace) -> Dict[str, object]:
    """One host down + non-pow2 fan-out: everything still plans."""
    # chunk planning floors to the largest pow2 and stays bitwise at pow2
    deg = FanOutModel(_base(), DEGRADED_DEVICES,
                      fanout_beta_s=FANOUT_BETA_S)
    assert deg.chunk_floor == 4 and deg.chunk_plan(20) == [16, 4], \
        (deg.chunk_floor, deg.chunk_plan(20))
    full = FanOutModel(_base(), HEALTHY_DEVICES,
                       fanout_beta_s=FANOUT_BETA_S)
    assert full.chunk_plan(20) == [16, 8] and full.chunk_floor == 8
    # a replica spanning two hosts pays the inter-host gather term
    spanning = FanOutModel(_base(), HEALTHY_DEVICES,
                           fanout_beta_s=FANOUT_BETA_S, hosts=2,
                           interhost_beta_s=0.01)
    assert spanning.overhead_s > full.overhead_s

    # host 1 down: only host 0's replicas survive; the DES still serves
    survivors = [t for t in replicate(TierSpec("NPU", DEPTH), HOSTS,
                                      REPLICAS) if t.host == 0]
    models = replica_models()
    tiers = [TierSpec(t.name, DEPTH, model=models[t.name],
                      max_batch=MAX_BATCH) for t in survivors]
    fits = replica_fits({t.name: models[t.name] for t in survivors})
    res = ServingSimulator(tiers=tiers, slo_s=100.0,
                           policy=PredictivePolicy(fits=fits)).run(trace)
    assert res.n_completed + res.rejected == len(trace)
    assert res.n_completed > 0 and res.p(95) > 0.0

    # the surviving half pool still carves into replica meshes (real jax
    # mesh objects when the forced-device pool is big enough)
    carved = 0
    try:
        import jax
        from repro.launch.mesh import make_replica_meshes
        pool = jax.local_devices()
        if len(pool) >= 4:
            meshes = make_replica_meshes(1, 2, pool[:len(pool) // 2])
            carved = len(meshes)
            assert carved == 2
    except ImportError:                              # pragma: no cover
        pass
    return {"survivor_p95": res.p(95), "survivor_completed": res.n_completed,
            "survivor_rejected": res.rejected,
            "degraded_chunk_plan": deg.chunk_plan(20),
            "interhost_overhead_s": spanning.overhead_s,
            "carved_meshes": carved}


def fault_parity(n: int = 8) -> Tuple[Dict, Dict]:
    """Seeded per-replica fault plan: both drivers, identical counters."""
    plan = FaultPlan(fail=frozenset({0, 1}))
    retry = RetryPolicy(max_retries=2, backoff_s=0.0)
    depth = n + 4                        # no BUSY clock races
    specs = replicate(TierSpec("NPU", depth, max_batch=2), HOSTS, REPLICAS)
    models = {t.name: DeviceModel(t.name, beta=0.05 + 0.02 * i, b=0.0,
                                  a=0.0) for i, t in enumerate(specs)}
    victim = specs[0].name

    def brk():
        return CircuitBreaker(failure_threshold=2, cooldown_s=1000.0)

    def record(t):
        return {"dispatched": dict(t.dispatched),
                "retries": dict(t.retries),
                "backend_errors": dict(t.backend_errors),
                "breaker_trips": dict(t.breaker_trips),
                "failed": t.failed}

    import dataclasses
    eng_tiers = [dataclasses.replace(
        t, breaker=brk(),
        backend=(FaultyBackend(ModeledBackend(models[t.name], embed_dim=4),
                               plan=plan) if t.name == victim
                 else ModeledBackend(models[t.name], embed_dim=4)))
        for t in specs]
    ve = WindVE(tiers=eng_tiers, retry=retry)
    old = sys.getswitchinterval()
    try:
        sys.setswitchinterval(5.0)       # pin the burst (see parity tests)
        try:
            futs = [ve.submit(length=16) for _ in range(n)]
        finally:
            sys.setswitchinterval(old)
        for f in futs:
            if f is not None:
                try:
                    f.result(timeout=30)
                except Exception:
                    pass
        eng = record(ve.stats)
    finally:
        sys.setswitchinterval(old)
        ve.shutdown()

    des_tiers = [dataclasses.replace(t, breaker=brk(),
                                     model=models[t.name]) for t in specs]
    # nonzero failure-detection cost keeps the DES victim's server serial
    # like the engine's worker: retry re-dispatch lands BETWEEN consecutive
    # batch failures on both clocks, so breaker-vs-retry ordering matches
    sim = ServingSimulator(tiers=des_tiers, slo_s=100.0, retry=retry,
                           faults={victim: FaultModel(plan=plan,
                                                      fail_latency_s=0.01)})
    des = record(sim.run([(0.0, 16)] * n))
    return eng, des


def run(smoke: bool = False) -> List[Row]:
    # the crowd is sized to QUEUE the topology without overflowing it
    # (~480 q/s burst vs ~600 q/s aggregate capacity): with zero BUSY
    # rejections both legs serve identical traffic, so p95 is a pure
    # routing comparison — oversubscribed traces degenerate into shedding
    # contests where tail latency no longer measures the policy
    if smoke:
        trace = flash_crowd_trace(12, 60.0, 8.0, 3, 6, seed=9)
    else:
        trace = flash_crowd_trace(30, 60.0, 8.0, 8, 12, seed=9)
    rows: List[Row] = []

    # ---- A/B: replica-aware predictive vs round-robin --------------------
    pred, rr = routing_ab(trace)
    deg_share = {
        k: v["dispatched"].get(DEGRADED, 0) / max(1, sum(
            v["dispatched"].values())) for k, v in
        (("predictive", pred), ("round-robin", rr))}
    for name, leg in (("predictive", pred), ("round-robin", rr)):
        rows.append((f"multihost/ab-{name}", leg["p95"] * 1e6,
                     f"p95={leg['p95']:.4f}s p50={leg['p50']:.4f}s "
                     f"completed={leg['completed']} "
                     f"rejected={leg['rejected']} "
                     f"degraded_share={deg_share[name]:.3f}"))

    # ---- degraded planning: one host down, non-pow2 fan-out --------------
    deg = degraded_planning(trace)
    rows.append(("multihost/degraded-one-host-down",
                 deg["survivor_p95"] * 1e6,
                 f"completed={deg['survivor_completed']} "
                 f"rejected={deg['survivor_rejected']} "
                 f"chunk_plan(20)={deg['degraded_chunk_plan']} "
                 f"carved_meshes={deg['carved_meshes']}"))

    # ---- fault parity: per-replica counters, both drivers ----------------
    eng, des = fault_parity()
    rows.append(("multihost/fault-parity", 0.0,
                 f"dispatched={eng['dispatched']} "
                 f"breaker_trips={eng['breaker_trips']} "
                 f"retries={eng['retries']} parity={eng == des}"))

    write_bench_json("multihost", rows, metrics={
        "hosts": HOSTS, "replicas": REPLICAS,
        "p95_predictive_s": pred["p95"],
        "p95_round_robin_s": rr["p95"],
        "p95_speedup": rr["p95"] / pred["p95"] if pred["p95"] else 0.0,
        "degraded_share_predictive": deg_share["predictive"],
        "degraded_share_round_robin": deg_share["round-robin"],
        "dispatched_predictive": pred["dispatched"],
        "dispatched_round_robin": rr["dispatched"],
        "replica_rollup_predictive": pred["rollup"],
        "degraded_chunk_plan": deg["degraded_chunk_plan"],
        "interhost_overhead_s": deg["interhost_overhead_s"],
        "one_host_down_completed": deg["survivor_completed"],
        "one_host_down_p95_s": deg["survivor_p95"],
        "carved_meshes": deg["carved_meshes"],
        "fault_parity_ok": eng == des,
        "fault_counters": eng,
    })

    # regression guards — benchmarks.run turns a raise into exit code 1
    assert pred["rejected"] == rr["rejected"] == 0 and \
        pred["completed"] == rr["completed"] == len(trace), \
        "the A/B legs must serve the whole trace (resize the crowd if " \
        "this topology started shedding)"
    assert pred["p95"] < rr["p95"], \
        f"replica-aware predictive must beat round-robin on p95 at equal " \
        f"hardware ({pred['p95']:.4f}s vs {rr['p95']:.4f}s)"
    assert deg_share["predictive"] < deg_share["round-robin"], \
        f"predictive must shift load OFF the degraded replica " \
        f"({deg_share['predictive']:.3f} vs {deg_share['round-robin']:.3f})"
    assert eng == des, \
        f"engine and DES disagree on per-replica fault counters:\n" \
        f"  eng={eng}\n  des={des}"
    assert set(eng["backend_errors"]) <= {replica_name('NPU', 0, 0)}, \
        "faults leaked across replica boundaries"
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small fast run (CI)")
    args = ap.parse_args()
    emit(run(smoke=args.smoke))
