"""Device-sharded embedding serving A/B: throughput scaling, recompiles,
precision parity and allocation reuse for ``ShardedEmbedderBackend``.

The same bucketed batch stream is served two ways:

* 1 device  — the PR 2 single-device bucketed path (what a sharded mesh of
              one degrades to);
* N devices — data-parallel mesh fan-out (serve-mode rules: weights
              resident, batch over ``data``) + bf16-resident weights +
              donated input buffers + async double-buffered dispatch.

Run standalone it forces an 8-device host mesh BEFORE importing jax
(``--xla_force_host_platform_device_count``); under ``benchmarks.run`` it
uses however many devices the process already has and says so in the row
(no silent caps).

Self-asserting regression guards (CI runs ``--smoke``; a raise exits
non-zero): near-linear throughput scaling — >= 3x on an 8-device mesh when
the host has the cores to back it, scaled by ``min(devices, cores)`` because
forced host devices share physical cores; ZERO steady-state recompiles after
prewarm; bf16 embeddings within 1e-2 cosine of the fp32 oracle; and one
reusable host staging pair per (B, S) bucket (zero steady-state host
allocations).
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import List

import numpy as np

from benchmarks.common import Row, emit, write_bench_json

DEFAULT_DEVICES = 8
MAX_TOKENS = 64
MIN_SEQ_BUCKET = 16
# Fig.-5-shaped mix, all inside the 64-token window so batches stay dense
LENGTHS = (12, 20, 28, 40, 55, 60)
WEIGHTS = (0.25, 0.2, 0.15, 0.15, 0.15, 0.1)


def _force_devices(n: int) -> None:
    """Must run BEFORE the first jax import (host device count is fixed at
    backend init)."""
    assert "jax" not in sys.modules, "set device count before importing jax"
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = \
            f"{flags} --xla_force_host_platform_device_count={n}".strip()


def _batches(n_batches: int, batch: int, seed: int = 0) -> List[List]:
    from repro.core.routing import Query

    rng = np.random.default_rng(seed)
    out, qid = [], 0
    for _ in range(n_batches):
        lens = rng.choice(LENGTHS, size=batch, p=WEIGHTS)
        out.append([Query(qid=(qid := qid + 1), length=int(ln))
                    for ln in lens])
    return out


def _serve_qps(backend, batches: List[List]) -> float:
    """Double-buffered serving pass (the engine worker's async discipline):
    batch N-1's fetch overlaps batch N's compute."""
    n = sum(len(b) for b in batches)
    t0 = time.perf_counter()
    prev = None
    for b in batches:
        fetch = backend.embed_batch_async(b)
        if prev is not None:
            prev()
        prev = fetch
    prev()
    return n / (time.perf_counter() - t0)


def run(smoke: bool = False) -> list[Row]:
    import jax

    from repro.configs import get_config
    from repro.core.sharded_backend import ShardedEmbedderBackend, \
        _serve_devices
    from repro.models import embedder

    devs = _serve_devices()
    ndev = len(devs)
    cores = os.cpu_count() or 1
    cfg = get_config("bge-large-zh-v1.5").smoke()
    params = embedder.init_embedder(jax.random.PRNGKey(0), cfg)

    batch = 16 if smoke else 32
    n_batches = 6 if smoke else 16
    batches = _batches(n_batches, batch)

    def make(n: int, dtype: str, **kw) -> ShardedEmbedderBackend:
        be = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                    devices=devs[:n], dtype=dtype,
                                    min_seq_bucket=MIN_SEQ_BUCKET, **kw)
        be.prewarm([(batch, s) for s in (16, 32, 64)])
        return be

    rows: list[Row] = []

    # --- throughput scaling: same bf16 bucketed stream, 1 vs N devices ----
    be1 = make(1, "bf16", async_dispatch=True)
    beN = make(ndev, "bf16", donate=True, async_dispatch=True)
    warmN = beN.traces
    _serve_qps(be1, batches[:2])          # warm the timing path
    _serve_qps(beN, batches[:2])
    qps1 = max(_serve_qps(be1, batches) for _ in range(2))
    qpsN = max(_serve_qps(beN, batches) for _ in range(2))
    speedup = qpsN / qps1
    # forced host devices SHARE physical cores: a 2-core container cannot
    # show 8-way scaling no matter how well the mesh fans out, so the floor
    # follows min(devices, cores) and caps at the 3x acceptance bar (hit on
    # any >= 6-core host — e.g. a real 8-NPU deployment)
    usable = min(ndev, cores)
    required = min(3.0, 0.55 * usable)
    rows.append((f"sharded/throughput-{ndev}dev", 1e6 / qpsN,
                 f"{qpsN:.0f} q/s vs {qps1:.0f} q/s on 1 dev = "
                 f"{speedup:.2f}x (>= {required:.2f}x required; "
                 f"{ndev} devices over {cores} cores)"))
    if ndev == 1:
        rows.append(("sharded/scaling-skipped", 0.0,
                     "single device: run standalone or set XLA_FLAGS="
                     "--xla_force_host_platform_device_count=8"))

    # --- zero steady-state recompiles after prewarm ----------------------
    serving_retraces = beN.traces - warmN
    rows.append(("sharded/serving-recompiles", 0.0,
                 f"{serving_retraces} retraces over "
                 f"{2 * (len(batches) + 2)} served batches after prewarm "
                 f"(0 required)"))

    # --- bounded, reused host staging (a small ring per bucket) ----------
    staged = sum(len(r) for r in beN._staging.values())
    used = len(beN._staging)
    rows.append(("sharded/host-staging-arrays", 0.0,
                 f"{staged} staging pairs across {used} live (B, S) buckets "
                 f"(<= {beN._staging_slots}/bucket: steady state allocates "
                 f"nothing)"))

    # --- bf16 vs fp32-oracle parity (the served-vector contract) ---------
    oracle = make(1, "fp32")
    eq = _batches(1, 8, seed=7)[0]
    a = np.stack(oracle.embed_batch(eq))
    b = np.stack(beN.embed_batch(eq))
    cos = 1.0 - (a * b).sum(-1) / (np.linalg.norm(a, axis=-1)
                                   * np.linalg.norm(b, axis=-1))
    cos_max = float(cos.max())
    rows.append(("sharded/bf16-parity", 0.0,
                 f"max cosine distance vs fp32 oracle = {cos_max:.2e} "
                 f"(<= 1e-2 required; pool_norm epilogue stays fp32)"))

    # --- async dispatch: enqueue cost vs blocking fetch ------------------
    t0 = time.perf_counter()
    fetch = beN.embed_batch_async(batches[0])
    t_enq = time.perf_counter() - t0
    fetch()
    t_tot = time.perf_counter() - t0
    rows.append(("sharded/async-enqueue", t_enq * 1e6,
                 f"enqueue {t_enq*1e3:.2f}ms vs {t_tot*1e3:.2f}ms to "
                 f"results: worker overlaps the gap (donate="
                 f"{beN.donate})"))

    write_bench_json("sharded_embed", rows, metrics={
        "devices": ndev, "cores": cores, "qps_1dev": qps1,
        "qps_ndev": qpsN, "scaling_speedup": speedup,
        "scaling_bar": required, "serving_retraces": serving_retraces,
        "bf16_cosine_distance_max": cos_max,
        "staging_pairs": staged, "staging_buckets": used,
        "async_enqueue_s": t_enq, "async_total_s": t_tot,
    })

    # regression guards — benchmarks.run turns a raise into exit code 1
    assert speedup >= required, \
        f"sharded throughput {speedup:.2f}x < {required:.2f}x " \
        f"({ndev} devices, {cores} cores)"
    assert serving_retraces == 0, \
        f"steady-state serving retraced {serving_retraces}x after prewarm"
    assert staged <= max(used, 1) * beN._staging_slots, \
        f"staging arrays leak: {staged} pairs for {used} buckets"
    assert cos_max <= 1e-2, \
        f"bf16 embeddings diverged from fp32 oracle: {cos_max:.2e}"
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fast run (CI)")
    ap.add_argument("--devices", type=int, default=DEFAULT_DEVICES,
                    help="forced host device count (standalone runs only)")
    args = ap.parse_args()
    _force_devices(args.devices)
    emit(run(smoke=args.smoke))


if __name__ == "__main__":
    main()
