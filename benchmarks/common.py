"""Shared benchmark helpers: timing + the paper's device/depth recipes."""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.estimator import fine_tune_depth, stress_test_depth
from repro.core.simulator import PAPER_DEVICES, profile_fn_for

Row = Tuple[str, float, str]   # (name, us_per_call, derived)


def time_us(fn: Callable[[], object], repeats: int = 5) -> float:
    fn()  # warmup
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats * 1e6


def finetuned_depths(npu_key: str, cpu_key: str, slo: float,
                     seed: int = 0) -> Tuple[int, int]:
    """The paper's 'fine-tuned in collaboration' depths: exhaustive local
    search against the device's nominal latency curve (noise belongs to the
    estimator-evaluation benchmark, table3)."""
    import dataclasses
    npu = dataclasses.replace(PAPER_DEVICES[npu_key], noise_std=0.0)
    cpu = dataclasses.replace(PAPER_DEVICES[cpu_key], noise_std=0.0)
    pn = profile_fn_for(npu, seed=seed)
    pc = profile_fn_for(cpu, seed=seed)
    dn = fine_tune_depth(pn, slo, start=stress_test_depth(pn, slo) or 8,
                         radius=16)
    dc = fine_tune_depth(pc, slo, start=max(stress_test_depth(pc, slo, 2), 4),
                         radius=16)
    return dn, dc


def emit(rows: List[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def write_bench_json(name: str, rows: List[Row],
                     metrics: Optional[Dict[str, float]] = None,
                     path: Optional[str] = None) -> str:
    """Dump a microbench run as machine-readable ``BENCH_<name>.json``.

    ``metrics`` carries the headline scalars (throughput q/s, p95 seconds,
    parity cosine, speedups ...) so the perf trajectory can be diffed
    across PRs by tooling instead of scraped out of log text; ``rows`` are
    the human CSV rows verbatim.  CI archives these files per run.
    """
    payload = {
        "bench": name,
        "metrics": {k: (float(v) if isinstance(v, (int, float)) else v)
                    for k, v in (metrics or {}).items()},
        "rows": [{"name": n, "us_per_call": round(us, 3), "derived": d}
                 for n, us, d in rows],
    }
    out = path or f"BENCH_{name}.json"
    with open(out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return out
