"""Fig. 4: latency-vs-concurrency fitting curves for all four devices.

Derived = fitted (alpha, beta) + the paper's Fig.-4 betas and the two
alpha-ratio claims (V100/Xeon = 0.21, Atlas/Kunpeng = 0.12)."""
from __future__ import annotations

from benchmarks.common import Row, emit, time_us
from repro.core.estimator import fit_latency
from repro.core.simulator import PAPER_DEVICES, profile_fn_for

PAPER_BETA = {"tesla-v100/bge": 0.27, "xeon-e5-2690/bge": 0.32,
              "atlas-300i-duo/bge": 0.24, "kunpeng-920/bge": 0.85}

# profile within each device's operating range (<= its 2s-SLO concurrency),
# like the paper's Fig. 4 x-axes
FIT_RANGE = {"tesla-v100/bge": 96, "xeon-e5-2690/bge": 22,
             "atlas-300i-duo/bge": 172, "kunpeng-920/bge": 8}


def fit_device(dev_key: str, n_points: int = 12):
    d = PAPER_DEVICES[dev_key]
    p = profile_fn_for(d, seed=4)
    cmax = FIT_RANGE[dev_key]
    cs = sorted({max(1, round(1 + (cmax - 1) * i / (n_points - 1)))
                 for i in range(n_points)})
    return fit_latency(cs, [p(c) for c in cs])


def run() -> list[Row]:
    rows: list[Row] = []
    fits = {}
    for dev, p_beta in PAPER_BETA.items():
        us = time_us(lambda d=dev: fit_device(d))
        fit = fit_device(dev)
        fits[dev] = fit
        rows.append((f"fig4/{dev.split('/')[0]}", us,
                     f"alpha={fit.alpha:.4f} beta={fit.beta:.3f} "
                     f"r2={fit.r2:.3f} (paper beta: {p_beta})"))
    r1 = fits["tesla-v100/bge"].alpha / fits["xeon-e5-2690/bge"].alpha
    r2 = fits["atlas-300i-duo/bge"].alpha / fits["kunpeng-920/bge"].alpha
    rows.append(("fig4/alpha-ratio-v100-xeon", 0.0,
                 f"{r1:.2f} (paper: 0.21)"))
    rows.append(("fig4/alpha-ratio-atlas-kunpeng", 0.0,
                 f"{r2:.2f} (paper: 0.12)"))
    # paper claim: beta_CPU > beta_NPU in both pairs
    ok = (fits["xeon-e5-2690/bge"].beta > fits["tesla-v100/bge"].beta and
          fits["kunpeng-920/bge"].beta > fits["atlas-300i-duo/bge"].beta)
    rows.append(("fig4/beta-cpu-gt-npu", 0.0, f"holds={ok} (paper: holds)"))
    return rows


if __name__ == "__main__":
    emit(run())
