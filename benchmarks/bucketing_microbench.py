"""Shape bucketing A/B: padded-token waste % and recompiles, fixed vs bucketed.

A mixed-length arrival trace (the structure of the paper's Fig. 5 length
sweep) is formed into batches two ways through the SAME scheduling core:

* fixed    — plain FIFO ``pop_batch`` + ``JaxEmbedderBackend``: every batch
             pads to the global ``max_tokens`` window and every new raw
             batch size is a fresh jit trace;
* bucketed — ``length_bucket_fn`` batch formation + power-of-two
             ``BucketedEmbedderBackend``: batches pad to their (B, S)
             bucket, the compile cache is keyed by bucket and can be
             pre-warmed to zero runtime recompiles.

The rows double as regression guards (CI runs this in ``--smoke``): the run
RAISES — and ``benchmarks.run`` exits non-zero — unless bucketing cuts
padded-token waste by >= 2x, serves the trace with ZERO runtime recompiles
(the pre-warmed enumerable bucket grid vs the fixed path's on-demand
retraces, one per raw batch size), and serves identical embeddings
(atol 1e-5).
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row, emit, write_bench_json
from repro.core.bucketing import (BucketedEmbedderBackend, default_buckets,
                                  length_bucket_fn)
from repro.core.routing import NPU, Query, QueueManager, TierSpec

MAX_TOKENS = 128
MAX_BATCH = 16
MIN_SEQ_BUCKET = 16
MIN_BATCH_BUCKET = 1
# Fig.-5-shaped mix: mostly short queries (real RAG question traffic) with
# a tail near the paper's 75-token segmentation setting and beyond
LENGTHS = (12, 20, 28, 40, 75, 110)
WEIGHTS = (0.25, 0.2, 0.15, 0.15, 0.15, 0.1)


def mixed_trace(n: int = 160, seed: int = 0) -> List[int]:
    rng = np.random.default_rng(seed)
    return [int(x) for x in rng.choice(LENGTHS, size=n, p=WEIGHTS)]


def form_batches(lengths: List[int], bucket_fn=None,
                 seed: int = 1) -> List[List[Query]]:
    """Arrival/drain dynamics through the shared core: bursts of varying
    size land in the queue, the worker drains one batch between bursts —
    raw batch sizes vary exactly as they do in a live engine."""
    qm = QueueManager([TierSpec(NPU, 10 ** 6, max_batch=MAX_BATCH,
                                bucket_fn=bucket_fn)])
    rng = np.random.default_rng(seed)
    batches: List[List[Query]] = []

    def drain_one() -> bool:
        batch = qm.pop_batch(NPU)
        if batch:
            qm.queues[NPU].finish(len(batch))
            batches.append(batch)
        return bool(batch)

    i = 0
    qid = 0
    while i < len(lengths):
        for ln in lengths[i:i + int(rng.integers(1, MAX_BATCH + 1))]:
            qid += 1
            qm.dispatch(Query(qid=qid, length=ln))
            i += 1
        drain_one()
    while drain_one():
        pass
    return batches


def serve(backend, batches: List[List[Query]]) -> float:
    t0 = time.perf_counter()
    for b in batches:
        backend.embed_batch(b)
    return time.perf_counter() - t0


def run() -> list[Row]:
    import jax

    from repro.configs import get_config
    from repro.core.windve import JaxEmbedderBackend
    from repro.models import embedder

    cfg = get_config("bge-large-zh-v1.5").smoke()
    params = embedder.init_embedder(jax.random.PRNGKey(0), cfg)
    fixed = JaxEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS)
    bucketed = BucketedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                       min_seq_bucket=MIN_SEQ_BUCKET,
                                       min_batch_bucket=MIN_BATCH_BUCKET)

    lengths = mixed_trace()
    fifo_batches = form_batches(lengths, bucket_fn=None)
    bucket_batches = form_batches(
        lengths, bucket_fn=length_bucket_fn(MIN_SEQ_BUCKET, MAX_TOKENS))

    # startup: the pow2 bucket grid is small and ENUMERABLE, so the
    # bucketed backend compiles it all eagerly; the fixed path has no
    # equivalent — its compile cache fills (and stalls) on demand, one raw
    # batch size at a time, for the whole life of the process
    grid = default_buckets(MAX_BATCH, MAX_TOKENS, MIN_SEQ_BUCKET,
                           MIN_BATCH_BUCKET)
    t0 = time.perf_counter()
    prewarmed = bucketed.prewarm(grid)
    t_warmup = time.perf_counter() - t0
    warm_traces = bucketed.traces

    serve(fixed, fifo_batches)          # cold pass: counts retraces + waste
    serve(bucketed, bucket_batches)
    fixed_retraces = fixed.traces
    bucketed_retraces = bucketed.traces - warm_traces
    t_fixed = serve(fixed, fifo_batches)      # warm pass: service time only
    t_buck = serve(bucketed, bucket_batches)

    n = len(lengths)
    rows: list[Row] = []
    reduction = fixed.padded_waste / max(bucketed.padded_waste, 1e-9)
    rows.append(("bucketing/padded-waste", 0.0,
                 f"fixed={fixed.padded_waste:.1%} "
                 f"bucketed={bucketed.padded_waste:.1%} "
                 f"reduction={reduction:.1f}x (>=2x required)"))
    rows.append(("bucketing/prewarm", t_warmup / max(prewarmed, 1) * 1e6,
                 f"compiled {prewarmed} bucket shapes eagerly at startup"))
    rows.append(("bucketing/serving-recompiles", 0.0,
                 f"fixed={fixed_retraces} bucketed={bucketed_retraces} "
                 f"on {len(fifo_batches)}/{len(bucket_batches)} batches "
                 f"(bucketed must be fewer; 0 == no compile stalls)"))
    rows.append(("bucketing/serve-warm-fixed", t_fixed / n * 1e6,
                 f"{len(fifo_batches)} FIFO batches @ S={MAX_TOKENS}"))
    rows.append(("bucketing/serve-warm-bucketed", t_buck / n * 1e6,
                 f"{len(bucket_batches)} bucketed batches, "
                 f"speedup={t_fixed / max(t_buck, 1e-9):.2f}x"))

    # numerical equality: same queries, bucket-padded vs max-padded
    eq_queries = [Query(qid=10 ** 6 + i, length=ln)
                  for i, ln in enumerate(LENGTHS)]
    a = np.stack(fixed.embed_batch(eq_queries))
    b = np.stack(bucketed.embed_batch(eq_queries))
    diff = float(np.abs(a - b).max())
    rows.append(("bucketing/equality", 0.0,
                 f"max|bucketed-fixed|={diff:.2e} (<=1e-5 required)"))

    write_bench_json("bucketing", rows, metrics={
        "padded_waste_fixed": fixed.padded_waste,
        "padded_waste_bucketed": bucketed.padded_waste,
        "waste_reduction": reduction,
        "serving_retraces_fixed": fixed_retraces,
        "serving_retraces_bucketed": bucketed_retraces,
        "warm_qps_fixed": n / max(t_fixed, 1e-9),
        "warm_qps_bucketed": n / max(t_buck, 1e-9),
        "warm_speedup": t_fixed / max(t_buck, 1e-9),
        "equality_max_abs_diff": diff,
    })

    # regression guards — benchmarks.run turns a raise into exit code 1
    assert reduction >= 2.0, \
        f"padded-waste reduction {reduction:.2f}x < 2x"
    assert prewarmed <= len(grid), "bucket grid must stay enumerable"
    assert bucketed_retraces == 0 < fixed_retraces, \
        f"bucketed serving must not retrace: {bucketed_retraces} " \
        f"vs fixed {fixed_retraces}"
    assert diff <= 1e-5, f"bucketed embeddings diverged: {diff}"
    return rows


if __name__ == "__main__":
    emit(run())
