"""Real-engine microbenchmarks on this host: dispatch overhead of the queue
manager (Algorithm 1) and the actual JAX embedder latency-vs-concurrency
curve (the paper's Eq. 12, measured for real on this CPU).

``--devices N`` (standalone runs; the shared harness convention with
``sharded_embed_microbench``) forces an N-device host mesh before importing
jax and adds the device-sharded backend's Eq. 12 curve next to the
single-device one.
"""
from __future__ import annotations

import argparse
import os
import sys

from benchmarks.common import Row, emit, time_us, write_bench_json


def run(devices: int = 1) -> list[Row]:
    import jax

    from repro.configs import get_config
    from repro.core.estimator import fit_latency
    from repro.core.routing import (CPU, NPU, CascadePolicy,
                                    LeastLoadedPolicy, LengthAwarePolicy,
                                    PredictivePolicy, Query, QueueManager,
                                    TierSpec)
    from repro.core.simulator import PAPER_DEVICES
    from repro.core.windve import JaxEmbedderBackend
    from repro.models import embedder

    rows: list[Row] = []

    # per-policy dispatch cost through the shared scheduling core (the
    # predictive policy prices a calibrated curve per candidate tier, so
    # its per-query cost is the one to watch as tiers multiply)
    for policy in (CascadePolicy(), LengthAwarePolicy(), LeastLoadedPolicy(),
                   PredictivePolicy(
                       fits={NPU: PAPER_DEVICES["tesla-v100/bge"],
                             CPU: PAPER_DEVICES["xeon-e5-2690/bge"]})):
        qm = QueueManager([TierSpec(NPU, 10 ** 6), TierSpec(CPU, 10 ** 6)],
                          policy=policy)
        i = [0]

        def dispatch():
            i[0] += 1
            qm.dispatch(Query(qid=i[0]))

        rows.append((f"engine/dispatch-{policy.name}",
                     time_us(dispatch, repeats=2000),
                     "per-query routing cost (cascade == Algorithm 1)"))

    metrics = {}

    # real embedder: measured t(C) linearity on this host CPU
    cfg = get_config("bge-large-zh-v1.5").smoke()
    params = embedder.init_embedder(jax.random.PRNGKey(0), cfg)
    be = JaxEmbedderBackend(cfg, params, max_tokens=32)

    def batch_lat(c: int) -> float:
        batch = [Query(qid=j, length=24) for j in range(c)]
        import time as _t
        t0 = _t.monotonic()
        be.embed_batch(batch)
        return _t.monotonic() - t0

    # JIT warm-up: compile every batch shape ONCE before timing, otherwise
    # the c's first sample is trace+compile time and the Eq. 12 fit is junk
    cs = [1, 2, 4, 8, 16]
    for c in cs:
        batch_lat(c)
    lats = [min(batch_lat(c) for _ in range(3)) for c in cs]
    fit = fit_latency(cs, lats)
    rows.append(("engine/jax-embedder-batch16", lats[-1] / 16 * 1e6,
                 f"measured Eq.12 fit: alpha={fit.alpha*1e3:.2f}ms "
                 f"beta={fit.beta*1e3:.2f}ms r2={fit.r2:.3f}"))
    metrics.update(embed_qps_batch16=16.0 / lats[-1],
                   eq12_alpha_s=fit.alpha, eq12_beta_s=fit.beta,
                   eq12_r2=fit.r2)

    # sharded fan-out: the same curve through the device-sharded backend
    # (batch over the mesh's data axis); on one device this IS the bucketed
    # single-device path, so the row only appears with a real fan-out
    ndev = min(max(1, devices), len(jax.devices()))
    if ndev > 1:
        from repro.core.sharded_backend import ShardedEmbedderBackend

        sbe = ShardedEmbedderBackend(cfg, params, max_tokens=32,
                                     devices=jax.devices()[:ndev],
                                     dtype="bf16", async_dispatch=True)

        def sharded_lat(c: int) -> float:
            batch = [Query(qid=j, length=24) for j in range(c)]
            import time as _t
            t0 = _t.monotonic()
            sbe.embed_batch(batch)
            return _t.monotonic() - t0

        # probe at multiples of the device count: below it every batch pads
        # to one identical ndev-row shape (flat fit), and keeping >= 2
        # points is what fit_latency requires
        scs = [ndev * c for c in (1, 2, 4, 8)]
        for c in scs:
            sharded_lat(c)
        slats = [min(sharded_lat(c) for _ in range(3)) for c in scs]
        sfit = fit_latency(scs, slats)
        rows.append((f"engine/sharded-embedder-{ndev}dev-batch{scs[-1]}",
                     slats[-1] / scs[-1] * 1e6,
                     f"measured Eq.12 fit: alpha={sfit.alpha*1e3:.2f}ms "
                     f"beta={sfit.beta*1e3:.2f}ms r2={sfit.r2:.3f}"))
        metrics.update(sharded_devices=ndev,
                       sharded_qps=scs[-1] / slats[-1],
                       sharded_eq12_alpha_s=sfit.alpha,
                       sharded_eq12_beta_s=sfit.beta)
    write_bench_json("engine", rows, metrics=metrics)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=1,
                    help="forced host device count (standalone runs only)")
    args = ap.parse_args()
    if args.devices > 1 and "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.devices}").strip()
    emit(run(devices=args.devices))


if __name__ == "__main__":
    main()
