"""Real-engine microbenchmarks on this host: dispatch overhead of the queue
manager (Algorithm 1) and the actual JAX embedder latency-vs-concurrency
curve (the paper's Eq. 12, measured for real on this CPU)."""
from __future__ import annotations

import jax

from benchmarks.common import Row, emit, time_us
from repro.configs import get_config
from repro.core.estimator import fit_latency
from repro.core.routing import (CPU, NPU, CascadePolicy, LeastLoadedPolicy,
                                LengthAwarePolicy, Query, QueueManager,
                                TierSpec)
from repro.core.windve import JaxEmbedderBackend
from repro.models import embedder


def run() -> list[Row]:
    rows: list[Row] = []

    # per-policy dispatch cost through the shared scheduling core
    for policy in (CascadePolicy(), LengthAwarePolicy(), LeastLoadedPolicy()):
        qm = QueueManager([TierSpec(NPU, 10 ** 6), TierSpec(CPU, 10 ** 6)],
                          policy=policy)
        i = [0]

        def dispatch():
            i[0] += 1
            qm.dispatch(Query(qid=i[0]))

        rows.append((f"engine/dispatch-{policy.name}",
                     time_us(dispatch, repeats=2000),
                     "per-query routing cost (cascade == Algorithm 1)"))

    # real embedder: measured t(C) linearity on this host CPU
    cfg = get_config("bge-large-zh-v1.5").smoke()
    params = embedder.init_embedder(jax.random.PRNGKey(0), cfg)
    be = JaxEmbedderBackend(cfg, params, max_tokens=32)

    def batch_lat(c: int) -> float:
        batch = [Query(qid=j, length=24) for j in range(c)]
        import time as _t
        t0 = _t.monotonic()
        be.embed_batch(batch)
        return _t.monotonic() - t0

    # JIT warm-up: compile every batch shape ONCE before timing, otherwise
    # the c's first sample is trace+compile time and the Eq. 12 fit is junk
    cs = [1, 2, 4, 8, 16]
    for c in cs:
        batch_lat(c)
    lats = [min(batch_lat(c) for _ in range(3)) for c in cs]
    fit = fit_latency(cs, lats)
    rows.append(("engine/jax-embedder-batch16", lats[-1] / 16 * 1e6,
                 f"measured Eq.12 fit: alpha={fit.alpha*1e3:.2f}ms "
                 f"beta={fit.beta*1e3:.2f}ms r2={fit.r2:.3f}"))
    return rows


if __name__ == "__main__":
    emit(run())
