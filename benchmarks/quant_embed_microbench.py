"""Quantized CPU-tier serving A/B: throughput, parity, recompiles and
resident-weight footprint for ``embed_dtype=int8`` and ``int8_w8a8``.

The SAME bucketed batch stream is warm-served three ways at IDENTICAL
(B, S) bucket shapes through the real serving backend
(``repro.core.sharded_backend``, 1-device mesh == the CPU-tier path):

* fp32 — the precision-oracle baseline (fp32-resident weights, fp32 trunk);
* int8 — weight-only quantized projections (int8 weights + fp32
         per-output-channel scales) through the fused quant matmul
         (``repro.kernels.quant_matmul``), fp32 activations, fp32
         ``pool_norm`` epilogue;
* int8_w8a8 — int8 weights AND per-batch dynamically quantized int8
         activations contracted with int32 accumulation, one fp32 dequant
         in the tile epilogue, fp32 ``pool_norm`` epilogue.

Self-asserting regression guards (CI runs ``--smoke``; a raise exits
non-zero):

* **throughput** — the >= 1.5x acceptance bar ARMS when a GEMM-level host
  probe shows the int8 formulation actually beating f32 by >= 1.6x (TPU
  MXU int8 tiles, VNNI-routed builds); on hosts whose XLA has no int8 GEMM
  routing (this CPU container lowers the int8 contraction through the same
  f32 units, measured ~0.9x at trunk shapes) the guard instead requires
  the serving path to retain >= 80% of the probed GEMM-level ratio — so a
  regression in the quantized path itself still fails the build
  everywhere.  The probe, the measured ratio and the applied bar are all
  printed (PR 3's core-aware-bar convention: no silent environment caps).
* **parity** — int8 embeddings >= 0.99 and int8_w8a8 >= 0.98 cosine vs the
  fp32 oracle on BOTH pooling modes (cls / mean) — the served-vector
  contract.
* **zero steady-state recompiles** after prewarm, and both quantized
  streams must execute the SAME bucket set as the fp32 stream (equal
  shapes, equal compile-cache behaviour).
* **footprint** — resident serving weights shrink >= 2.5x (projections are
  1 byte/element; the embedding table, norms and scales stay float), and
  int8_w8a8 is byte-identical to int8 at rest (activation quantization is
  a trace-time choice, not a second weight copy).

Also emits ``BENCH_quant_embed.json`` (throughput, p95, parity, probes,
``w8a8_slope_scale`` — the measured quantized/fp32 per-query service-time
ratio that ``repro.core.estimator.quantized_fit`` consumes to re-price
Eq. 12 depth for the quantized tier) so the perf trajectory is tracked
across PRs.
"""
from __future__ import annotations

import argparse
import time
from typing import List

import numpy as np

from benchmarks.common import Row, emit, write_bench_json

MAX_TOKENS = 64
MIN_SEQ_BUCKET = 16
# Fig.-5-shaped mix inside the window so batches stay dense
LENGTHS = (12, 20, 28, 40, 55, 60)
WEIGHTS = (0.25, 0.2, 0.15, 0.15, 0.15, 0.1)


def _batches(n_batches: int, batch: int, seed: int = 0) -> List[List]:
    from repro.core.routing import Query

    rng = np.random.default_rng(seed)
    out, qid = [], 0
    for _ in range(n_batches):
        lens = rng.choice(LENGTHS, size=batch, p=WEIGHTS)
        out.append([Query(qid=(qid := qid + 1), length=int(ln))
                    for ln in lens])
    return out


def _serve(backend, batches: List[List]):
    """Double-buffered warm-serve pass (the engine worker's discipline).
    Returns (qps, [per-batch wall seconds])."""
    n = sum(len(b) for b in batches)
    lats: List[float] = []
    t0 = time.perf_counter()
    prev = None
    for b in batches:
        tb = time.perf_counter()
        fetch = backend.embed_batch_async(b)
        if prev is not None:
            prev()
        prev = fetch
        lats.append(time.perf_counter() - tb)
    prev()
    return n / (time.perf_counter() - t0), lats


def _gemm_probe(jnp, M: int, K: int, N: int, repeats: int = 10) -> float:
    """Host physics: t(f32 matmul) / t(fused int8 quant matmul) at trunk
    shapes — the ratio the serving path can at best approach."""
    import jax

    from repro.kernels.quant_matmul import quant_matmul
    from repro.models.quantize import quantize_dense

    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (M, K), jnp.float32)
    w = jax.random.normal(kw, (K, N), jnp.float32)
    w8, scale = quantize_dense(w)
    f32 = jax.jit(lambda a, b: a @ b)

    def best(fn, *args) -> float:
        jax.block_until_ready(fn(*args))
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts.append(time.perf_counter() - t0)
        return min(ts)

    return best(f32, x, w) / best(quant_matmul, x, w8, scale)


def _gemm_probe_w8a8(jnp, M: int, K: int, N: int, repeats: int = 10,
                     ) -> float:
    """Host physics for the W8A8 formulation: t(f32 matmul) / t(dynamic
    activation quant + int8 x int8 int32-accumulation matmul + dequant)."""
    import jax

    from repro.kernels.quant_matmul import quant_matmul_w8a8
    from repro.models.quantize import quantize_dense

    kx, kw = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(kx, (M, K), jnp.float32)
    w = jax.random.normal(kw, (K, N), jnp.float32)
    w8, scale = quantize_dense(w)
    f32 = jax.jit(lambda a, b: a @ b)

    def best(fn, *args) -> float:
        jax.block_until_ready(fn(*args))
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts.append(time.perf_counter() - t0)
        return min(ts)

    return best(f32, x, w) / best(quant_matmul_w8a8, x, w8, scale)


def run(smoke: bool = False) -> list[Row]:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.sharded_backend import ShardedEmbedderBackend

    # mid-size trunk: projections dominate service time (the regime the
    # quantization targets), still fast enough for CI smoke
    cfg = get_config("bge-large-zh-v1.5").smoke().replace(
        d_model=256, num_heads=4, num_kv_heads=4, head_dim=64, d_ff=1024,
        num_layers=2 if smoke else 4)
    from repro.models import embedder
    params = embedder.init_embedder(jax.random.PRNGKey(0), cfg)

    batch = 8 if smoke else 16
    n_batches = 6 if smoke else 16
    batches = _batches(n_batches, batch)
    buckets = [(batch, s) for s in (16, 32, 64)]

    def make(dtype: str) -> ShardedEmbedderBackend:
        be = ShardedEmbedderBackend(
            cfg, params, max_tokens=MAX_TOKENS,
            devices=jax.local_devices()[:1], dtype=dtype,
            min_seq_bucket=MIN_SEQ_BUCKET, async_dispatch=True)
        be.prewarm(buckets)
        return be

    rows: list[Row] = []
    f32_be = make("fp32")
    i8_be = make("int8")
    aa_be = make("int8_w8a8")
    warm_f32, warm_i8, warm_aa = f32_be.traces, i8_be.traces, aa_be.traces

    # --- host GEMM physics probes (arm the acceptance bars) --------------
    probe = _gemm_probe(jnp, batch * 32, cfg.d_model, cfg.d_ff)
    probe_aa = _gemm_probe_w8a8(jnp, batch * 32, cfg.d_model, cfg.d_ff)
    hw_int8 = probe >= 1.6
    hw_w8a8 = probe_aa >= 1.6
    # the serving path must retain >= 80% of whatever the host's GEMM-level
    # quantized:f32 physics allows; once the hardware win is there, the
    # full 1.5x acceptance bar applies
    required = 1.5 if hw_int8 else 0.8 * probe
    required_aa = 1.5 if hw_w8a8 else 0.8 * probe_aa

    # --- warm-serve throughput at identical bucket shapes ----------------
    _serve(f32_be, batches[:2])           # warm the timing path
    _serve(i8_be, batches[:2])
    _serve(aa_be, batches[:2])
    qps_f32 = max(_serve(f32_be, batches)[0] for _ in range(2))
    qps_i8, lats = 0.0, []
    for _ in range(2):
        q, ls = _serve(i8_be, batches)
        if q > qps_i8:
            qps_i8, lats = q, ls
    qps_aa, lats_aa = 0.0, []
    for _ in range(2):
        q, ls = _serve(aa_be, batches)
        if q > qps_aa:
            qps_aa, lats_aa = q, ls
    ratio = qps_i8 / qps_f32
    ratio_aa = qps_aa / qps_f32
    # per-query service time ratio: the beta_s slope transform Eq. 12's
    # quantized_fit consumes (< 1 when the W8A8 formulation is faster)
    slope_scale = qps_f32 / qps_aa
    p95 = float(np.percentile(lats, 95))
    p95_aa = float(np.percentile(lats_aa, 95))
    note = (" — int8 hardware win" if hw_int8 else
            ": no int8 GEMM routing on this host, 1.5x bar arms at "
            ">=1.6x probe")
    note_aa = (" — W8A8 hardware win" if hw_w8a8 else
               ": no int8 GEMM routing on this host, 1.5x bar arms at "
               ">=1.6x probe")
    rows.append(("quant/throughput", 1e6 / qps_i8,
                 f"int8 {qps_i8:.0f} q/s vs fp32 {qps_f32:.0f} q/s = "
                 f"{ratio:.2f}x (bar {required:.2f}x; host int8:f32 GEMM "
                 f"probe {probe:.2f}x{note})"))
    rows.append(("quant/throughput-w8a8", 1e6 / qps_aa,
                 f"w8a8 {qps_aa:.0f} q/s vs fp32 {qps_f32:.0f} q/s = "
                 f"{ratio_aa:.2f}x (bar {required_aa:.2f}x; host w8a8:f32 "
                 f"GEMM probe {probe_aa:.2f}x{note_aa})"))
    rows.append(("quant/batch-p95", p95 * 1e6,
                 f"int8 warm-serve per-batch p95 = {p95*1e3:.1f}ms "
                 f"over {len(lats)} batches"))
    rows.append(("quant/batch-p95-w8a8", p95_aa * 1e6,
                 f"w8a8 warm-serve per-batch p95 = {p95_aa*1e3:.1f}ms "
                 f"over {len(lats_aa)} batches"))
    rows.append(("quant/w8a8-slope-scale", 0.0,
                 f"measured W8A8/fp32 per-query service-time ratio "
                 f"{slope_scale:.3f} (feeds estimator.quantized_fit to "
                 f"re-price Eq. 12 depth for the quantized tier)"))

    # --- identical bucket shapes + zero steady-state recompiles ----------
    retraces = ((f32_be.traces - warm_f32) + (i8_be.traces - warm_i8)
                + (aa_be.traces - warm_aa))
    served = 3 * (2 + 2 * len(batches))   # per backend: 2 warm-up + 2 passes
    buckets_equal = (sorted(i8_be.warm_buckets) == sorted(f32_be.warm_buckets)
                     == sorted(aa_be.warm_buckets))
    rows.append(("quant/serving-recompiles", 0.0,
                 f"{retraces} retraces over {served} served "
                 f"batches after prewarm (0 required); bucket sets equal "
                 f"across fp32/int8/w8a8: {buckets_equal}"))

    # --- quantized vs fp32-oracle cosine parity, BOTH pooling modes ------
    eq = _batches(1, 8, seed=7)[0]
    worst: dict = {"int8": {}, "int8_w8a8": {}}
    for pool in ("cls", "mean"):
        pcfg = cfg.replace(pool=pool)
        oracle = ShardedEmbedderBackend(pcfg, params, max_tokens=MAX_TOKENS,
                                        devices=jax.local_devices()[:1],
                                        dtype="fp32",
                                        min_seq_bucket=MIN_SEQ_BUCKET)
        a = np.stack(oracle.embed_batch(eq))
        for dtype in ("int8", "int8_w8a8"):
            quant = ShardedEmbedderBackend(pcfg, params,
                                           max_tokens=MAX_TOKENS,
                                           devices=jax.local_devices()[:1],
                                           dtype=dtype,
                                           min_seq_bucket=MIN_SEQ_BUCKET)
            b = np.stack(quant.embed_batch(eq))
            worst[dtype][pool] = float(((a * b).sum(-1)
                                        / (np.linalg.norm(a, axis=-1)
                                           * np.linalg.norm(b, axis=-1))
                                        ).min())
    rows.append(("quant/parity", 0.0,
                 f"min cosine vs fp32 oracle: "
                 f"cls={worst['int8']['cls']:.5f} "
                 f"mean={worst['int8']['mean']:.5f} (>= 0.99 required; "
                 f"served vectors stay fp32 unit vectors)"))
    rows.append(("quant/parity-w8a8", 0.0,
                 f"min cosine vs fp32 oracle: "
                 f"cls={worst['int8_w8a8']['cls']:.5f} "
                 f"mean={worst['int8_w8a8']['mean']:.5f} (>= 0.98 "
                 f"required; served vectors stay fp32 unit vectors)"))

    # --- full-mesh W8A8 composition (forced-8-device CI leg) -------------
    # CI forces an 8-device host mesh (XLA_FLAGS); the W8A8 path must serve
    # identically on the full data-sharded mesh as on the 1-device CPU tier
    mesh_devs = len(jax.local_devices())
    if mesh_devs >= 2:
        mesh_be = ShardedEmbedderBackend(
            cfg, params, max_tokens=MAX_TOKENS, dtype="int8_w8a8",
            min_seq_bucket=MIN_SEQ_BUCKET, async_dispatch=True)
        mq = _batches(1, 8, seed=11)[0]
        one = np.stack(aa_be.embed_batch(mq))
        full = np.stack(mesh_be.embed_batch(mq))
        mesh_err = float(np.abs(one - full).max())
        assert mesh_err <= 1e-5, \
            f"W8A8 on the {mesh_be.device_count}-device mesh diverged " \
            f"from the 1-device tier by {mesh_err:.2e}"
        rows.append(("quant/w8a8-mesh-parity", 0.0,
                     f"{mesh_be.device_count}-device W8A8 mesh matches the "
                     f"1-device tier (max abs err {mesh_err:.1e})"))
    else:
        mesh_err = None
        rows.append(("quant/w8a8-mesh-parity", 0.0,
                     "skipped: single-device host (CI forces 8 via "
                     "XLA_FLAGS)"))

    # --- resident-weight footprint ---------------------------------------
    shrink = f32_be.params_nbytes / i8_be.params_nbytes
    rows.append(("quant/resident-weights", 0.0,
                 f"fp32 {f32_be.params_nbytes/1e6:.1f}MB -> int8 "
                 f"{i8_be.params_nbytes/1e6:.1f}MB = {shrink:.1f}x smaller "
                 f"(>= 2.5x required; embed table/norms/scales stay float; "
                 f"w8a8 resident bytes == int8: "
                 f"{aa_be.params_nbytes == i8_be.params_nbytes})"))

    write_bench_json("quant_embed", rows, metrics={
        "qps_int8": qps_i8, "qps_fp32": qps_f32, "qps_w8a8": qps_aa,
        "throughput_ratio": ratio, "throughput_ratio_w8a8": ratio_aa,
        "throughput_bar": required, "throughput_bar_w8a8": required_aa,
        "gemm_probe_ratio": probe, "gemm_probe_w8a8": probe_aa,
        "w8a8_slope_scale": slope_scale,
        "batch_p95_s": p95, "batch_p95_w8a8_s": p95_aa,
        "cosine_cls": worst["int8"]["cls"],
        "cosine_mean": worst["int8"]["mean"],
        "cosine_w8a8_cls": worst["int8_w8a8"]["cls"],
        "cosine_w8a8_mean": worst["int8_w8a8"]["mean"],
        "serving_retraces": retraces, "weight_shrink": shrink,
        "w8a8_mesh_devices": mesh_devs,
        "w8a8_mesh_max_abs_err": mesh_err,
    })

    # regression guards — benchmarks.run turns a raise into exit code 1
    assert ratio >= required, \
        f"int8 warm-serve throughput {ratio:.2f}x < {required:.2f}x bar " \
        f"(host GEMM probe {probe:.2f}x)"
    assert ratio_aa >= required_aa, \
        f"w8a8 warm-serve throughput {ratio_aa:.2f}x < {required_aa:.2f}x " \
        f"bar (host GEMM probe {probe_aa:.2f}x)"
    assert retraces == 0, \
        f"steady-state serving retraced {retraces}x after prewarm"
    assert buckets_equal, \
        "quantized streams executed different bucket shapes than fp32"
    for pool, cos in worst["int8"].items():
        assert cos >= 0.99, \
            f"int8 embeddings diverged from fp32 oracle ({pool}): {cos:.5f}"
    for pool, cos in worst["int8_w8a8"].items():
        assert cos >= 0.98, \
            f"w8a8 embeddings diverged from fp32 oracle ({pool}): {cos:.5f}"
    assert shrink >= 2.5, \
        f"resident weights shrank only {shrink:.2f}x (>= 2.5x required)"
    assert aa_be.params_nbytes == i8_be.params_nbytes, \
        "w8a8 must reuse the int8 resident tree, not carry a second copy"
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small fast run (CI)")
    args = ap.parse_args()
    emit(run(smoke=args.smoke))


if __name__ == "__main__":
    main()
