"""Property-based cross-driver parity: random topologies, both drivers.

``tests/test_parity.py`` spot-checks engine-vs-DES agreement on hand-picked
configs; this suite generates random (tier count, depths, bucket_fn, policy,
devices, max_batch, query lengths) configurations and asserts the two
drivers of the shared scheduling core agree on

* routed counts per tier (``Telemetry.dispatched``),
* rejection (BUSY) counts,
* per-tier batch-size distributions (the batches each driver actually
  formed through ``QueueManager.pop_batch``).

Determinism notes: the threaded engine's dispatch sequence matches the DES
only if the whole burst is submitted before any worker acts.  Submission is
pure Python (no blocking calls release the GIL), so raising
``sys.setswitchinterval`` for the ~ms submission loop keeps the main thread
scheduled until every query is dispatched — workers then drain a static
backlog exactly like the DES does after its same-instant arrival events.
Runs under real ``hypothesis`` when installed, else the deterministic
seeded stub in ``tests/_hypothesis_stub.py``.
"""
import dataclasses
import sys
from collections import defaultdict

from hypothesis import given, settings
from hypothesis import strategies as st

import numpy as np

from repro.core.bucketing import length_bucket_fn
from repro.core.cache import CACHE, cache_tier
from repro.core.routing import (CascadePolicy, LeastLoadedPolicy,
                                LengthAwarePolicy, PredictivePolicy,
                                RoundRobinPolicy, TierSpec, replicate)
from repro.core.simulator import (DeviceModel, ServingSimulator,
                                  sharded_model)
from repro.core.windve import ModeledBackend, WindVE

# flat (b = a = 0) noise-free service curves: latency is beta per execution
# chunk, slow enough that a burst outlives its submission window, fast
# enough to keep 10 random examples quick.  Tier i gets a distinct beta so
# predictive/least-loaded orderings are non-trivial.
TIER_BETAS = (0.12, 0.18, 0.24)
BUCKET = length_bucket_fn(min_bucket=32, max_bucket=128)


class RecordingModel:
    """Wraps any DES latency model and records each serviced batch size."""

    def __init__(self, inner):
        self.inner = inner
        self.batches = []

    def __getattr__(self, name):            # name/noise_std/ref_length/...
        return getattr(self.inner, name)

    def latency(self, concurrency, length=75, rng=None):
        self.batches.append(int(concurrency))
        return self.inner.latency(concurrency, length, rng)


def make_policy(kind, models):
    if kind == "cascade":
        return CascadePolicy()
    if kind == "length-aware":
        return LengthAwarePolicy(long_threshold=200)
    if kind == "least-loaded":
        return LeastLoadedPolicy()
    if kind == "predictive":
        # the DES device models double as the calibrated fits — identical
        # pricing in both drivers by construction
        return PredictivePolicy(fits=dict(models))
    raise ValueError(kind)


def base_models(n_tiers, devices):
    out = {}
    for i in range(n_tiers):
        base = DeviceModel(f"T{i}", beta=TIER_BETAS[i], b=0.0, a=0.0)
        out[f"T{i}"] = sharded_model(base, devices if i == 0 else 1)
    return out


def run_des(n_tiers, depths, models, policy_kind, bucketed, max_batch,
            lengths):
    recorders = {name: RecordingModel(m) for name, m in models.items()}
    tiers = [TierSpec(f"T{i}", depths[i], model=recorders[f"T{i}"],
                      max_batch=max_batch,
                      bucket_fn=BUCKET if bucketed else None)
             for i in range(n_tiers)]
    sim = ServingSimulator(tiers=tiers, slo_s=100.0,
                           policy=make_policy(policy_kind, models))
    res = sim.run([(0.0, ln) for ln in lengths])
    batches = {name: sorted(r.batches) for name, r in recorders.items()}
    return dict(res.dispatched), res.rejected, res.n_completed, batches


def run_engine(n_tiers, depths, models, policy_kind, bucketed, max_batch,
               lengths):
    tiers = [TierSpec(f"T{i}", depths[i],
                      backend=ModeledBackend(
                          DeviceModel(f"T{i}", beta=TIER_BETAS[i], b=0.0,
                                      a=0.0),
                          embed_dim=4,
                          devices=getattr(models[f"T{i}"], "devices", 1)),
                      max_batch=max_batch,
                      bucket_fn=BUCKET if bucketed else None)
             for i in range(n_tiers)]
    ve = WindVE(tiers=tiers, policy=make_policy(policy_kind, models))
    seen = defaultdict(list)
    ve.add_batch_hook(lambda tier, batch, lat: seen[tier].append(len(batch)))
    old = sys.getswitchinterval()
    try:
        # hold the GIL across the burst: no worker may form a batch until
        # every query of the burst has been dispatched (see module docs)
        sys.setswitchinterval(5.0)
        try:
            futs = [ve.submit(length=ln) for ln in lengths]
        finally:
            sys.setswitchinterval(old)
        done = [f.result(timeout=60) for f in futs if f is not None]
        disp, rej = dict(ve.stats.dispatched), ve.stats.rejected
    finally:
        sys.setswitchinterval(old)
        ve.shutdown()
    return disp, rej, len(done), {t: sorted(b) for t, b in seen.items()}


CONFIG = st.tuples(
    st.integers(min_value=1, max_value=3),                  # tier count
    st.tuples(st.integers(min_value=0, max_value=8),        # per-tier depths
              st.integers(min_value=1, max_value=8),        # (tier 0 may be
              st.integers(min_value=1, max_value=6)),       #  full: depth 0)
    st.booleans(),                                          # bucket_fn on?
    st.sampled_from(["cascade", "length-aware", "least-loaded",
                     "predictive"]),
    st.sampled_from([1, 2, 4]),                             # tier-0 devices
    st.sampled_from([None, 2, 4]),                          # max_batch cap
    st.lists(st.integers(min_value=5, max_value=400),       # query lengths
             min_size=1, max_size=18),
)


@settings(max_examples=10, deadline=None)
@given(CONFIG)
def test_engine_and_des_agree_on_random_configs(cfg):
    n_tiers, all_depths, bucketed, policy_kind, devices, max_batch, \
        lengths = cfg
    depths = list(all_depths[:n_tiers])
    if all(d == 0 for d in depths):
        depths[-1] = 1          # at least one admitting tier keeps the
        #                         engine run bounded AND meaningful
    models = base_models(n_tiers, devices)

    s_disp, s_rej, s_done, s_batches = run_des(
        n_tiers, depths, models, policy_kind, bucketed, max_batch, lengths)
    e_disp, e_rej, e_done, e_batches = run_engine(
        n_tiers, depths, models, policy_kind, bucketed, max_batch, lengths)

    assert e_disp == s_disp, (cfg, e_disp, s_disp)
    assert e_rej == s_rej, (cfg, e_rej, s_rej)
    assert e_done == s_done == sum(s_disp.values())

    # per-tier batch-size distributions: the batches the two drivers formed
    # through the shared pop_batch must be the same multiset
    for i in range(n_tiers):
        name = f"T{i}"
        assert e_batches.get(name, []) == s_batches.get(name, []), \
            (cfg, name, e_batches.get(name), s_batches.get(name))
        cap = max_batch if max_batch else max(1, depths[i])
        assert all(b <= cap for b in s_batches.get(name, []))


@settings(max_examples=6, deadline=None)
@given(st.sampled_from(["cascade", "length-aware", "least-loaded",
                        "predictive"]),
       st.lists(st.integers(min_value=5, max_value=400),
                min_size=2, max_size=14))
def test_bucketed_batches_single_bucket_both_drivers(policy_kind, lengths):
    """With a bucket_fn, EVERY batch either driver forms is single-bucket
    (the contract that lets backends pad to the bucket, not a straggler)."""
    models = base_models(2, 1)
    tiers = [TierSpec("T0", 4, model=RecordingModel(models["T0"]),
                      bucket_fn=BUCKET),
             TierSpec("T1", 4, model=RecordingModel(models["T1"]),
                      bucket_fn=BUCKET)]
    sim = ServingSimulator(tiers=tiers, slo_s=100.0,
                           policy=make_policy(policy_kind, models))
    res = sim.run([(0.0, ln) for ln in lengths])
    assert res.n_completed == sum(res.dispatched.values())

    eng_tiers = [TierSpec(f"T{i}", 4,
                          backend=ModeledBackend(
                              DeviceModel(f"T{i}", beta=TIER_BETAS[i],
                                          b=0.0, a=0.0), embed_dim=4),
                          bucket_fn=BUCKET) for i in range(2)]
    ve = WindVE(tiers=eng_tiers, policy=make_policy(policy_kind, models))
    batches = []
    ve.add_batch_hook(lambda tier, batch, lat: batches.append(list(batch)))
    old = sys.getswitchinterval()
    try:
        sys.setswitchinterval(5.0)
        try:
            futs = [ve.submit(length=ln) for ln in lengths]
        finally:
            sys.setswitchinterval(old)
        for f in futs:
            if f is not None:
                f.result(timeout=60)
    finally:
        sys.setswitchinterval(old)
        ve.shutdown()
    for batch in batches:
        assert len({BUCKET(q) for q in batch}) == 1, \
            [(q.qid, q.length) for q in batch]


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(["cascade", "least-loaded", "predictive"]),
       st.lists(st.integers(min_value=0, max_value=5),     # phase-1 keys
                min_size=1, max_size=10),
       st.lists(st.integers(min_value=0, max_value=5),     # phase-2 keys
                min_size=1, max_size=10))
def test_cache_tier_hit_miss_parity(policy_kind, keys1, keys2):
    """With a cache tier at the head of the topology, both drivers must
    agree exactly on per-tier hit/miss/insert counts for two-phase traffic:
    a pinned burst (all arrivals before any completion — every lookup
    misses, every completion admits), then, after the backlog fully drains,
    a second pinned burst whose hits are exactly the phase-1 key set.
    Admission happens BEFORE the future resolves in the engine, so the
    drained-backlog guarantee is identical under monotonic and sim time."""
    LEN = 64
    models = base_models(2, 1)

    def specs(mk):
        return [cache_tier(64)] + [mk(i) for i in range(2)]

    sim = ServingSimulator(
        tiers=specs(lambda i: TierSpec(f"T{i}", 8, model=models[f"T{i}"])),
        slo_s=100.0, policy=make_policy(policy_kind, models))
    arrivals = [(0.0, LEN, k) for k in keys1] + \
               [(1000.0, LEN, k) for k in keys2]    # far past phase-1 drain
    res = sim.run(arrivals)

    ve = WindVE(
        tiers=specs(lambda i: TierSpec(
            f"T{i}", 8,
            backend=ModeledBackend(DeviceModel(f"T{i}", beta=TIER_BETAS[i],
                                               b=0.0, a=0.0), embed_dim=4))),
        policy=make_policy(policy_kind, models))
    old = sys.getswitchinterval()
    try:
        for phase in (keys1, keys2):        # drain fully between phases
            sys.setswitchinterval(5.0)
            try:
                futs = [ve.submit(payload=np.array([k], np.int64),
                                  length=LEN) for k in phase]
            finally:
                sys.setswitchinterval(old)
            for f in futs:
                if f is not None:
                    f.result(timeout=60)
    finally:
        sys.setswitchinterval(old)
        ve.shutdown()

    e, s = ve.stats, res
    assert dict(e.cache_hits) == dict(s.cache_hits), (keys1, keys2)
    assert dict(e.cache_misses) == dict(s.cache_misses)
    assert dict(e.cache_inserts) == dict(s.cache_inserts)
    assert dict(e.dispatched) == dict(s.dispatched)
    assert e.rejected == s.rejected == 0      # 2x depth 8 >= 10-query burst
    # the hits are exactly the phase-2 keys already admitted in phase 1
    expect_hits = sum(1 for k in keys2 if k in set(keys1))
    assert e.cache_hits.get(CACHE, 0) == expect_hits
    assert e.summary().get("cache_hit_rate") == \
        s.summary().get("cache_hit_rate")


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(["cascade", "least-loaded", "predictive"]),
       st.lists(st.integers(min_value=5, max_value=400),
                min_size=1, max_size=12))
def test_admission_under_capacity_is_invisible(policy_kind, lengths):
    """Admission control must be a pure overload mechanism: with depths
    that cover the whole burst, a full watermark, and an SLO every fit
    passes, switching the controller ON changes NOTHING — identical
    dispatch counters, batch multisets, and zero rejections of any reason,
    in BOTH drivers.  (The capacity-plan bench asserts the opposite regime:
    under a flash crowd the counters must diverge, and identically so.)"""
    from repro.core.admission import AdmissionController

    n_tiers = 2
    depths = [len(lengths), len(lengths)]
    models = base_models(n_tiers, 1)

    def admission():
        return AdmissionController(fits=dict(models), slo_s=100.0,
                                   reject_cost=0.5, watermark=1.0)

    def des(adm):
        recorders = {n: RecordingModel(m) for n, m in models.items()}
        tiers = [TierSpec(f"T{i}", depths[i], model=recorders[f"T{i}"])
                 for i in range(n_tiers)]
        sim = ServingSimulator(tiers=tiers, slo_s=100.0,
                               policy=make_policy(policy_kind, models),
                               admission=adm)
        res = sim.run([(0.0, ln) for ln in lengths])
        return (dict(res.dispatched), res.rejected, res.n_completed,
                {k: v for k, v in res.rejections.items() if v},
                {n: sorted(r.batches) for n, r in recorders.items()
                 if r.batches})

    def engine(adm):
        tiers = [TierSpec(f"T{i}", depths[i],
                          backend=ModeledBackend(
                              DeviceModel(f"T{i}", beta=TIER_BETAS[i],
                                          b=0.0, a=0.0), embed_dim=4))
                 for i in range(n_tiers)]
        ve = WindVE(tiers=tiers, policy=make_policy(policy_kind, models),
                    admission=adm)
        seen = defaultdict(list)
        ve.add_batch_hook(lambda t, b, lat: seen[t].append(len(b)))
        old = sys.getswitchinterval()
        try:
            sys.setswitchinterval(5.0)
            try:
                futs = [ve.submit(length=ln) for ln in lengths]
            finally:
                sys.setswitchinterval(old)
            done = [f.result(timeout=60) for f in futs if f is not None]
            out = (dict(ve.stats.dispatched), ve.stats.rejected, len(done),
                   {k: v for k, v in ve.stats.rejections.items() if v},
                   {t: sorted(b) for t, b in seen.items() if b})
        finally:
            sys.setswitchinterval(old)
            ve.shutdown()
        return out

    d_off, d_on = des(None), des(admission())
    e_off, e_on = engine(None), engine(admission())
    assert d_on == d_off, (policy_kind, lengths, d_on, d_off)
    assert e_on == e_off, (policy_kind, lengths, e_on, e_off)
    assert e_on == d_on, (policy_kind, lengths, e_on, d_on)
    assert d_on[3] == {}                       # no rejections of any reason


def test_admission_preserves_served_embeddings_bitwise():
    """Real-backend smoke: under capacity, the embeddings a query stream
    receives are BITWISE identical with the admission controller on vs off
    — overload control must never perturb what gets computed, only whether
    a doomed query is accepted."""
    import jax

    from repro.configs import get_config
    from repro.core.admission import AdmissionController
    from repro.core.windve import JaxEmbedderBackend
    from repro.data.workload import make_queries
    from repro.models import embedder

    cfg = get_config("bge-large-zh-v1.5").smoke()
    params = embedder.init_embedder(jax.random.PRNGKey(0), cfg)
    payloads = make_queries(4, cfg.vocab_size, length=16, seed=5)
    fit = DeviceModel("T0", beta=0.01, b=0.001, a=0.0)

    def serve(adm):
        ve = WindVE(tiers=[TierSpec("T0", 8,
                                    backend=JaxEmbedderBackend(
                                        cfg, params, max_tokens=16))],
                    admission=adm)
        try:
            futs = [ve.submit(payload=p, length=16) for p in payloads]
            assert all(f is not None for f in futs)
            return [np.asarray(f.result(timeout=60)) for f in futs], \
                dict(ve.stats.dispatched)
        finally:
            ve.shutdown()

    off_emb, off_disp = serve(None)
    on_emb, on_disp = serve(AdmissionController(fits={"T0": fit},
                                                slo_s=100.0))
    assert on_disp == off_disp
    for a, b in zip(on_emb, off_emb):
        assert a.dtype == b.dtype and np.array_equal(a, b)


# ---------------------------------------------------------------------------
# multi-replica topologies: replicas are ordinary tiers, so BOTH drivers
# must agree counter-for-counter PER REPLICA — including under the
# replica-oblivious round-robin baseline and under seeded fault plans
# pinned to one replica of a replica set.
# ---------------------------------------------------------------------------

def replica_specs(hosts, replicas, depth, max_batch=None):
    """Expand one logical NPU tier into an H x R replica set, each replica
    with a distinct flat service curve (so load-aware orderings are
    non-trivial), and return (specs, models keyed by replica name)."""
    specs = replicate(TierSpec("NPU", depth, max_batch=max_batch),
                      hosts, replicas)
    models = {t.name: DeviceModel(t.name, beta=0.05 + 0.02 * i, b=0.0,
                                  a=0.0)
              for i, t in enumerate(specs)}
    return specs, models


def make_replica_policy(kind, models):
    if kind == "round-robin":
        # stateful rotation counter: each driver gets its own instance
        return RoundRobinPolicy()
    return make_policy(kind, models)


REPLICA_CONFIG = st.tuples(
    st.integers(min_value=1, max_value=2),                  # hosts
    st.integers(min_value=1, max_value=3),                  # replicas/host
    st.integers(min_value=1, max_value=6),                  # replica depth
    st.sampled_from(["cascade", "least-loaded", "predictive",
                     "round-robin"]),
    st.sampled_from([None, 2, 4]),                          # max_batch cap
    st.lists(st.integers(min_value=5, max_value=400),       # query lengths
             min_size=1, max_size=18),
)


@settings(max_examples=8, deadline=None)
@given(REPLICA_CONFIG)
def test_multi_replica_topology_parity(cfg):
    """Random hosts x replicas topologies: routed counts, BUSY rejections,
    completions, and per-replica batch multisets agree across drivers for
    every policy — replica tiers are just tiers to the scheduling core."""
    hosts, replicas, depth, policy_kind, max_batch, lengths = cfg
    specs, models = replica_specs(hosts, replicas, depth, max_batch)

    recorders = {n: RecordingModel(m) for n, m in models.items()}
    des_tiers = [dataclasses.replace(t, model=recorders[t.name])
                 for t in specs]
    sim = ServingSimulator(tiers=des_tiers, slo_s=100.0,
                           policy=make_replica_policy(policy_kind, models))
    res = sim.run([(0.0, ln) for ln in lengths])
    s_disp, s_rej, s_done = dict(res.dispatched), res.rejected, \
        res.n_completed
    s_batches = {n: sorted(r.batches) for n, r in recorders.items()
                 if r.batches}

    eng_tiers = [dataclasses.replace(
        t, backend=ModeledBackend(models[t.name], embed_dim=4))
        for t in specs]
    ve = WindVE(tiers=eng_tiers,
                policy=make_replica_policy(policy_kind, models))
    seen = defaultdict(list)
    ve.add_batch_hook(lambda tier, batch, lat: seen[tier].append(len(batch)))
    old = sys.getswitchinterval()
    try:
        sys.setswitchinterval(5.0)
        try:
            futs = [ve.submit(length=ln) for ln in lengths]
        finally:
            sys.setswitchinterval(old)
        done = [f.result(timeout=60) for f in futs if f is not None]
        e_disp, e_rej = dict(ve.stats.dispatched), ve.stats.rejected
    finally:
        sys.setswitchinterval(old)
        ve.shutdown()
    e_batches = {t: sorted(b) for t, b in seen.items() if b}

    assert e_disp == s_disp, (cfg, e_disp, s_disp)
    assert e_rej == s_rej, (cfg, e_rej, s_rej)
    assert len(done) == s_done == sum(s_disp.values())
    assert e_batches == s_batches, (cfg, e_batches, s_batches)
    # every dispatch landed on a real replica of the logical tier
    assert set(e_disp) <= {t.name for t in specs}


@settings(max_examples=6, deadline=None)
@given(st.tuples(
    st.integers(min_value=1, max_value=2),            # hosts
    st.integers(min_value=1, max_value=2),            # replicas/host
    st.lists(st.integers(min_value=0, max_value=3),   # victim fail ordinals
             min_size=0, max_size=3),
    st.integers(min_value=0, max_value=2),            # max_retries
    st.integers(min_value=4, max_value=10),           # burst size
    st.sampled_from(["cascade", "predictive"]),
))
def test_multi_replica_fault_counters_per_replica(cfg):
    """Seeded fault plan pinned to ONE replica of an H x R set: retries,
    backend errors, breaker trips, failover dispatches, and terminal
    failures must match counter-for-counter per replica across drivers —
    a replica's breaker isolates that replica, its siblings absorb the
    failover."""
    from repro.core.faults import FaultModel, FaultPlan, FaultyBackend
    from repro.core.health import CircuitBreaker
    from repro.core.routing import RetryPolicy

    hosts, replicas, fails, retries, n, policy_kind = cfg
    plan = FaultPlan(fail=frozenset(fails))
    retry = RetryPolicy(max_retries=retries, backoff_s=0.0)
    depth = n + 4          # no BUSY: rejection never hangs on a clock race
    specs, models = replica_specs(hosts, replicas, depth, max_batch=2)
    victim = specs[0].name

    def brk():
        # cooldown far beyond any run: a trip stays a trip on either clock
        return CircuitBreaker(failure_threshold=2, cooldown_s=1000.0)

    def record(t):
        out = {
            "dispatched": dict(t.dispatched),
            "rejected": t.rejected,
            "retries": dict(t.retries),
            "backend_errors": dict(t.backend_errors),
            "breaker_trips": dict(t.breaker_trips),
            "failed": t.failed,
        }
        return out

    eng_tiers = [dataclasses.replace(
        t, breaker=brk(),
        backend=(FaultyBackend(ModeledBackend(models[t.name], embed_dim=4),
                               plan=plan)
                 if t.name == victim
                 else ModeledBackend(models[t.name], embed_dim=4)))
        for t in specs]
    ve = WindVE(tiers=eng_tiers, retry=retry,
                policy=make_replica_policy(policy_kind, models))
    old = sys.getswitchinterval()
    try:
        sys.setswitchinterval(5.0)
        try:
            futs = [ve.submit(length=16) for _ in range(n)]
        finally:
            sys.setswitchinterval(old)
        done = fail = 0
        for f in futs:
            if f is None:
                continue
            try:
                f.result(timeout=30)
                done += 1
            except Exception:
                fail += 1
        eng = record(ve.stats)
        eng["client_done"], eng["client_fail"] = done, fail
    finally:
        sys.setswitchinterval(old)
        ve.shutdown()

    des_tiers = [dataclasses.replace(t, breaker=brk(), model=models[t.name])
                 for t in specs]
    # nonzero failure-detection cost keeps the DES victim's server serial
    # like the engine's worker thread: the retry re-dispatch lands BETWEEN
    # consecutive batch failures on both clocks (at 0.0 two same-instant
    # failures trip the breaker before the first retry re-dispatches)
    sim = ServingSimulator(tiers=des_tiers, slo_s=100.0, retry=retry,
                           policy=make_replica_policy(policy_kind, models),
                           faults={victim: FaultModel(plan=plan,
                                                      fail_latency_s=0.01)})
    res = sim.run([(0.0, 16)] * n)
    des = record(res)
    des["client_done"], des["client_fail"] = res.n_completed, res.failed

    assert eng == des, (cfg, eng, des)
    assert eng["client_done"] + eng["client_fail"] == n
    # faults never leak across replica boundaries: only the victim errors
    assert set(eng["backend_errors"]) <= {victim}
    assert set(eng["breaker_trips"]) <= {victim}


def test_replicas_one_serves_bitwise_identical_to_plain_tier():
    """``replicate(spec, 1, 1)`` is TODAY's path, bit for bit: a real jax
    backend served through the degenerate replica set returns embeddings
    bitwise identical to the un-replicated spec, with identical counters —
    the replica layer must be invisible until it is asked for."""
    import jax

    from repro.configs import get_config
    from repro.core.windve import JaxEmbedderBackend
    from repro.data.workload import make_queries
    from repro.models import embedder

    cfg = get_config("bge-large-zh-v1.5").smoke()
    params = embedder.init_embedder(jax.random.PRNGKey(0), cfg)
    payloads = make_queries(4, cfg.vocab_size, length=16, seed=7)
    be = JaxEmbedderBackend(cfg, params, max_tokens=16)

    def serve(tiers):
        ve = WindVE(tiers=tiers)
        try:
            futs = [ve.submit(payload=p, length=16) for p in payloads]
            assert all(f is not None for f in futs)
            return [np.asarray(f.result(timeout=60)) for f in futs], \
                dict(ve.stats.dispatched)
        finally:
            ve.shutdown()

    plain_emb, plain_disp = serve([TierSpec("T0", 8, backend=be)])
    rep = replicate(TierSpec("T0", 8, backend=be), hosts=1, replicas=1)
    assert len(rep) == 1 and rep[0].name == "T0"    # no @h0r0 suffix at 1x1
    rep_emb, rep_disp = serve(list(rep))

    assert rep_disp == plain_disp == {"T0": len(payloads)}
    for a, b in zip(rep_emb, plain_emb):
        assert a.dtype == b.dtype and np.array_equal(a, b)
