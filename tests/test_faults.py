"""Fault-injection vocabulary (``repro.core.faults``): ordinal plans,
wall-time schedules, the engine-side ``FaultyBackend`` wrapper and its DES
mirror ``FaultModel``."""
import numpy as np
import pytest

from repro.core.cost_model import availability
from repro.core.faults import (BackendError, FaultModel, FaultPlan,
                               FaultSchedule, FaultyBackend)
from repro.core.routing import Query


# ---------------------------------------------------------------------------
# FaultPlan / FaultSchedule
# ---------------------------------------------------------------------------

def test_plan_normalizes_iterables_to_frozensets():
    p = FaultPlan(fail=[2, 3], stall={1}, corrupt=(0,), stall_s=0.5)
    assert p.fail == frozenset({2, 3})
    assert p.stall == frozenset({1})
    assert p.corrupt == frozenset({0})


def test_plan_rejects_negative_stall():
    with pytest.raises(ValueError):
        FaultPlan(stall_s=-0.1)


def test_schedule_sorts_and_validates_windows():
    s = FaultSchedule(((5.0, 6.0), (1.0, 2.0)))
    assert s.windows == ((1.0, 2.0), (5.0, 6.0))
    with pytest.raises(ValueError):
        FaultSchedule(((2.0, 2.0),))
    with pytest.raises(ValueError):
        FaultSchedule(((3.0, 1.0),))


def test_schedule_is_down_half_open_interval():
    s = FaultSchedule(((1.0, 2.0),))
    assert not s.is_down(0.5)
    assert s.is_down(1.0)                # [start, end)
    assert s.is_down(1.5)
    assert not s.is_down(2.0)
    assert s.down_s == pytest.approx(1.0)


def test_schedule_next_up():
    s = FaultSchedule(((1.0, 2.0), (4.0, 5.0)))
    assert s.next_up(0.0) == 0.0         # already up
    assert s.next_up(1.5) == 2.0
    assert s.next_up(4.0) == 5.0


def test_from_mttf_deterministic_and_bounded():
    a = FaultSchedule.from_mttf(10.0, 2.0, horizon_s=100.0, seed=7)
    b = FaultSchedule.from_mttf(10.0, 2.0, horizon_s=100.0, seed=7)
    assert a.windows == b.windows        # seeded: replayable
    c = FaultSchedule.from_mttf(10.0, 2.0, horizon_s=100.0, seed=8)
    assert a.windows != c.windows
    for s, e in a.windows:
        assert 0.0 < s < e <= 100.0


def test_from_mttf_up_fraction_matches_availability():
    """Over a long horizon the empirical up fraction approaches the
    alternating-renewal closed form MTTF/(MTTF+MTTR) (cost_model)."""
    mttf, mttr, horizon = 10.0, 5.0, 50_000.0
    s = FaultSchedule.from_mttf(mttf, mttr, horizon_s=horizon, seed=0)
    up_frac = 1.0 - s.down_s / horizon
    assert up_frac == pytest.approx(availability(mttf, mttr), abs=0.03)


def test_from_mttf_validation():
    with pytest.raises(ValueError):
        FaultSchedule.from_mttf(0.0, 1.0, 10.0)
    with pytest.raises(ValueError):
        FaultSchedule.from_mttf(1.0, -1.0, 10.0)


# ---------------------------------------------------------------------------
# FaultyBackend (engine side)
# ---------------------------------------------------------------------------

class CountingBackend:
    """Minimal Backend: distinct embedding per qid, no jax needed."""

    name = "counting"
    telemetry = None

    def __init__(self):
        self.calls = 0

    def embed_batch(self, queries):
        self.calls += 1
        return [np.full(4, float(q.qid), np.float32) for q in queries]


def q(i):
    return Query(qid=i, length=8)


def test_faulty_backend_ordinal_fail():
    fb = FaultyBackend(CountingBackend(), plan=FaultPlan(fail={1}))
    assert fb.embed_batch([q(0)])[0][0] == 0.0      # execution #0 fine
    with pytest.raises(BackendError):
        fb.embed_batch([q(1)])                      # execution #1 injected
    assert fb.embed_batch([q(2)])[0][0] == 2.0      # execution #2 fine
    assert fb.executions == 3
    assert fb.injected_failures == 1
    assert fb.inner.calls == 2                      # the failure never ran


def test_faulty_backend_ordinal_corrupt_keeps_shape():
    fb = FaultyBackend(CountingBackend(), plan=FaultPlan(corrupt={0}))
    [good] = CountingBackend().embed_batch([q(5)])
    [bad] = fb.embed_batch([q(5)])
    assert bad.shape == good.shape and bad.dtype == good.dtype
    assert not np.allclose(bad, good)               # silently WRONG values
    assert fb.injected_corruptions == 1


def test_faulty_backend_stall_then_serve():
    fb = FaultyBackend(CountingBackend(),
                       plan=FaultPlan(stall={0}, stall_s=0.0))
    out = fb.embed_batch([q(1), q(2)])
    assert len(out) == 2
    assert fb.injected_stalls == 1


def test_faulty_backend_schedule_uses_relative_clock():
    t = [100.0]                                      # fake wall clock
    fb = FaultyBackend(CountingBackend(),
                       schedule=FaultSchedule(((1.0, 2.0),)),
                       clock=lambda: t[0])
    fb.embed_batch([q(0)])                           # t0 pinned at 100.0
    t[0] = 101.5                                     # 1.5s in: down window
    with pytest.raises(BackendError):
        fb.embed_batch([q(1)])
    t[0] = 102.5                                     # window closed
    assert len(fb.embed_batch([q(2)])) == 1
    assert fb.injected_failures == 1


def test_faulty_backend_forwards_telemetry():
    inner = CountingBackend()
    fb = FaultyBackend(inner)
    marker = object()
    fb.telemetry = marker
    assert inner.telemetry is marker
    assert fb.telemetry is marker
    assert fb.name == "faulty(counting)"
    assert fb.async_dispatch is False


# ---------------------------------------------------------------------------
# FaultModel (DES side)
# ---------------------------------------------------------------------------

def test_fault_model_mirrors_ordinal_plan():
    fm = FaultModel(plan=FaultPlan(fail={1}, stall={0}, stall_s=0.3))
    failed, extra = fm.outcome(now=0.0)              # #0: stalled, served
    assert (failed, extra) == (False, 0.3)
    failed, extra = fm.outcome(now=0.1)              # #1: injected failure
    assert (failed, extra) == (True, 0.0)
    failed, extra = fm.outcome(now=0.2)              # #2: clean
    assert (failed, extra) == (False, 0.0)
    assert fm.executions == 3
    assert fm.injected_failures == 1
    assert fm.injected_stalls == 1


def test_fault_model_schedule_on_sim_time():
    fm = FaultModel(schedule=FaultSchedule(((1.0, 2.0),)),
                    fail_latency_s=0.05)
    assert fm.outcome(now=0.5) == (False, 0.0)
    assert fm.outcome(now=1.5) == (True, 0.0)
    assert fm.fail_latency_s == 0.05
    fm.reset()
    assert fm.executions == 0 and fm.injected_failures == 0


def test_fault_model_and_backend_agree_on_a_plan():
    """The parity contract in miniature: the same ordinal plan produces the
    same per-execution outcome sequence through both injectors."""
    plan = FaultPlan(fail={0, 3}, stall={2}, stall_s=0.0)
    fm = FaultModel(plan=plan)
    fb = FaultyBackend(CountingBackend(), plan=plan)
    eng = []
    for i in range(5):
        try:
            fb.embed_batch([q(i)])
            eng.append(False)
        except BackendError:
            eng.append(True)
    des = [fm.outcome(float(i))[0] for i in range(5)]
    assert eng == des == [True, False, False, True, False]


def test_fault_model_rejects_negative_fail_latency():
    with pytest.raises(ValueError):
        FaultModel(fail_latency_s=-0.1)
