"""§4.4 CPU affinity / NUMA planner tests."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.affinity import NumaTopology, numa_crossings, plan_affinity

KUNPENG = NumaTopology(total_cores=128, numa_nodes=4)   # the paper's box


def test_reverse_index_order():
    cores = plan_affinity(KUNPENG, 8)
    assert cores == list(range(127, 119, -1))


def test_no_numa_crossing_when_fits():
    cores = plan_affinity(KUNPENG, 32)      # one full NUMA
    assert numa_crossings(KUNPENG, cores) == 0


def test_first_numa_reserved():
    # paper §5.4: at most 96 of 128 cores usable (first NUMA = framework)
    cores = plan_affinity(KUNPENG, 96)
    assert min(cores) == 32
    with pytest.raises(ValueError):
        plan_affinity(KUNPENG, 97)


def test_large_worker_spans_numas_from_top():
    cores = plan_affinity(KUNPENG, 64)
    assert max(cores) == 127
    assert numa_crossings(KUNPENG, cores) == 1


def test_single_numa_box_not_reserved():
    topo = NumaTopology(total_cores=8, numa_nodes=1)
    assert plan_affinity(topo, 8) == list(range(7, -1, -1))


@given(numas=st.integers(1, 8), cpn=st.integers(2, 32),
       need=st.integers(1, 64))
@settings(max_examples=200, deadline=None)
def test_plan_properties(numas, cpn, need):
    topo = NumaTopology(total_cores=numas * cpn, numa_nodes=numas)
    usable = topo.total_cores - (cpn if numas > 1 else 0)
    if need > usable:
        with pytest.raises(ValueError):
            plan_affinity(topo, need)
        return
    cores = plan_affinity(topo, need)
    assert len(cores) == len(set(cores)) == need
    # reserved NUMA untouched
    if numas > 1:
        assert all(c >= cpn for c in cores)
    # paper rule: if the worker fits one NUMA it must not cross
    if need <= cpn:
        assert numa_crossings(topo, cores) == 0
