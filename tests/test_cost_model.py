"""§3 cost model and §3.2/§4.2.3 savings/bounds tests."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cost_model import (Deployment, concurrency_uplift_bound,
                                   cost_peak, cost_throughput, peak_saving,
                                   throughput_uplift, waiting_slots)
from repro.core.simulator import PAPER_DEVICES


def test_waiting_slots_eq4():
    assert waiting_slots(t_total_max=1.0, t_proc=0.3) == 2
    assert waiting_slots(t_total_max=2.0, t_proc=0.3) == 5
    assert waiting_slots(t_total_max=0.2, t_proc=0.3) == 0


def test_paper_headline_numbers():
    # Table 1, V100 + Xeon @2s: 96 + 22
    assert throughput_uplift(96, 22) == pytest.approx(0.229, abs=1e-3)
    assert peak_saving(96, 22) == pytest.approx(0.186, abs=1e-3)
    # @1s: 44 + 8 -> 18.2%
    assert throughput_uplift(44, 8) == pytest.approx(0.182, abs=1e-3)


def test_peak_cost_monotone_in_concurrency():
    c1 = cost_peak(1000, 96)
    c2 = cost_peak(1000, 118)
    assert c2 < c1
    assert (c1 - c2) / c1 == pytest.approx(peak_saving(96, 22), abs=1e-9)


def test_throughput_cost_eq5():
    # N/n / T * D * P
    c = cost_throughput(n_queries_per_s=100, t_total_max=1.0, t_proc=0.25,
                        throughput=10, d=Deployment(2, 5.0))
    assert c == pytest.approx(100 / 3 / 10 * 2 * 5.0)


def test_ineq19_bound_holds_for_paper_devices():
    """C_CPU/C_NPU < alpha_NPU/alpha_CPU (§4.2.3) on the calibrated devices."""
    for model, npu_k, cpu_k, c_npu, c_cpu, slo in [
        ("bge", "tesla-v100/bge", "xeon-e5-2690/bge", 96, 22, 2.0),
        ("bge", "tesla-v100/bge", "xeon-e5-2690/bge", 44, 8, 1.0),
    ]:
        npu, cpu = PAPER_DEVICES[npu_k], PAPER_DEVICES[cpu_k]
        # effective alpha at the operating point (secant slope)
        a_npu = (npu.latency(c_npu) - npu.beta) / c_npu
        a_cpu = (cpu.latency(c_cpu) - cpu.beta) / c_cpu
        assert throughput_uplift(c_npu, c_cpu) < \
            concurrency_uplift_bound(a_npu, a_cpu) + 1e-9


@given(c_npu=st.integers(1, 500), c_cpu=st.integers(0, 500))
@settings(max_examples=200, deadline=None)
def test_savings_identities(c_npu, c_cpu):
    s = peak_saving(c_npu, c_cpu)
    u = throughput_uplift(c_npu, c_cpu)
    assert 0 <= s < 1
    assert u >= 0
    # s = u / (1 + u)
    assert s == pytest.approx(u / (1 + u), abs=1e-12)


def test_looser_slo_gives_bigger_uplift():
    """Ineq. 23: relaxing the SLO increases the uplift (beta_CPU > beta_NPU)."""
    npu, cpu = PAPER_DEVICES["tesla-v100/bge"], PAPER_DEVICES["xeon-e5-2690/bge"]
    from repro.core.estimator import fine_tune_depth
    from repro.core.simulator import profile_fn_for
    ups = []
    for slo in (1.0, 2.0):
        cn = fine_tune_depth(profile_fn_for(npu), slo, start=100, radius=60)
        cc = fine_tune_depth(profile_fn_for(cpu), slo, start=30, radius=29)
        ups.append(throughput_uplift(cn, cc))
    assert ups[1] > ups[0]
