"""Suite-size ratchet: the satellite test additions stay locked in.

CI's coverage gate (``pytest --cov=repro --cov-fail-under=...``) only runs
where ``pytest-cov`` is installable; this container cannot install it, so
the always-on floor is the collected-test count — deleting or breaking the
collection of any suite (e.g. the property-parity or golden-embedding
files) fails tier-1 everywhere, not just in CI.

Raise ``FLOOR`` when tests are added; never lower it to make a PR pass.
"""
import os
import re
import subprocess
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# 485 collected as of the fault-tolerance PR (deadlines, retry/failover,
# circuit breaking, chaos fault model); small slack so a legitimate
# parametrization tweak is not a CI incident
FLOOR = 600


def test_collected_test_count_never_regresses():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src")] +
        env.get("PYTHONPATH", "").split(os.pathsep))
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "--collect-only", "-q", "tests"],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, \
        f"collection failed:\n{proc.stdout}\n{proc.stderr}"
    m = re.search(r"(\d+)\s+tests?\s+collected", proc.stdout)
    assert m, f"could not parse collection summary:\n{proc.stdout[-2000:]}"
    n = int(m.group(1))
    assert n >= FLOOR, \
        f"collected {n} tests < floor {FLOOR}: a suite was lost or broken"
