"""Trip-count-aware HLO cost model: parity with XLA on loop-free programs,
x trip-count on scans (where XLA's own cost_analysis undercounts)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.roofline.hlo_cost import HloCostModel, analyse_hlo


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def _xla_cost(compiled):
    """cost_analysis() returns a per-device list on older jax, a dict now."""
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def test_matches_xla_on_loop_free():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    c = _compile(lambda x, w: jnp.tanh(x @ w), x, w)
    ours = analyse_hlo(c.as_text()).flops
    xla = _xla_cost(c)["flops"]
    assert ours == pytest.approx(xla, rel=0.05)


def test_scan_multiplied_by_trip_count():
    def scanned(x, w):
        return lax.scan(lambda h, wi: (jnp.tanh(h @ wi), None), x, w)[0]

    def unrolled(x, w):
        h = x
        for i in range(10):
            h = jnp.tanh(h @ w[i])
        return h

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    c_scan = _compile(scanned, x, w)
    c_unroll = _compile(unrolled, x, w)
    f_scan = analyse_hlo(c_scan.as_text()).flops
    f_unroll = analyse_hlo(c_unroll.as_text()).flops
    # ours: scan == unrolled; XLA's builtin: scan == unrolled / 10
    assert f_scan == pytest.approx(f_unroll, rel=0.05)
    assert _xla_cost(c_scan)["flops"] == \
        pytest.approx(f_unroll / 10, rel=0.05)


def test_nested_scan_multiplies():
    def nested(x, w):
        def outer(h, wi):
            def inner(h2, _):
                return jnp.tanh(h2 @ wi), None
            return lax.scan(inner, h, jnp.arange(4))[0], None
        return lax.scan(outer, x, w)[0]

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 128, 128), jnp.float32)
    c = _compile(nested, x, w)
    flops = analyse_hlo(c.as_text()).flops
    per_mm = 2 * 64 * 128 * 128
    assert flops == pytest.approx(20 * per_mm, rel=0.2)   # 5 x 4 matmuls


def test_collectives_counted_with_shapes():
    import os
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("needs >1 host device (dry-run process sets 512)")


def test_dynamic_update_slice_counts_update_not_buffer():
    def f(buf, val):
        return lax.dynamic_update_slice(buf, val, (0, 0))

    buf = jax.ShapeDtypeStruct((4096, 1024), jnp.float32)
    val = jax.ShapeDtypeStruct((1, 1024), jnp.float32)
    # donated buffer -> true in-place update (like our decode caches)
    c = jax.jit(f, donate_argnums=(0,)).lower(buf, val).compile()
    b = analyse_hlo(c.as_text()).bytes
    assert b < 2 * 4096 * 1024 * 4 * 0.1     # nowhere near full-buffer traffic


def test_scan_accumulator_not_counted_as_full_buffer():
    """The falcon-mamba regression: per-step ys stacking must cost the slice,
    not the whole (S, ...) output buffer."""
    def f(x):
        def step(c, xt):
            return c, jnp.tanh(xt)
        return lax.scan(step, 0.0, x)[1]

    x = jax.ShapeDtypeStruct((4096, 512), jnp.float32)
    c = _compile(f, x)
    b = analyse_hlo(c.as_text()).bytes
    full = 4096 * 512 * 4
    # read input once + write output once (x small per-step overhead), NOT
    # 4096 x full-buffer
    assert b < 20 * full
