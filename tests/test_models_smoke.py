"""Per-architecture REDUCED smoke tests (spec deliverable f).

For each of the 10 assigned archs: instantiate the reduced same-family
variant (2 layers, d_model<=512, <=4 experts), run one forward/train step on
CPU, assert output shapes and no NaNs; plus prefill/decode consistency."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.configs.base import ShapeConfig
from repro.models import api, lm
from repro.steps import optim
from repro.steps.inputs import make_batch
from repro.steps.train import build_train_step

SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")


@pytest.fixture(scope="module")
def mesh():
    from repro.launch.mesh import make_host_mesh

    return make_host_mesh()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_reduced_variant_limits(arch):
    cfg = get_config(arch).smoke()
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.num_experts <= 4


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_train_step(arch, mesh):
    cfg = get_config(arch).smoke()
    key = jax.random.PRNGKey(0)
    params = api.init_params(key, cfg)
    opt = optim.init(params)
    batch = make_batch(cfg, SHAPE, key)
    step = build_train_step(cfg, SHAPE, mesh)
    from repro.launch.mesh import mesh_context
    with mesh_context(mesh):
        p2, o2, m = jax.jit(step)(params, opt, batch)
    assert jnp.isfinite(m["loss"])
    assert float(m["loss"]) > 0
    assert not any(bool(jnp.isnan(x).any()) for x in jax.tree.leaves(p2))
    # params actually moved
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)).max()), params, p2)
    assert max(jax.tree.leaves(diffs)) > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_shapes(arch):
    cfg = get_config(arch).smoke()
    key = jax.random.PRNGKey(1)
    params = api.init_params(key, cfg)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.cross_attention:
        from repro.models import encdec
        frames = jax.random.normal(key, (B, cfg.num_frames, cfg.d_model))
        logits, _ = encdec.forward(params, cfg, toks, frames)
        assert logits.shape == (B, S, cfg.vocab_size)
    else:
        extra = None
        total = S
        if cfg.frontend == "vision":
            extra = jax.random.normal(key, (B, cfg.num_patches, cfg.d_model))
            total = S + cfg.num_patches
        logits, _ = lm.forward(params, cfg, toks, extra_embed=extra)
        assert logits.shape == (B, total, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "falcon-mamba-7b",
                                  "hymba-1.5b", "qwen3-moe-30b-a3b",
                                  "internvl2-2b", "whisper-tiny",
                                  "starcoder2-7b"])
def test_prefill_decode_matches_forward(arch):
    """decode(prefill(prompt)) logits == forward(prompt + token) logits."""
    cfg = get_config(arch).smoke()
    if cfg.is_moe:  # capacity dropping is batch-dependent; use dropless
        cfg = cfg.replace(capacity_factor=float(cfg.num_experts) /
                          cfg.experts_per_token)
    key = jax.random.PRNGKey(2)
    params = api.init_params(key, cfg)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    nxt = jnp.array([1, 2], dtype=jnp.int32)
    toks2 = jnp.concatenate([toks, nxt[:, None]], 1)

    if cfg.cross_attention:
        from repro.models import encdec
        frames = jax.random.normal(key, (B, cfg.num_frames, cfg.d_model))
        _, cache = encdec.prefill(params, cfg, toks, frames, max_len=S + 4,
                                  cache_dtype=jnp.float32)
        got, _ = encdec.decode_step(params, cfg, nxt, cache)
        want, _ = encdec.forward(params, cfg, toks2, frames)
    else:
        extra = None
        total = S
        if cfg.frontend == "vision":
            extra = jax.random.normal(key, (B, cfg.num_patches, cfg.d_model))
            total = S + cfg.num_patches
        _, cache = lm.prefill(params, cfg, toks, extra_embed=extra,
                              max_len=total + 4, cache_dtype=jnp.float32)
        got, _ = lm.decode_step(params, cfg, nxt, cache)
        want, _ = lm.forward(params, cfg, toks2, extra_embed=extra)
    err = float(jnp.abs(want[:, -1].astype(jnp.float32) -
                        got.astype(jnp.float32)).max())
    assert err < 0.15, f"{arch}: decode/forward mismatch {err}"  # bf16 compute


def test_sliding_window_ring_buffer_far_past_window():
    cfg = get_config("starcoder2-7b").smoke()   # window 16
    key = jax.random.PRNGKey(3)
    params = api.init_params(key, cfg)
    T = 40
    toks = jax.random.randint(key, (2, T), 0, cfg.vocab_size)
    _, cache = lm.prefill(params, cfg, toks[:, :24], max_len=64,
                          cache_dtype=jnp.float32)
    lg = None
    for t in range(24, T):
        lg, cache = lm.decode_step(params, cfg, toks[:, t], cache)
    want, _ = lm.forward(params, cfg, toks)
    err = float(jnp.abs(want[:, -1].astype(jnp.float32) -
                        lg.astype(jnp.float32)).max())
    assert err < 0.15


def test_moe_aux_loss_positive_and_balancedish():
    cfg = get_config("qwen3-moe-30b-a3b").smoke()
    key = jax.random.PRNGKey(4)
    params = api.init_params(key, cfg)
    toks = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    _, aux = lm.forward(params, cfg, toks)
    # Switch-style aux is ~1 for balanced routing, E for total collapse
    assert 0.5 < float(aux) < cfg.num_experts
