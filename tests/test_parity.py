"""Engine-vs-DES parity and engine regression tests for the shared core.

Both drivers (threaded ``WindVE``, event-driven ``ServingSimulator``) route
every query through the same ``QueueManager`` + ``DispatchPolicy`` code and
form batches through the same ``QueueManager.pop_batch`` (bucket_fn-aware),
so their dispatch decisions on the same arrival pattern must agree exactly.
"""
import time
from dataclasses import dataclass, field

import pytest

from repro.core.bucketing import length_bucket_fn
from repro.core.routing import (BUSY, CPU, NPU, CascadePolicy,
                                LengthAwarePolicy, TierSpec)
from repro.core.simulator import DeviceModel, ServingSimulator, cpu_core_scaled
from repro.core.windve import ModeledBackend, WindVE

# slow enough that a burst is fully submitted before anything completes
# (makes the threaded engine's dispatch sequence deterministic), fast enough
# to keep the suite quick
NPU_DEV = DeviceModel("npu", beta=0.25, b=0.0, a=0.0)
CPU_DEV = DeviceModel("cpu", beta=0.40, b=0.0, a=0.0)


def burst_engine(tiers, n, policy=None, length=75):
    ve = WindVE(tiers=tiers, policy=policy)
    try:
        futs = [ve.submit(length=length) for _ in range(n)]
        for f in futs:
            if f is not None:
                f.result(timeout=30)
        return dict(ve.stats.dispatched), ve.stats.rejected
    finally:
        ve.shutdown()


class TestEngineDESParity:
    def test_burst_dispatch_counts_agree(self):
        n = 30
        eng_tiers = [TierSpec(NPU, 8, backend=ModeledBackend(NPU_DEV, 4)),
                     TierSpec(CPU, 4, backend=ModeledBackend(CPU_DEV, 4))]
        sim_tiers = [TierSpec(NPU, 8, model=NPU_DEV),
                     TierSpec(CPU, 4, model=CPU_DEV)]
        eng_disp, eng_rej = burst_engine(eng_tiers, n)
        sim = ServingSimulator(tiers=sim_tiers, slo_s=5.0).run_burst(n)
        assert eng_disp == dict(sim.dispatched) == {NPU: 8, CPU: 4}
        assert eng_rej == sim.rejected == n - 12

    def test_three_tier_parity_via_tierspec_only(self):
        """NPU + big-core CPU + little-core CPU, both drivers, config only."""
        little = cpu_core_scaled(CPU_DEV, cores=44)
        n = 20
        eng_tiers = [
            TierSpec(NPU, 6, backend=ModeledBackend(NPU_DEV, 4)),
            TierSpec("CPU-big", 3, backend=ModeledBackend(CPU_DEV, 4)),
            TierSpec("CPU-little", 2, backend=ModeledBackend(little, 4))]
        sim_tiers = [TierSpec(NPU, 6, model=NPU_DEV),
                     TierSpec("CPU-big", 3, model=CPU_DEV),
                     TierSpec("CPU-little", 2, model=little)]
        eng_disp, eng_rej = burst_engine(eng_tiers, n)
        sim = ServingSimulator(tiers=sim_tiers, slo_s=10.0).run_burst(n)
        want = {NPU: 6, "CPU-big": 3, "CPU-little": 2}
        assert eng_disp == dict(sim.dispatched) == want
        assert eng_rej == sim.rejected == n - 11
        assert sim.violations == 0               # all 11 fit the 10s SLO

    def test_policy_objects_are_shared_not_copied(self):
        """One policy instance can drive both drivers simultaneously."""
        policy = CascadePolicy()
        sim = ServingSimulator(tiers=[TierSpec(NPU, 4, model=NPU_DEV)],
                               slo_s=5.0, policy=policy)
        r = sim.run_burst(6)
        assert r.rejected == 2
        eng_disp, eng_rej = burst_engine(
            [TierSpec(NPU, 4, backend=ModeledBackend(NPU_DEV, 4))], 6,
            policy=policy)
        assert eng_disp == {NPU: 4} and eng_rej == 2

    def test_length_aware_parity(self):
        policy = LengthAwarePolicy(long_threshold=300)
        sim_tiers = [TierSpec(NPU, 2, model=NPU_DEV),
                     TierSpec(CPU, 4, model=CPU_DEV)]
        sim = ServingSimulator(tiers=sim_tiers, slo_s=5.0, query_length=500,
                               policy=policy)
        r = sim.run_burst(5)                     # long: NPU-only, depth 2
        assert dict(r.dispatched) == {NPU: 2} and r.rejected == 3
        eng_tiers = [TierSpec(NPU, 2, backend=ModeledBackend(NPU_DEV, 4)),
                     TierSpec(CPU, 4, backend=ModeledBackend(CPU_DEV, 4))]
        eng_disp, eng_rej = burst_engine(eng_tiers, 5, policy=policy,
                                         length=500)
        assert eng_disp == {NPU: 2} and eng_rej == 3


@dataclass(frozen=True)
class RecordingModel(DeviceModel):
    """DeviceModel that records every (batch_size, length) it services."""

    calls: list = field(default_factory=list, compare=False)

    def latency(self, concurrency, length=75, rng=None):
        self.calls.append((int(concurrency), int(length)))
        return super().latency(concurrency, length, rng)


class TestBucketedBatchFormationParity:
    """Bucketed pop_batch drives BOTH drivers on the same arrival trace."""

    LENGTHS = [10, 70, 20, 120, 30, 80, 15, 40]
    BUCKET = staticmethod(length_bucket_fn(min_bucket=32, max_bucket=128))

    def test_engine_and_sim_dispatch_agree_with_bucket_fn(self):
        bucket = self.BUCKET
        npu = RecordingModel(NPU_DEV.name, NPU_DEV.beta, NPU_DEV.b, NPU_DEV.a)
        sim = ServingSimulator(
            tiers=[TierSpec(NPU, 6, model=npu, bucket_fn=bucket)], slo_s=9.0)
        res = sim.run([(0.0, ln) for ln in self.LENGTHS])
        eng_tiers = [TierSpec(NPU, 6, backend=ModeledBackend(NPU_DEV, 4),
                              bucket_fn=bucket)]
        ve = WindVE(tiers=eng_tiers)
        seen = []
        ve.add_batch_hook(lambda tier, batch, lat: seen.append(list(batch)))
        try:
            futs = [ve.submit(length=ln) for ln in self.LENGTHS]
            done = [f for f in futs if f is not None]
            for f in done:
                f.result(timeout=30)
        finally:
            ve.shutdown()
        # identical admission verdicts on the identical trace
        assert dict(ve.stats.dispatched) == dict(res.dispatched) == {NPU: 6}
        assert ve.stats.rejected == res.rejected == 2
        assert res.n_completed == len(done) == 6
        # EVERY batch either driver formed is single-bucket (the contract
        # that lets the backend pad to the bucket, not the straggler)
        for b, ln in npu.calls:                         # DES service calls
            assert ln <= 128
        sim_batches = npu.calls
        assert all(len({bucket(q) for q in batch}) == 1 for batch in seen)
        assert sum(c for c, _ in sim_batches) == 6
        assert sum(len(b) for b in seen) == 6

    def test_des_bucketed_batches_are_single_bucket_and_fifo(self):
        """Burst trace, deterministic DES: buckets are 10/20/30/15 -> 32,
        40 -> 64, 70/120/80 -> 128; the head of the line picks each batch's
        bucket and the modeled latency follows the batch MAX length."""
        bucket = self.BUCKET
        npu = RecordingModel("npu", beta=0.25, b=0.0, a=0.0)
        sim = ServingSimulator(
            tiers=[TierSpec(NPU, 100, model=npu, bucket_fn=bucket)],
            slo_s=50.0)
        res = sim.run([(0.0, ln) for ln in self.LENGTHS])
        assert res.n_completed == len(self.LENGTHS)
        assert npu.calls == [(4, 30),       # qids 1,3,5,7: bucket 32
                             (3, 120),      # qids 2,4,6:   bucket 128
                             (1, 40)]       # qid 8:        bucket 64
        assert res.violations == 0


class TestFuturesRace:
    def test_all_accepted_futures_resolve(self):
        """Regression: the seed registered the future AFTER dispatch, so a
        fast worker could complete the query first, pop nothing, and leave
        the caller hanging on fut.result().  Tiny depth + near-instant
        backend maximizes the race window."""
        instant = DeviceModel("instant", beta=0.0, b=0.0, a=0.0)
        ve = WindVE(tiers=[TierSpec(NPU, 1,
                                    backend=ModeledBackend(instant, 2))])
        try:
            resolved = 0
            deadline = time.monotonic() + 20
            while resolved < 50 and time.monotonic() < deadline:
                f = ve.submit(length=4)
                if f is None:
                    continue
                f.result(timeout=5)              # hung forever in the seed
                resolved += 1
            assert resolved == 50
            assert not ve._futures, "leaked futures after completion"
        finally:
            ve.shutdown()

    def test_busy_rolls_back_registration(self):
        slow = DeviceModel("slow", beta=0.5, b=0.0, a=0.0)
        ve = WindVE(tiers=[TierSpec(NPU, 1,
                                    backend=ModeledBackend(slow, 2))])
        try:
            f1 = ve.submit()
            assert f1 is not None
            assert ve.submit() is None           # BUSY
            assert len(ve._futures) == 1         # rollback happened
            f1.result(timeout=10)
        finally:
            ve.shutdown()


class TestBatchHook:
    def test_hook_sees_every_batch_and_detaches(self):
        dev = DeviceModel("d", beta=0.02, b=0.0, a=0.0)
        ve = WindVE(tiers=[TierSpec(NPU, 4, backend=ModeledBackend(dev, 2))])
        seen = []
        hook = ve.add_batch_hook(
            lambda tier, batch, lat: seen.append((tier, len(batch), lat)))
        try:
            futs = [ve.submit() for _ in range(4)]
            for f in futs:
                f.result(timeout=10)
            assert sum(n for _, n, _ in seen) == 4
            assert all(t == NPU and lat >= 0.0 for t, _, lat in seen)
            ve.remove_batch_hook(hook)
            before = len(seen)
            ve.submit().result(timeout=10)
            time.sleep(0.05)
            assert len(seen) == before
        finally:
            ve.shutdown()

    def test_hook_exception_does_not_kill_worker(self):
        dev = DeviceModel("d", beta=0.01, b=0.0, a=0.0)
        ve = WindVE(tiers=[TierSpec(NPU, 2, backend=ModeledBackend(dev, 2))])
        ve.add_batch_hook(lambda *a: (_ for _ in ()).throw(RuntimeError("x")))
        try:
            f = ve.submit()
            assert f.result(timeout=10) is not None
            f2 = ve.submit()                     # worker must still be alive
            assert f2.result(timeout=10) is not None
        finally:
            ve.shutdown()
