"""Calibrated device models + discrete-event simulation tests."""
import pytest

from repro.core.queue_manager import CPU, NPU
from repro.core.simulator import (PAPER_DEVICES, DeviceModel, ServingSimulator,
                                  cpu_core_scaled, diurnal_trace,
                                  profile_fn_for, solve_anchors)


class TestCalibration:
    @pytest.mark.parametrize("dev,c1,c2", [
        ("tesla-v100/bge", 44, 96), ("xeon-e5-2690/bge", 8, 22),
        ("atlas-300i-duo/bge", 84, 172), ("kunpeng-920/bge", 2, 8),
        ("tesla-v100/jina", 48, 112), ("xeon-e5-2690/jina", 11, 30),
    ])
    def test_anchors_hit_exactly(self, dev, c1, c2):
        d = PAPER_DEVICES[dev]
        assert d.latency(c1) == pytest.approx(1.0, abs=1e-9)
        assert d.latency(c2) == pytest.approx(2.0, abs=1e-9)

    def test_convexity_nonnegative(self):
        for d in PAPER_DEVICES.values():
            assert d.a >= -1e-12 and d.b > 0

    def test_solve_anchors_roundtrip(self):
        b, a = solve_anchors(0.3, 10, 1.0, 40, 2.0)
        assert 0.3 + b * 10 + a * 100 == pytest.approx(1.0)
        assert 0.3 + b * 40 + a * 1600 == pytest.approx(2.0)

    def test_length_scaling_monotone(self):
        d = PAPER_DEVICES["tesla-v100/bge"]
        assert d.latency(44, length=500) > d.latency(44, length=75)
        assert d.latency(44, length=75) == pytest.approx(1.0)

    def test_core_scaling(self):
        cpu = PAPER_DEVICES["xeon-e5-2690/bge"]
        fewer = cpu_core_scaled(cpu, cores=22, full_cores=44)
        assert fewer.latency(8) > cpu.latency(8)
        assert fewer.beta == cpu.beta          # model-load cost unchanged


class TestDES:
    def test_burst_within_capacity_no_violations(self):
        npu = PAPER_DEVICES["tesla-v100/bge"]
        cpu = PAPER_DEVICES["xeon-e5-2690/bge"]
        r = ServingSimulator(npu, cpu, 96, 22, slo_s=2.0).run_burst(118)
        assert r.accepted == 118
        assert r.rejected == 0
        assert r.violations == 0

    def test_offload_expands_concurrency_22_9_pct(self):
        """The paper's Table 1 @2s: 96 -> 118 (+22.9%)."""
        npu = PAPER_DEVICES["tesla-v100/bge"]
        cpu = PAPER_DEVICES["xeon-e5-2690/bge"]
        base = ServingSimulator(npu, None, 96, 0, slo_s=2.0).run_burst(140)
        wind = ServingSimulator(npu, cpu, 96, 22, slo_s=2.0).run_burst(140)
        assert base.max_ok_concurrency == 96
        assert wind.max_ok_concurrency == 118
        uplift = (wind.max_ok_concurrency - base.max_ok_concurrency) / \
            base.max_ok_concurrency
        assert uplift == pytest.approx(22 / 96, abs=1e-9)

    def test_overload_rejects_rather_than_violates(self):
        npu = PAPER_DEVICES["tesla-v100/bge"]
        cpu = PAPER_DEVICES["xeon-e5-2690/bge"]
        r = ServingSimulator(npu, cpu, 96, 22, slo_s=2.0).run_burst(200)
        assert r.rejected == 200 - 118
        assert r.violations == 0

    def test_sequential_arrivals_reuse_queue(self):
        npu = PAPER_DEVICES["tesla-v100/bge"]
        sim = ServingSimulator(npu, None, 44, 0, slo_s=1.0)
        arrivals = [(3.0 * i, 75) for i in range(5)]   # fully spaced out
        r = sim.run(arrivals)
        assert r.accepted == 5 and r.rejected == 0
        assert all(q.e2e_latency <= 1.0 + 1e-9 for q in r.completed)

    def test_diurnal_trace_shape(self):
        tr = diurnal_trace(60, base_rate=2, peak_rate=20, seed=3)
        assert all(0 <= t <= 60 for t, _ in tr)
        assert [t for t, _ in tr] == sorted(t for t, _ in tr)
        # peak half of the day should carry more traffic than the trough half
        mid = [t for t, _ in tr if 15 <= t < 45]
        edge = [t for t, _ in tr if t < 15 or t >= 45]
        assert len(mid) > len(edge)


class TestFanOutModel:
    """DES-side sharded NPU tier: the fan-out service curve (per-device pow2
    chunks + gather overhead) the depth estimator now calibrates against."""

    def _base(self):
        return DeviceModel("dev", beta=0.25, b=0.02, a=0.0)

    def test_one_device_is_the_base_model_itself(self):
        from repro.core.simulator import sharded_model

        base = self._base()
        assert sharded_model(base, 1) is base     # bitwise PR 2 degrade

    def test_rejects_single_device(self):
        from repro.core.simulator import FanOutModel

        with pytest.raises(ValueError):
            FanOutModel(self._base(), 1)

    def test_degraded_non_pow2_mesh_is_plannable(self):
        # a mid-outage replica mesh (one host quarantined: 8 -> 6 devices)
        # must plan, not crash — chunks stay pow2 (floored at the largest
        # pow2 that fits) and the straggler device takes ceil rows
        from repro.core.simulator import FanOutModel

        base = self._base()
        f6 = FanOutModel(base, 6)
        assert f6.chunk_floor == 4
        assert f6.chunk_plan(20) == [16, 4]
        # chunk 16 over 6 devices -> ceil(16/6) = 3 rows on the fullest
        # device; chunk 4 -> 1 row
        assert f6.latency(20) == pytest.approx(base.latency(3) +
                                               base.latency(1))

    def test_pow2_mesh_unchanged_by_degraded_planning(self):
        # the degraded-mesh extension is bitwise inert at pow2 counts
        from repro.core.simulator import FanOutModel

        base = self._base()
        f8 = FanOutModel(base, 8)
        assert f8.chunk_floor == 8
        for batch in (1, 8, 20, 64, 100):
            assert f8.latency(batch) == pytest.approx(sum(
                f8.overhead_s + base.latency(c // 8)
                for c in f8.chunk_plan(batch)))

    def test_interhost_gather_term(self):
        # a replica group carved across hosts pays the cross-host gather
        # on top of the intra-host tree; hosts=1 leaves overhead unchanged
        from repro.core.simulator import FanOutModel, sharded_model

        base = self._base()
        f1h = FanOutModel(base, 8, fanout_beta_s=0.01)
        f2h = FanOutModel(base, 8, fanout_beta_s=0.01,
                          hosts=2, interhost_beta_s=0.1)
        assert f1h.overhead_s == pytest.approx(0.03)
        assert f2h.overhead_s == pytest.approx(0.03 + 0.1)
        assert f2h.latency(8) == pytest.approx(base.latency(1) + 0.13)
        assert "x2h" in f2h.name and "x2h" not in f1h.name
        with pytest.raises(ValueError):
            FanOutModel(base, 8, hosts=3)   # uneven split over hosts
        s = sharded_model(base, 8, 0.01, hosts=2, interhost_beta_s=0.1)
        assert s.overhead_s == pytest.approx(f2h.overhead_s)

    def test_chunk_plan_mirrors_bucketed_batch_plan(self):
        from repro.core.bucketing import BucketedEmbedderBackend
        from repro.core.simulator import FanOutModel

        f = FanOutModel(self._base(), 4)
        plan = BucketedEmbedderBackend._batch_plan
        class Stub:  # borrow the real planner with the mesh-floored bucket
            min_batch_bucket = 4
        for batch in (1, 3, 4, 5, 8, 13, 20, 21, 64, 100):
            assert f.chunk_plan(batch) == plan(Stub(), batch), batch

    def test_per_device_rows_set_the_latency(self):
        from repro.core.simulator import FanOutModel

        base = self._base()
        f8 = FanOutModel(base, 8)
        # batch 64 -> one chunk of 64 -> 8 rows per device
        assert f8.latency(64) == pytest.approx(base.latency(8))
        # batch 8 -> 1 row per device
        assert f8.latency(8) == pytest.approx(base.latency(1))

    def test_gather_overhead_scales_with_log_devices(self):
        from repro.core.simulator import FanOutModel

        base = self._base()
        f2 = FanOutModel(base, 2, fanout_beta_s=0.01)
        f8 = FanOutModel(base, 8, fanout_beta_s=0.01)
        assert f2.overhead_s == pytest.approx(0.01)
        assert f8.overhead_s == pytest.approx(0.03)
        assert f8.latency(8) == pytest.approx(base.latency(1) + 0.03)

    def test_multi_chunk_batches_serialize(self):
        from repro.core.simulator import FanOutModel

        base = self._base()
        f4 = FanOutModel(base, 4)
        # 20 -> chunks [16, 4] -> rows 4 then 1, executed back to back
        assert f4.chunk_plan(20) == [16, 4]
        assert f4.latency(20) == pytest.approx(base.latency(4) +
                                               base.latency(1))

    def test_noisy_fanout_takes_the_straggler(self):
        import random

        from repro.core.simulator import FanOutModel

        base = DeviceModel("noisy", beta=0.25, b=0.02, a=0.0, noise_std=0.2)
        f8 = FanOutModel(base, 8)
        rng1, rng2 = random.Random(3), random.Random(3)
        # the straggler max over 8 independent draws dominates one draw
        one = [base.latency(8, rng=rng1) for _ in range(64)]
        fan = [f8.latency(64, rng=rng2) for _ in range(64)]
        assert sum(fan) / len(fan) > sum(one) / len(one)

    def test_estimated_depth_scales_near_linear_with_devices(self):
        from repro.core.cost_model import fanout_efficiency
        from repro.core.estimator import (estimate_depth,
                                          fanout_probe_points)
        from repro.core.simulator import sharded_model

        base = self._base()
        d1, _ = estimate_depth(profile_fn_for(base), 1.0)
        for n in (2, 4, 8):
            m = sharded_model(base, n, fanout_beta_s=0.004)
            dn, _ = estimate_depth(profile_fn_for(m), 1.0,
                                   probe_points=fanout_probe_points(n))
            assert 0.8 <= fanout_efficiency(dn, d1, n) <= 1.1, (n, dn, d1)

    def test_closed_form_matches_estimator_on_linear_base(self):
        from repro.core.cost_model import fanout_depth
        from repro.core.estimator import (estimate_depth,
                                          fanout_probe_points)
        from repro.core.simulator import sharded_model

        base = self._base()
        for n in (2, 8):
            m = sharded_model(base, n, fanout_beta_s=0.005)
            dn, _ = estimate_depth(profile_fn_for(m), 1.0,
                                   probe_points=fanout_probe_points(n))
            closed = fanout_depth(base.b, base.beta, n, 1.0,
                                  overhead_s=m.overhead_s)
            assert abs(dn - closed) <= max(1, n), (n, dn, closed)

    def test_modeled_backend_devices_wraps_the_model(self):
        from repro.core.simulator import FanOutModel
        from repro.core.windve import ModeledBackend

        base = self._base()
        be1 = ModeledBackend(base, embed_dim=4)
        be8 = ModeledBackend(base, embed_dim=4, devices=8)
        assert be1.model is base
        assert isinstance(be8.model, FanOutModel)
        assert be8.model.devices == 8 and "8dev" in be8.name


class TestSeededDeterminism:
    """Every BENCH comparison rests on DES runs being replayable: the same
    seed must reproduce the identical Telemetry.summary(), including noisy
    devices, fan-out straggler sampling and Poisson diurnal arrivals."""

    def _summary(self, seed, trace_seed=11):
        from repro.core.queue_manager import Query  # noqa: F401
        from repro.core.routing import TierSpec
        from repro.core.simulator import sharded_model

        npu = PAPER_DEVICES["atlas-300i-duo/bge"]     # noise_std = 0.03
        cpu = PAPER_DEVICES["kunpeng-920/bge"]        # noise_std = 0.05
        arrivals = diurnal_trace(30, 4.0, 40.0, seed=trace_seed)
        tiers = [TierSpec(NPU, 84, model=sharded_model(npu, 4, 0.004)),
                 TierSpec(CPU, 2, model=cpu)]
        sim = ServingSimulator(tiers=tiers, slo_s=1.0, seed=seed)
        return sim.run(list(arrivals)).summary()

    def test_same_seed_identical_summaries(self):
        a, b = self._summary(seed=7), self._summary(seed=7)
        assert a == b
        assert a["completed"] > 0 and a["p95_s"] > 0.0

    def test_different_sim_seed_changes_noisy_latencies(self):
        a, b = self._summary(seed=7), self._summary(seed=8)
        # same arrivals, different device-noise draws: tails move
        assert a["accepted"] == b["accepted"]
        assert a != b

    def test_different_trace_seed_changes_arrivals(self):
        a = self._summary(seed=7, trace_seed=11)
        b = self._summary(seed=7, trace_seed=12)
        assert a["accepted"] != b["accepted"] or a != b

    def test_diurnal_trace_is_seed_deterministic(self):
        assert diurnal_trace(45, 3, 25, seed=5) == \
            diurnal_trace(45, 3, 25, seed=5)
