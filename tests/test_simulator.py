"""Calibrated device models + discrete-event simulation tests."""
import pytest

from repro.core.queue_manager import CPU, NPU
from repro.core.simulator import (PAPER_DEVICES, DeviceModel, ServingSimulator,
                                  cpu_core_scaled, diurnal_trace,
                                  profile_fn_for, solve_anchors)


class TestCalibration:
    @pytest.mark.parametrize("dev,c1,c2", [
        ("tesla-v100/bge", 44, 96), ("xeon-e5-2690/bge", 8, 22),
        ("atlas-300i-duo/bge", 84, 172), ("kunpeng-920/bge", 2, 8),
        ("tesla-v100/jina", 48, 112), ("xeon-e5-2690/jina", 11, 30),
    ])
    def test_anchors_hit_exactly(self, dev, c1, c2):
        d = PAPER_DEVICES[dev]
        assert d.latency(c1) == pytest.approx(1.0, abs=1e-9)
        assert d.latency(c2) == pytest.approx(2.0, abs=1e-9)

    def test_convexity_nonnegative(self):
        for d in PAPER_DEVICES.values():
            assert d.a >= -1e-12 and d.b > 0

    def test_solve_anchors_roundtrip(self):
        b, a = solve_anchors(0.3, 10, 1.0, 40, 2.0)
        assert 0.3 + b * 10 + a * 100 == pytest.approx(1.0)
        assert 0.3 + b * 40 + a * 1600 == pytest.approx(2.0)

    def test_length_scaling_monotone(self):
        d = PAPER_DEVICES["tesla-v100/bge"]
        assert d.latency(44, length=500) > d.latency(44, length=75)
        assert d.latency(44, length=75) == pytest.approx(1.0)

    def test_core_scaling(self):
        cpu = PAPER_DEVICES["xeon-e5-2690/bge"]
        fewer = cpu_core_scaled(cpu, cores=22, full_cores=44)
        assert fewer.latency(8) > cpu.latency(8)
        assert fewer.beta == cpu.beta          # model-load cost unchanged


class TestDES:
    def test_burst_within_capacity_no_violations(self):
        npu = PAPER_DEVICES["tesla-v100/bge"]
        cpu = PAPER_DEVICES["xeon-e5-2690/bge"]
        r = ServingSimulator(npu, cpu, 96, 22, slo_s=2.0).run_burst(118)
        assert r.accepted == 118
        assert r.rejected == 0
        assert r.violations == 0

    def test_offload_expands_concurrency_22_9_pct(self):
        """The paper's Table 1 @2s: 96 -> 118 (+22.9%)."""
        npu = PAPER_DEVICES["tesla-v100/bge"]
        cpu = PAPER_DEVICES["xeon-e5-2690/bge"]
        base = ServingSimulator(npu, None, 96, 0, slo_s=2.0).run_burst(140)
        wind = ServingSimulator(npu, cpu, 96, 22, slo_s=2.0).run_burst(140)
        assert base.max_ok_concurrency == 96
        assert wind.max_ok_concurrency == 118
        uplift = (wind.max_ok_concurrency - base.max_ok_concurrency) / \
            base.max_ok_concurrency
        assert uplift == pytest.approx(22 / 96, abs=1e-9)

    def test_overload_rejects_rather_than_violates(self):
        npu = PAPER_DEVICES["tesla-v100/bge"]
        cpu = PAPER_DEVICES["xeon-e5-2690/bge"]
        r = ServingSimulator(npu, cpu, 96, 22, slo_s=2.0).run_burst(200)
        assert r.rejected == 200 - 118
        assert r.violations == 0

    def test_sequential_arrivals_reuse_queue(self):
        npu = PAPER_DEVICES["tesla-v100/bge"]
        sim = ServingSimulator(npu, None, 44, 0, slo_s=1.0)
        arrivals = [(3.0 * i, 75) for i in range(5)]   # fully spaced out
        r = sim.run(arrivals)
        assert r.accepted == 5 and r.rejected == 0
        assert all(q.e2e_latency <= 1.0 + 1e-9 for q in r.completed)

    def test_diurnal_trace_shape(self):
        tr = diurnal_trace(60, base_rate=2, peak_rate=20, seed=3)
        assert all(0 <= t <= 60 for t, _ in tr)
        assert [t for t, _ in tr] == sorted(t for t, _ in tr)
        # peak half of the day should carry more traffic than the trough half
        mid = [t for t, _ in tr if 15 <= t < 45]
        edge = [t for t, _ in tr if t < 15 or t >= 45]
        assert len(mid) > len(edge)
