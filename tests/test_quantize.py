"""Int8 weight-only quantized serving path: the load-time transform, the
dense-apply routing, backend/engine composition, per-bucket Eq. 12 fits and
the vectorized tokenizer.

Kernel-level sweeps of ``quant_matmul`` (Pallas interpret vs jnp oracle)
live in ``test_kernels``; this file owns the serving semantics."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import perf_flags
from repro.configs import get_config
from repro.core.bucketing import BucketedEmbedderBackend, length_bucket_fn
from repro.core.estimator import (LatencyFit, estimate_depth,
                                  estimate_depth_per_bucket, quantized_fit)
from repro.core.routing import (CPU, NPU, LengthAwarePolicy, PredictivePolicy,
                                Query, TierSpec)
from repro.core.sharded_backend import ShardedEmbedderBackend
from repro.core.simulator import PAPER_DEVICES, profile_fn_for, quantized_model
from repro.core.windve import JaxEmbedderBackend, WindVE
from repro.models import embedder, layers as L
from repro.models.quantize import (EMBED_DTYPES, is_quantized, quantize_dense,
                                   quantize_params, serve_params,
                                   wants_act_quant)

KEY = jax.random.PRNGKey(0)
MAX_TOKENS = 64


@pytest.fixture(scope="module")
def bge_smoke():
    cfg = get_config("bge-large-zh-v1.5").smoke()
    params = embedder.init_embedder(KEY, cfg)
    return cfg, params


def queries(lengths, payloads=False, vocab=1000, base_qid=0):
    rng = np.random.default_rng(3)
    return [Query(qid=base_qid + i, length=ln,
                  payload=(rng.integers(1, vocab, ln) if payloads else None))
            for i, ln in enumerate(lengths)]


def min_cosine(a, b):
    return float(((a * b).sum(-1) / (np.linalg.norm(a, axis=-1)
                                     * np.linalg.norm(b, axis=-1))).min())


# ------------------------------------------------------ the transform -----
class TestQuantizeParams:
    def test_per_output_channel_scales_and_roundtrip(self):
        w = jax.random.normal(KEY, (96, 130)) * jnp.linspace(0.1, 4.0, 130)
        q, scale = quantize_dense(w)
        assert q.dtype == jnp.int8 and scale.shape == (130,)
        assert int(jnp.abs(q).max()) <= 127
        # per-channel symmetric: every channel uses its own full int8 range
        assert float(jnp.abs(q).max(axis=0).min()) >= 126
        err = jnp.abs(q.astype(jnp.float32) * scale - w)
        # symmetric round-to-nearest: error <= scale/2 per element
        assert bool((err <= scale[None, :] * 0.5 + 1e-7).all())

    def test_zero_channel_gets_unit_scale(self):
        w = jnp.zeros((8, 4)).at[:, 0].set(1.0)
        q, scale = quantize_dense(w)
        assert float(scale[1]) == 1.0 and int(jnp.abs(q[:, 1:]).max()) == 0

    def test_stacked_blocks_quantize_layerwise(self, bge_smoke):
        cfg, params = bge_smoke
        qp = quantize_params(params)
        blk = qp["blocks"]["attn"]
        # stacked (L, K, N) weights -> int8 + per-(layer, channel) scales
        assert blk["wq"].dtype == jnp.int8
        assert blk["wq_scale"].shape == (cfg.num_layers,
                                         blk["wq"].shape[-1])
        # scales are computed per layer, not shared across the stack
        per_layer = [quantize_dense(params["blocks"]["attn"]["wq"][i])[1]
                     for i in range(cfg.num_layers)]
        np.testing.assert_allclose(np.asarray(blk["wq_scale"]),
                                   np.stack(per_layer), rtol=1e-6)

    def test_non_dense_leaves_stay_float(self, bge_smoke):
        cfg, params = bge_smoke
        qp = quantize_params(params)
        assert qp["embed"].dtype == params["embed"].dtype        # gather
        assert qp["final_norm"]["scale"].dtype != jnp.int8
        assert qp["blocks"]["norm1"]["scale"].dtype != jnp.int8
        assert is_quantized(qp) and not is_quantized(params)

    def test_moe_expert_stacks_excluded(self):
        """Expert-stacked weights reuse dense names but bypass dense_apply
        (einsum dispatch) — quantizing them would silently drop the dequant
        scale.  Their extra expert dim is what excludes them, standalone
        (E, D, F) and layer-stacked (L, E, D, F) alike."""
        cfg = get_config("qwen3-moe-30b-a3b").smoke()
        moe = L.init_moe(KEY, cfg, jnp.float32)
        stacked = jax.vmap(lambda _: moe)(jnp.arange(2))   # (L, E, D, F)
        for p in ({"moe": moe}, {"blocks": {"moe": stacked}}):
            qp = quantize_params(p)
            leaf = (qp.get("moe") or qp["blocks"]["moe"])
            assert leaf["w_gate"].dtype != jnp.int8
            assert "w_gate_scale" not in leaf

    def test_serve_params_policies(self, bge_smoke):
        cfg, params = bge_smoke
        t32, c32 = serve_params(params, "fp32")
        assert t32 is params and c32 == jnp.float32
        tb, cb = serve_params(params, "bf16")
        assert tb["embed"].dtype == jnp.bfloat16 and cb == jnp.bfloat16
        t8, c8 = serve_params(params, "int8")
        assert is_quantized(t8) and c8 == jnp.float32
        ta, ca = serve_params(params, "int8_w8a8")
        assert is_quantized(ta) and ca == jnp.float32
        with pytest.raises(ValueError, match="fp32|bf16|int8"):
            serve_params(params, "fp16")
        assert set(EMBED_DTYPES) == {"fp32", "bf16", "int8", "int8_w8a8"}
        assert wants_act_quant("int8_w8a8")
        assert not any(wants_act_quant(d) for d in ("fp32", "bf16", "int8",
                                                    None))

    def test_unknown_embed_dtype_rejected_both_spellings(self, bge_smoke):
        """Both entry points name the FULL valid set (incl. int8_w8a8) when
        rejecting a policy: serve_params at backend construction and
        parse_opt at the CLI."""
        cfg, params = bge_smoke
        with pytest.raises(ValueError) as e1:
            serve_params(params, "w8a8")
        with pytest.raises(ValueError) as e2:
            perf_flags.parse_opt("embed_dtype=w8a8")
        for err in (str(e1.value), str(e2.value)):
            for valid in EMBED_DTYPES:
                assert valid in err
            assert "w8a8'" in err or "'w8a8'" in err
        # the value check guards parse time, not just first backend build
        with pytest.raises(ValueError, match="int8_w8a8"):
            perf_flags.parse_opt("embed_donate=1,embed_dtype=int9")


# ------------------------------------------------- dense-apply routing ----
class TestDenseApplyRouting:
    def test_float_path_unchanged(self):
        p = {"wq": jax.random.normal(KEY, (32, 48))}
        x = jax.random.normal(KEY, (4, 32))
        np.testing.assert_array_equal(
            np.asarray(L.dense_apply(p, "wq", x)),
            np.asarray(x @ p["wq"]))

    def test_quantized_path_close_to_float(self):
        w = jax.random.normal(KEY, (64, 96))
        q, s = quantize_dense(w)
        p = {"wo": q, "wo_scale": s}
        x = jax.random.normal(KEY, (8, 64))
        got = np.asarray(L.dense_apply(p, "wo", x))
        want = np.asarray(x @ w)
        assert np.abs(got - want).max() <= 0.05 * np.abs(want).max()

    def test_act_quant_routes_w8a8(self, monkeypatch):
        """With a scale sibling AND act_quant on, dense_apply must take the
        W8A8 kernel (and stay on weight-only / plain matmul otherwise)."""
        from repro.kernels.quant_matmul import ops as qm_ops

        calls = []
        monkeypatch.setattr(
            qm_ops, "quant_matmul_w8a8",
            lambda x, w8, s, **kw: calls.append("w8a8") or x @ w8.astype(
                x.dtype))
        monkeypatch.setattr(
            qm_ops, "quant_matmul",
            lambda x, w8, s, **kw: calls.append("w8") or x @ w8.astype(
                x.dtype))
        w = jax.random.normal(KEY, (16, 24))
        q, s = quantize_dense(w)
        x = jax.random.normal(KEY, (4, 16))
        L.dense_apply({"wq": q, "wq_scale": s}, "wq", x, act_quant=True)
        L.dense_apply({"wq": q, "wq_scale": s}, "wq", x, act_quant=False)
        L.dense_apply({"wq": w}, "wq", x, act_quant=True)   # float: no-op
        assert calls == ["w8a8", "w8"]

    def test_w8a8_path_close_to_float(self):
        w = jax.random.normal(KEY, (64, 96))
        q, s = quantize_dense(w)
        p = {"wo": q, "wo_scale": s}
        x = jax.random.normal(KEY, (8, 64))
        got = np.asarray(L.dense_apply(p, "wo", x, act_quant=True))
        want = np.asarray(x @ w)
        assert np.abs(got - want).max() <= 0.05 * np.abs(want).max()
        assert got.dtype == want.dtype

    @pytest.mark.parametrize("model,pool", [("bge-large-zh-v1.5", "cls"),
                                            ("jina-v2", "mean")])
    @pytest.mark.parametrize("dtype,bar", [("int8", 0.99),
                                           ("int8_w8a8", 0.98)])
    def test_embedder_quantized_cosine_parity(self, model, pool, dtype, bar):
        """Acceptance guard: int8 trunk >= 0.99 and W8A8 trunk >= 0.98
        cosine vs the fp32 oracle for BOTH paper model families (cls and
        mean pooling)."""
        cfg = get_config(model).smoke()
        assert cfg.pool == pool
        params = embedder.init_embedder(KEY, cfg)
        qp, cdt = serve_params(params, dtype)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 40), 1,
                                  cfg.vocab_size)
        mask = (jnp.arange(40)[None, :] <
                jnp.asarray([[40], [22], [9], [33]])).astype(jnp.float32)
        a = np.asarray(embedder.embed(params, cfg, toks, mask,
                                      compute_dtype=jnp.float32))
        b = np.asarray(embedder.embed(qp, cfg, toks, mask,
                                      compute_dtype=cdt,
                                      act_quant=wants_act_quant(dtype)))
        assert b.dtype == np.float32
        np.testing.assert_allclose(np.linalg.norm(b, axis=-1), 1.0,
                                   atol=1e-3)
        assert min_cosine(a, b) >= bar


# ------------------------------------------------- serving backends -------
class TestInt8Backends:
    def test_all_three_backends_agree(self, bge_smoke):
        """Fixed, bucketed and 1-device sharded int8 paths serve the same
        vectors (the bucketed/sharded degrade contract, quantized)."""
        cfg, params = bge_smoke
        qs = queries([12, 30, 55, 20, 44, 9], payloads=True,
                     vocab=cfg.vocab_size)
        fix = JaxEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                 dtype="int8")
        buck = BucketedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                       min_seq_bucket=8, dtype="int8")
        shard = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                       min_seq_bucket=8, dtype="int8")
        a = np.stack(fix.embed_batch(qs))
        b = np.stack(buck.embed_batch(qs))
        c = np.stack(shard.embed_batch(qs))
        np.testing.assert_allclose(a, b, atol=1e-5)
        np.testing.assert_allclose(b, c, atol=1e-5)
        assert "int8" in fix.name and "int8" in buck.name \
            and "int8" in shard.name

    def test_sharded_int8_parity_and_footprint(self, bge_smoke):
        cfg, params = bge_smoke
        oracle = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                        dtype="fp32")
        i8 = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                    dtype="int8")
        qs = queries([12, 30, 55, 20, 44, 9], payloads=True,
                     vocab=cfg.vocab_size)
        a = np.stack(oracle.embed_batch(qs))
        b = np.stack(i8.embed_batch(qs))
        assert a.dtype == b.dtype == np.float32
        assert min_cosine(a, b) >= 0.99
        # weight-only: projections are 1 byte/element, so the resident tree
        # shrinks (the smoke embed table is fp32 and relatively large)
        assert i8.params_nbytes < 0.5 * oracle.params_nbytes
        assert i8.serve_dtype == jnp.float32          # fp32 activations

    def test_prewarm_then_zero_serving_retraces(self, bge_smoke):
        cfg, params = bge_smoke
        be = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                    min_seq_bucket=8, dtype="int8",
                                    donate=True, async_dispatch=True)
        grid = be.warm_grid(max_batch=4)
        n = be.prewarm(grid)
        assert n == len(grid) == be.traces
        for lens in ([5], [9, 9], [40, 33, 20], [7, 7, 7, 60]):
            be.embed_batch(queries(lens))
        assert be.traces == n, "int8 serving retraced despite prewarm"
        assert be.bucket_hits > 0

    def test_flag_selects_int8_default(self, bge_smoke):
        cfg, params = bge_smoke
        try:
            perf_flags.set_flags(embed_dtype="int8")
            be = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS)
            assert be.dtype == "int8"
            assert is_quantized(be.params)
        finally:
            perf_flags.reset_flags()

    def test_parse_opt_int8_roundtrip(self):
        kw = perf_flags.parse_opt("embed_dtype=int8,embed_donate=1,"
                                  "embed_async=1")
        assert kw["embed_dtype"] == "int8"
        flags = perf_flags.set_flags(**kw)
        assert flags.embed_dtype == "int8"
        perf_flags.reset_flags()

    def test_engine_serves_int8_with_bucketing_async_donate(self, bge_smoke):
        """embed_dtype=int8 composes with donation, async dispatch and
        length-aware bucketed batch formation under the real engine; every
        future receives ITS query's embedding (>= 0.99 cosine vs the fp32
        oracle serving the same payload)."""
        cfg, params = bge_smoke
        be = ShardedEmbedderBackend(cfg, params, max_tokens=32,
                                    min_seq_bucket=8, dtype="int8",
                                    donate=True, async_dispatch=True)
        oracle = ShardedEmbedderBackend(cfg, params, max_tokens=32,
                                        min_seq_bucket=8, dtype="fp32")
        rng = np.random.default_rng(11)
        payloads = [rng.integers(1, cfg.vocab_size, 20) for _ in range(12)]
        ve = WindVE(tiers=[TierSpec(NPU, 64, backend=be, max_batch=3,
                                    bucket_fn=length_bucket_fn(8, 32))])
        try:
            futs = [ve.submit(payload=p, length=len(p)) for p in payloads]
            got = [f.result(timeout=60) for f in futs]
        finally:
            ve.shutdown()
        want = oracle.embed_batch(
            [Query(qid=100 + i, payload=p, length=len(p))
             for i, p in enumerate(payloads)])
        for g, w in zip(got, want):
            assert min_cosine(np.asarray(g)[None], np.asarray(w)[None]) \
                >= 0.99


# ------------------------------------------------- W8A8 serving ----------
class TestW8A8Backends:
    def test_all_three_backends_agree(self, bge_smoke):
        """Fixed, bucketed and 1-device sharded W8A8 paths serve the same
        vectors (the bucketed/sharded degrade contract, fully quantized)."""
        cfg, params = bge_smoke
        qs = queries([12, 30, 55, 20, 44, 9], payloads=True,
                     vocab=cfg.vocab_size)
        fix = JaxEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                 dtype="int8_w8a8")
        buck = BucketedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                       min_seq_bucket=8, dtype="int8_w8a8")
        shard = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                       min_seq_bucket=8, dtype="int8_w8a8")
        assert fix.act_quant and buck.act_quant and shard.act_quant
        a = np.stack(fix.embed_batch(qs))
        b = np.stack(buck.embed_batch(qs))
        c = np.stack(shard.embed_batch(qs))
        np.testing.assert_allclose(a, b, atol=1e-5)
        np.testing.assert_allclose(b, c, atol=1e-5)
        assert "int8_w8a8" in fix.name and "int8_w8a8" in buck.name \
            and "int8_w8a8" in shard.name

    def test_sharded_w8a8_parity_and_footprint(self, bge_smoke):
        cfg, params = bge_smoke
        oracle = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                        dtype="fp32")
        i8 = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                    dtype="int8")
        w8a8 = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                      dtype="int8_w8a8")
        qs = queries([12, 30, 55, 20, 44, 9], payloads=True,
                     vocab=cfg.vocab_size)
        a = np.stack(oracle.embed_batch(qs))
        b = np.stack(w8a8.embed_batch(qs))
        assert a.dtype == b.dtype == np.float32
        np.testing.assert_allclose(np.linalg.norm(b, axis=-1), 1.0,
                                   atol=1e-3)
        assert min_cosine(a, b) >= 0.98
        # same resident tree as weight-only int8 — activation quantization
        # is a trace-time choice, not a second copy of the weights
        assert w8a8.params_nbytes == i8.params_nbytes
        assert w8a8.serve_dtype == jnp.float32   # trunk dequantizes to fp32
        assert not oracle.act_quant and not i8.act_quant and w8a8.act_quant

    def test_prewarm_then_zero_serving_retraces(self, bge_smoke):
        """W8A8 composes with donation + async dispatch + bucketing and the
        dynamic activation quantization does NOT add steady-state retraces
        (the per-batch scales are traced values, not cache keys)."""
        cfg, params = bge_smoke
        be = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                    min_seq_bucket=8, dtype="int8_w8a8",
                                    donate=True, async_dispatch=True)
        grid = be.warm_grid(max_batch=4)
        n = be.prewarm(grid)
        assert n == len(grid) == be.traces
        for lens in ([5], [9, 9], [40, 33, 20], [7, 7, 7, 60]):
            be.embed_batch(queries(lens))
        assert be.traces == n, "w8a8 serving retraced despite prewarm"
        assert be.bucket_hits > 0

    def test_flag_selects_w8a8_default(self, bge_smoke):
        cfg, params = bge_smoke
        try:
            perf_flags.set_flags(embed_dtype="int8_w8a8")
            be = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS)
            assert be.dtype == "int8_w8a8"
            assert is_quantized(be.params) and be.act_quant
        finally:
            perf_flags.reset_flags()

    def test_parse_opt_w8a8_roundtrip(self):
        kw = perf_flags.parse_opt("embed_dtype=int8_w8a8,embed_donate=1,"
                                  "embed_async=1")
        assert kw["embed_dtype"] == "int8_w8a8"
        flags = perf_flags.set_flags(**kw)
        assert flags.embed_dtype == "int8_w8a8"
        perf_flags.reset_flags()

    def test_engine_serves_w8a8_with_bucketing_async_donate(self, bge_smoke):
        """embed_dtype=int8_w8a8 composes with donation, async dispatch and
        length-aware bucketed batch formation under the real engine; every
        future receives ITS query's embedding (>= 0.98 cosine vs the fp32
        oracle serving the same payload)."""
        cfg, params = bge_smoke
        be = ShardedEmbedderBackend(cfg, params, max_tokens=32,
                                    min_seq_bucket=8, dtype="int8_w8a8",
                                    donate=True, async_dispatch=True)
        oracle = ShardedEmbedderBackend(cfg, params, max_tokens=32,
                                        min_seq_bucket=8, dtype="fp32")
        rng = np.random.default_rng(11)
        payloads = [rng.integers(1, cfg.vocab_size, 20) for _ in range(12)]
        ve = WindVE(tiers=[TierSpec(NPU, 64, backend=be, max_batch=3,
                                    bucket_fn=length_bucket_fn(8, 32))])
        try:
            futs = [ve.submit(payload=p, length=len(p)) for p in payloads]
            got = [f.result(timeout=60) for f in futs]
        finally:
            ve.shutdown()
        want = oracle.embed_batch(
            [Query(qid=100 + i, payload=p, length=len(p))
             for i, p in enumerate(payloads)])
        for g, w in zip(got, want):
            assert min_cosine(np.asarray(g)[None], np.asarray(w)[None]) \
                >= 0.98


# ------------------------------------- quantized-tier calibration ---------
class TestQuantizedCalibration:
    """Satellite: the measured W8A8 ``beta_s`` feeds back into the Eq. 12
    machinery, so depth estimation and predictive dispatch price the
    quantized tier correctly."""

    def test_quantized_fit_scales_slope_only(self):
        fit = LatencyFit(alpha=0.1, beta=0.3, r2=0.99)
        qf = quantized_fit(fit, 0.6)
        assert qf.alpha == pytest.approx(0.06)
        assert qf.beta == fit.beta and qf.r2 == fit.r2
        with pytest.raises(ValueError):
            quantized_fit(fit, 0.0)

    def test_w8a8_modeled_depth_at_least_fp32(self):
        """Fitted depth for the W8A8-modeled backend >= fp32 depth on the
        same device (and strictly greater when the slope actually binds)."""
        dev = PAPER_DEVICES["xeon-e5-2690/bge"]
        d_f32, fit_f32 = estimate_depth(profile_fn_for(dev), 2.0)
        q = quantized_model(dev, 0.6)
        d_q, fit_q = estimate_depth(profile_fn_for(q), 2.0)
        assert d_q >= d_f32 > 0
        assert d_q > d_f32          # 0.6x slope must buy real depth at 2s
        assert fit_q.alpha < fit_f32.alpha
        # the offline shortcut (scale the fp32 fit) prices the quantized
        # tier like re-profiling the scaled device does
        short = quantized_fit(fit_f32, 0.6)
        assert short.max_concurrency(2.0) > d_f32
        assert short.max_concurrency(2.0) == pytest.approx(d_q, rel=0.15)
        with pytest.raises(ValueError):
            quantized_model(dev, -1.0)

    def test_per_bucket_w8a8_depths_dominate_fp32(self):
        dev = PAPER_DEVICES["xeon-e5-2690/bge"]
        q = quantized_model(dev, 0.5)

        def profile(d):
            return lambda c, length: d.latency(c, length)

        f32 = estimate_depth_per_bucket(profile(dev), 2.0, [16, 64, 128])
        w8 = estimate_depth_per_bucket(profile(q), 2.0, [16, 64, 128])
        assert all(w8[b][0] >= f32[b][0] for b in (16, 64, 128))
        assert any(w8[b][0] > f32[b][0] for b in (16, 64, 128))

    def test_predictive_policy_prefers_w8a8_tier_at_equal_backlog(self):
        """Two CPU tiers, same device, one serving W8A8: at equal backlog
        the predictive policy must order the quantized tier first."""
        from repro.core.queue_manager import QueueManager

        base = LatencyFit(alpha=0.2, beta=0.3, r2=1.0)
        pol = PredictivePolicy(fits={CPU: base,
                                     "CPU-w8a8": quantized_fit(base, 0.5)})
        tiers = [TierSpec(CPU, 8), TierSpec("CPU-w8a8", 8)]
        qm = QueueManager(tiers)
        for i in range(3):      # equal backlog on both tiers
            assert qm.queues[CPU].push(Query(qid=i, length=20))
            assert qm.queues["CPU-w8a8"].push(Query(qid=10 + i, length=20))
        order = pol.candidates(Query(qid=99, length=20), tiers, qm)
        assert order[0] == "CPU-w8a8"


# ---------------------------------------------- per-bucket Eq. 12 fits ----
class TestPerBucketDepths:
    def test_per_bucket_fits_recover_linear_curves(self):
        # alpha grows with bucket length (Fig. 5's collapse), beta fixed
        def profile(c, length):
            return 0.001 * length * c + 0.05

        fits = estimate_depth_per_bucket(profile, 1.0, [16, 64, 128],
                                         probe_points=(1, 2, 4, 8))
        assert set(fits) == {16, 64, 128}
        d16, f16 = fits[16]
        d128, f128 = fits[128]
        assert f16.alpha == pytest.approx(0.016, rel=1e-6)
        assert f128.alpha == pytest.approx(0.128, rel=1e-6)
        assert d16 > d128          # short buckets sustain deeper queues
        assert d16 == int((1.0 - 0.05) / 0.016)

    def test_threshold_from_first_collapsed_bucket(self):
        pol = LengthAwarePolicy.from_bucket_depths({16: 40, 32: 9, 64: 0,
                                                    128: 0})
        # queries round UP into their bucket, so anything ABOVE the last
        # live bucket (32) pads into the dead 64-bucket and must be long
        assert pol.long_threshold == 33
        tiers = [TierSpec(NPU, 4), TierSpec("CPU", 4)]
        assert pol.candidates(Query(qid=1, length=40), tiers, None) == [NPU]
        assert pol.candidates(Query(qid=2, length=32), tiers, None) \
            == [NPU, "CPU"]

    def test_threshold_when_smallest_bucket_collapses(self):
        # every length pads into a dead bucket -> every query is long
        pol = LengthAwarePolicy.from_bucket_depths({16: 0, 32: 0})
        assert pol.long_threshold == 1
        tiers = [TierSpec(NPU, 4), TierSpec("CPU", 4)]
        assert pol.candidates(Query(qid=1, length=2), tiers, None) == [NPU]

    def test_threshold_when_no_bucket_collapses(self):
        # unprofiled lengths must not ride the slow tier on faith
        pol = LengthAwarePolicy.from_bucket_depths({16: 40, 96: 5})
        assert pol.long_threshold == 97

    def test_empty_depths_rejected(self):
        with pytest.raises(ValueError):
            LengthAwarePolicy.from_bucket_depths({})

    def test_real_backend_bucket_curves_are_monotone_in_length(self,
                                                               bge_smoke):
        """On the real int8 backend a longer bucket costs at least as much
        per batch (warm, best-of-3) — the structure the per-bucket fits
        feed into the policy."""
        import time as _t

        cfg, params = bge_smoke
        be = BucketedEmbedderBackend(cfg, params, max_tokens=128,
                                     min_seq_bucket=16, dtype="int8")

        def profile(c, length):
            batch = queries([length] * c, base_qid=length * 100)
            be.embed_batch(batch)          # warm this (c, length) bucket
            best = float("inf")
            for _ in range(3):
                t0 = _t.monotonic()
                be.embed_batch(batch)
                best = min(best, _t.monotonic() - t0)
            return best

        t16 = profile(4, 16)
        t128 = profile(4, 128)
        assert t128 > t16 * 1.5


# ------------------------------------------------ vectorized tokenizer ----
class TestVectorizedTokenize:
    @staticmethod
    def _reference(cfg, qs, seq_len):
        toks = np.zeros((len(qs), seq_len), np.int32)
        mask = np.zeros((len(qs), seq_len), np.float32)
        real = truncated = 0
        for i, q in enumerate(qs):
            ids = q.payload
            if ids is None:
                ids = (np.arange(q.length) % (cfg.vocab_size - 1)) + 1
            if len(ids) > seq_len:
                truncated += 1
            n = min(len(ids), seq_len)
            toks[i, :n] = np.asarray(ids[:n], np.int32)
            mask[i, :n] = 1.0
            real += n
        return toks, mask, real, truncated

    def test_matches_loop_reference_mixed_batch(self, bge_smoke):
        cfg, params = bge_smoke
        be = JaxEmbedderBackend(cfg, params, max_tokens=32)
        rng = np.random.default_rng(5)
        qs = [Query(qid=1, length=10),                       # synthetic
              Query(qid=2, length=40,
                    payload=rng.integers(1, 500, 40)),       # truncated
              Query(qid=3, length=50),                       # synth trunc
              Query(qid=4, length=3, payload=[7, 8, 9]),     # list payload
              Query(qid=5, length=32,
                    payload=rng.integers(1, 500, 32))]       # exact fit
        got = be._tokenize(qs, 32)
        want = self._reference(cfg, qs, 32)
        np.testing.assert_array_equal(got[0], want[0])
        np.testing.assert_array_equal(got[1], want[1])
        assert got[2:] == want[2:]

    def test_out_buffer_rows_beyond_batch_zeroed(self, bge_smoke):
        cfg, params = bge_smoke
        be = JaxEmbedderBackend(cfg, params, max_tokens=32)
        out = (np.full((6, 16), 9, np.int32), np.full((6, 16), 9.0,
                                                      np.float32))
        qs = queries([10, 12], payloads=True, vocab=400)
        toks, mask, real, trunc = be._tokenize(qs, 16, out=out)
        assert toks is out[0] and mask is out[1]
        assert (toks[2:] == 0).all() and (mask[2:] == 0.0).all()
        want = self._reference(cfg, qs, 16)
        np.testing.assert_array_equal(toks[:2], want[0])
        assert (real, trunc) == want[2:]

    def test_empty_batch(self, bge_smoke):
        cfg, params = bge_smoke
        be = JaxEmbedderBackend(cfg, params, max_tokens=32)
        toks, mask, real, trunc = be._tokenize([], 16)
        assert toks.shape == (0, 16) and real == 0 and trunc == 0
