"""Beyond-paper extensions: online re-calibration + LM generation serving."""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.adaptive import OnlineCalibrator, attach
from repro.core.llm_backend import LMGenerateBackend
from repro.core.queue_manager import CPU, NPU, Query
from repro.core.simulator import DeviceModel
from repro.core.windve import ModeledBackend, WindVE
from repro.models import api


class TestOnlineCalibrator:
    def test_refit_recovers_line(self):
        cal = OnlineCalibrator(slo_s=1.0, min_points=4, headroom=1.0)
        for c in (1, 2, 4, 8, 4, 2, 8, 1):
            cal.observe("NPU", c, 0.02 * c + 0.2)
        depth, fit = cal.suggest_depth("NPU", current=10)
        assert fit is not None
        assert fit.alpha == pytest.approx(0.02, abs=1e-6)
        assert depth == 40

    def test_uninformative_window_keeps_current(self):
        cal = OnlineCalibrator(slo_s=1.0)
        for _ in range(20):
            cal.observe("NPU", 4, 0.3)    # single concurrency level
        depth, fit = cal.suggest_depth("NPU", current=7)
        assert depth == 7 and fit is None

    def test_attached_engine_adapts_depth(self):
        # device drifts slower than the initial (wrong) depth assumes
        slow = DeviceModel("drifty", beta=0.05, b=0.05, a=0.0)
        ve = WindVE(ModeledBackend(slow, embed_dim=4), None,
                    npu_depth=40, cpu_depth=0)   # 40 would breach a 0.6s SLO
        try:
            cal = OnlineCalibrator(slo_s=0.6, min_points=2, headroom=1.0)
            attach(ve, cal, refit_every=1)
            for wave in (1, 3, 1, 6, 2):   # distinct batch sizes per wave
                futs = [ve.submit(length=75) for _ in range(wave)]
                for f in futs:
                    if f is not None:
                        f.result(timeout=30)
                time.sleep(0.05)           # let the worker go idle
            # true depth at 0.6s SLO: (0.6-0.05)/0.05 = 11
            assert ve.qm.queues[NPU].depth < 40
            assert ve.qm.queues[NPU].depth >= 1
        finally:
            ve.shutdown()


class TestLMServing:
    @pytest.fixture(scope="class")
    def backend(self):
        cfg = get_config("stablelm-1.6b").smoke()
        params = api.init_params(jax.random.PRNGKey(0), cfg)
        return LMGenerateBackend(cfg, params, max_prompt=16, max_new_tokens=4)

    def test_generate_batch_shapes(self, backend):
        qs = [Query(qid=i, length=8) for i in range(3)]
        outs = backend.embed_batch(qs)
        assert len(outs) == 3
        for o in outs:
            assert o.shape == (4,)
            assert o.dtype == np.int32
            assert (o >= 0).all() and (o < backend.cfg.vocab_size).all()

    def test_lm_behind_windve_queue_manager(self, backend):
        """The paper's technique applied to an assigned arch: Algorithm-1
        dispatch + BUSY semantics around token generation."""
        ve = WindVE(backend, None, npu_depth=2, cpu_depth=0)
        try:
            futs = [ve.submit(length=8) for _ in range(4)]
            accepted = [f for f in futs if f is not None]
            assert len(accepted) == 2 and ve.stats.rejected == 2
            outs = [f.result(timeout=120) for f in accepted]
            assert all(o.shape == (4,) for o in outs)
        finally:
            ve.shutdown()

    def test_greedy_matches_direct_decode(self, backend):
        """Backend generation == direct prefill+decode loop."""
        import jax.numpy as jnp
        from repro.models import lm
        cfg, params = backend.cfg, backend.params
        ids = np.arange(2, 10, dtype=np.int32)
        out = backend.embed_batch([Query(qid=1, payload=ids, length=8)])[0]
        toks = np.ones((1, 16), np.int32)
        toks[0, -8:] = ids
        logits, cache = lm.prefill(params, cfg, jnp.asarray(toks),
                                   max_len=20, cache_dtype=jnp.float32)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        want = [int(tok[0])]
        for _ in range(3):
            lg, cache = lm.decode_step(params, cfg, tok, cache)
            tok = jnp.argmax(lg, -1).astype(jnp.int32)
            want.append(int(tok[0]))
        assert list(out) == want


def test_multi_worker_pool_drains_in_parallel():
    slow = DeviceModel("slow", beta=0.2, b=0.0, a=0.0)
    # 4 queries, depth 4, batches of 1: 1 worker ~0.8s, 4 workers ~0.2s
    t0 = time.monotonic()
    ve = WindVE(ModeledBackend(slow, embed_dim=2), None, npu_depth=4,
                cpu_depth=0, max_batch={NPU: 1}, workers={NPU: 4})
    try:
        futs = [ve.submit() for _ in range(4)]
        for f in futs:
            f.result(timeout=10)
        elapsed = time.monotonic() - t0
        assert elapsed < 0.7, f"parallel workers too slow: {elapsed}"
    finally:
        ve.shutdown()
