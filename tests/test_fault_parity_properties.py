"""Engine-vs-DES fault parity: seeded fault schedules, identical counters.

The fault-tolerance layer lives in the shared scheduling core, so both
drivers must agree not just on routing (``test_parity_properties``) but on
*failure accounting*: under the same ordinal :class:`FaultPlan` (the
deterministic parity vocabulary — batch ordinals, not wall time) the
threaded engine and the DES must report identical

* ``retries`` / ``backend_errors`` per tier,
* terminal ``failed`` counts (retry exhaustion),
* ``breaker_trips`` (threshold trips are clock-free),
* dispatch verdicts and completion counts.

Determinism notes (same as ``test_parity_properties``): bursts are
submitted under a pinned GIL so the engine's workers drain a static backlog
exactly like the DES drains same-instant arrivals; tier depths exceed the
burst so no BUSY verdict can depend on wall-clock races; breaker cooldowns
are far longer than a run so open tiers stay open on both clocks (the
half-open recovery test drives its clock explicitly with wide margins).
"""
import sys

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.faults import FaultModel, FaultPlan, FaultyBackend
from repro.core.health import CircuitBreaker
from repro.core.routing import DeadlineExceeded, RetryPolicy, TierSpec
from repro.core.simulator import DeviceModel, ServingSimulator
from repro.core.windve import ModeledBackend, WindVE

T0, T1 = "T0", "T1"
BETAS = {T0: 0.05, T1: 0.07}
LEN = 16


def models():
    return {n: DeviceModel(n, beta=b, b=0.0, a=0.0)
            for n, b in BETAS.items()}


def pinned_burst(ve, n, **kw):
    old = sys.getswitchinterval()
    sys.setswitchinterval(5.0)
    try:
        return [ve.submit(length=LEN, **kw) for _ in range(n)]
    finally:
        sys.setswitchinterval(old)


def drain(futs, timeout=30):
    """(completions, failures) over a burst's futures — bounded wait."""
    done = fail = 0
    for f in futs:
        if f is None:
            continue
        try:
            f.result(timeout=timeout)
            done += 1
        except Exception:
            fail += 1
    return done, fail


def counters(t):
    """The fault-accounting record both drivers must agree on."""
    return {
        "dispatched": dict(t.dispatched),
        "rejected": t.rejected,
        "completed": t.n_completed,
        "per_device": dict(t.per_device),
        "deadline_misses": dict(t.deadline_misses),
        "retries": dict(t.retries),
        "backend_errors": dict(t.backend_errors),
        "breaker_trips": dict(t.breaker_trips),
        "breaker_recoveries": dict(t.breaker_recoveries),
        "failed": t.failed,
    }


def breaker():
    # cooldown far beyond any run: a trip stays a trip on either clock
    return CircuitBreaker(failure_threshold=2, cooldown_s=1000.0)


def engine_run(plan, retry, n, max_batch, depth):
    m = models()
    ve = WindVE(
        tiers=[TierSpec(T0, depth,
                        backend=FaultyBackend(
                            ModeledBackend(m[T0], embed_dim=4), plan=plan),
                        max_batch=max_batch, breaker=breaker()),
               TierSpec(T1, depth,
                        backend=ModeledBackend(m[T1], embed_dim=4),
                        max_batch=max_batch, breaker=breaker())],
        retry=retry)
    try:
        done, fail = drain(pinned_burst(ve, n))
        out = counters(ve.stats)
        out["client_done"], out["client_fail"] = done, fail
    finally:
        ve.shutdown()
    return out


def des_run(plan, retry, n, max_batch, depth):
    m = models()
    sim = ServingSimulator(
        tiers=[TierSpec(T0, depth, model=m[T0], max_batch=max_batch,
                        breaker=breaker()),
               TierSpec(T1, depth, model=m[T1], max_batch=max_batch,
                        breaker=breaker())],
        slo_s=100.0, retry=retry, faults={T0: FaultModel(plan=plan)})
    res = sim.run([(0.0, LEN)] * n)
    out = counters(res)
    out["client_done"], out["client_fail"] = res.n_completed, res.failed
    return out


CONFIG = st.tuples(
    st.lists(st.integers(min_value=0, max_value=4),   # T0 fail ordinals
             min_size=0, max_size=4),
    st.integers(min_value=0, max_value=3),            # max_retries
    st.integers(min_value=4, max_value=12),           # burst size
    st.sampled_from([1, 2, 4]),                       # max_batch
)


@settings(max_examples=6, deadline=None)
@given(CONFIG)
def test_fault_counters_agree_under_seeded_plans(cfg):
    fails, retries, n, max_batch = cfg
    plan = FaultPlan(fail=frozenset(fails))
    retry = RetryPolicy(max_retries=retries, backoff_s=0.0)
    depth = n + 4          # no BUSY: rejection never hangs on a clock race
    eng = engine_run(plan, retry, n, max_batch, depth)
    des = des_run(plan, retry, n, max_batch, depth)
    assert eng == des, (cfg, eng, des)
    # internal consistency: every accepted query ended exactly one way
    assert eng["client_done"] + eng["client_fail"] == n


def test_dead_on_arrival_parity():
    """deadline_s=0: every query is dead at dispatch in both drivers —
    the ARRIVAL pseudo-tier owns every miss, nothing reaches a queue."""
    n = 5
    m = models()
    ve = WindVE(tiers=[TierSpec(T0, 8,
                               backend=ModeledBackend(m[T0], embed_dim=4))],
                default_deadline_s=0.0)
    try:
        futs = pinned_burst(ve, n)
        for f in futs:
            with pytest.raises(DeadlineExceeded):
                f.result(timeout=5)
        eng = counters(ve.stats)
    finally:
        ve.shutdown()
    sim = ServingSimulator(tiers=[TierSpec(T0, 8, model=m[T0])],
                           slo_s=100.0, deadline_s=0.0)
    des = counters(sim.run([(0.0, LEN)] * n))
    assert eng == des
    assert eng["deadline_misses"] == {"arrival": n}
    assert eng["failed"] == n and eng["dispatched"] == {}


def test_queued_expiry_parity():
    """A deadline that exactly one queued query misses: serial batches of 1
    at 0.3 s/batch, deadline 0.75 s — queries 1-3 serve (the third finishes
    late; lateness is an SLO violation, not a miss), the fourth expires in
    the queue.  Event margins are >= 0.15 s, far above engine jitter."""
    n, beta, deadline = 4, 0.3, 0.75
    model = DeviceModel(T0, beta=beta, b=0.0, a=0.0)
    ve = WindVE(tiers=[TierSpec(T0, 8,
                               backend=ModeledBackend(model, embed_dim=4),
                               max_batch=1)],
                default_deadline_s=deadline)
    try:
        done, fail = drain(pinned_burst(ve, n))
        eng = counters(ve.stats)
    finally:
        ve.shutdown()
    sim = ServingSimulator(tiers=[TierSpec(T0, 8, model=model, max_batch=1)],
                           slo_s=100.0, deadline_s=deadline)
    des = counters(sim.run([(0.0, LEN)] * n))
    assert eng == des
    assert eng["deadline_misses"] == {T0: 1}
    assert eng["completed"] == 3 and eng["failed"] == 1
    assert (done, fail) == (3, 1)


def test_latency_stall_trip_and_recovery_parity():
    """A stalled (not raising) execution trips the latency-EWMA breaker in
    both drivers, and the half-open probe recovery is replayed identically:
    burst 1 stalls and trips T0; after the cooldown, burst 2's first
    dispatch ticks T0 half-open, serves as the probe, and re-closes it."""
    stall, trip_at, cooldown = 0.5, 0.2, 0.5
    plan = FaultPlan(stall={0}, stall_s=stall)
    m = models()

    def mk_breaker():
        return CircuitBreaker(failure_threshold=100, cooldown_s=cooldown,
                              latency_trip_s=trip_at)

    ve = WindVE(
        tiers=[TierSpec(T0, 8,
                        backend=FaultyBackend(
                            ModeledBackend(m[T0], embed_dim=4), plan=plan),
                        max_batch=2, breaker=mk_breaker()),
               TierSpec(T1, 8, backend=ModeledBackend(m[T1], embed_dim=4),
                        max_batch=2)])
    try:
        assert drain(pinned_burst(ve, 2)) == (2, 0)   # stalled, served, trip
        import time
        time.sleep(stall + cooldown + 0.3)            # well past cooldown
        assert drain(pinned_burst(ve, 2)) == (2, 0)   # the probe, re-close
        eng = counters(ve.stats)
    finally:
        ve.shutdown()

    sim = ServingSimulator(
        tiers=[TierSpec(T0, 8, model=m[T0], max_batch=2,
                        breaker=mk_breaker()),
               TierSpec(T1, 8, model=m[T1], max_batch=2)],
        slo_s=100.0, faults={T0: FaultModel(plan=plan)})
    # burst 2 arrives long after stall+cooldown (margins >> jitter)
    des = counters(sim.run([(0.0, LEN)] * 2 + [(5.0, LEN)] * 2))
    assert eng == des
    assert eng["breaker_trips"] == {T0: 1}
    assert eng["breaker_recoveries"] == {T0: 1}
    assert eng["dispatched"] == {T0: 4}               # probe went to T0
    assert eng["backend_errors"] == {}                # a stall never raises
