"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import (attention_ref, flash_attention,
                                           flash_attention_pallas)
from repro.kernels.pool_norm import pool_norm, pool_norm_pallas, pool_norm_ref
from repro.kernels.quant_matmul import (quant_matmul, quant_matmul_pallas,
                                        quant_matmul_ref, quant_matmul_w8a8,
                                        quantize_activations,
                                        w8a8_matmul_pallas, w8a8_matmul_ref)
from repro.kernels.rmsnorm import rmsnorm_pallas, rmsnorm_ref
from repro.kernels.ssm_scan import ssm_scan_pallas, ssm_scan_ref

KEY = jax.random.PRNGKey(7)


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------- flash ----
FLASH_CASES = [
    # B, H, KV, Sq, Sk, hd, causal, window
    (2, 4, 2, 256, 256, 64, True, 0),     # GQA causal, aligned
    (1, 4, 4, 128, 384, 64, False, 0),    # MHA cross-shaped, Sk > Sq
    (2, 8, 2, 200, 200, 128, True, 64),   # sliding window + padding
    (1, 2, 1, 96, 96, 32, True, 0),       # small head_dim, padding
    (1, 6, 3, 130, 257, 64, True, 0),     # both dims ragged
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(case, dtype):
    B, H, KV, Sq, Sk, hd, causal, window = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, Sq, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, KV, Sk, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, KV, Sk, hd), jnp.float32).astype(dtype)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol(dtype), rtol=tol(dtype))


RAGGED_CASES = [
    # B, H, KV, Sq, Sk, hd, causal, lens
    (2, 4, 2, 96, 96, 64, False, (50, 96)),     # embedder-shaped, ragged
    (2, 2, 1, 64, 64, 32, True, (10, 64)),      # causal + ragged
    (3, 4, 4, 130, 130, 64, False, (1, 77, 130)),  # block padding + ragged
]


@pytest.mark.parametrize("case", RAGGED_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kv_len_vs_ref(case, dtype):
    """Per-example valid-key prefixes (ragged/bucketed batches)."""
    B, H, KV, Sq, Sk, hd, causal, lens = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, Sq, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, KV, Sk, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, KV, Sk, hd), jnp.float32).astype(dtype)
    kv_len = jnp.asarray(lens, jnp.int32)
    ref = attention_ref(q, k, v, causal=causal, kv_len=kv_len)
    got = flash_attention_pallas(q, k, v, causal=causal, interpret=True,
                                 kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol(dtype), rtol=tol(dtype))
    # the pure-JAX chunked path must mask identically (kv_len -> kv_mask)
    gj = flash_attention(q, k, v, causal=causal, backend="jnp",
                         kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(gj, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol(dtype), rtol=tol(dtype))


def test_attn_forward_kernel_flag_matches_jnp_path():
    """FLAGS.attn_kernel routes attn_forward through the Pallas kernel; the
    interpreted kernel must agree with the default pure-JAX path, masks
    included (the embedder's serving configuration)."""
    from repro.configs import get_config
    from repro.models import layers as L
    from repro.perf_flags import reset_flags, set_flags
    cfg = get_config("bge-large-zh-v1.5").smoke()
    p = L.init_attention(jax.random.PRNGKey(1), cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 40, cfg.d_model))
    pos = jnp.arange(40, dtype=jnp.int32)
    kv_mask = (jnp.arange(40)[None, :] <
               jnp.asarray([[23], [40]])).astype(jnp.float32)
    base = L.attn_forward(p, cfg, x, pos, causal=False, kv_mask=kv_mask)
    try:
        set_flags(attn_kernel="interpret")
        kernel = L.attn_forward(p, cfg, x, pos, causal=False,
                                kv_mask=kv_mask)
    finally:
        reset_flags()
    np.testing.assert_allclose(np.asarray(kernel), np.asarray(base),
                               atol=2e-5)


def test_flash_jnp_backend_matches_ref():
    q = jax.random.normal(KEY, (2, 4, 160, 64))
    k = jax.random.normal(KEY, (2, 2, 160, 64))
    v = jax.random.normal(KEY, (2, 2, 160, 64))
    ref = attention_ref(q, k, v, causal=True)
    got = flash_attention(q, k, v, causal=True, backend="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_flash_block_size_invariance():
    q = jax.random.normal(KEY, (1, 2, 256, 64))
    k = jax.random.normal(KEY, (1, 2, 256, 64))
    v = jax.random.normal(KEY, (1, 2, 256, 64))
    a = flash_attention_pallas(q, k, v, block_q=64, block_k=64, interpret=True)
    b = flash_attention_pallas(q, k, v, block_q=128, block_k=256,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------- ssm ------
SSM_CASES = [
    # B, S, DI, N, chunk, block_di
    (2, 256, 512, 16, 128, 512),
    (1, 128, 1024, 16, 64, 256),
    (2, 64, 256, 8, 64, 256),
    (1, 64, 128, 16, 16, 128),
]


@pytest.mark.parametrize("case", SSM_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan_vs_ref(case, dtype):
    B, S, DI, N, chunk, bdi = case
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, DI), jnp.float32).astype(dtype)
    dt = (jax.nn.softplus(jax.random.normal(ks[1], (B, S, DI))) * 0.1
          ).astype(dtype)
    Bm = jax.random.normal(ks[2], (B, S, N), jnp.float32).astype(dtype)
    Cm = jax.random.normal(ks[3], (B, S, N), jnp.float32).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[4], (DI, N)) * 0.5)
    yr, hr = ssm_scan_ref(x, dt, Bm, Cm, A)
    yp, hp = ssm_scan_pallas(x, dt, Bm, Cm, A, chunk=chunk, block_di=bdi,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yr),
                               atol=tol(dtype) * 10, rtol=tol(dtype) * 10)
    np.testing.assert_allclose(np.asarray(hp), np.asarray(hr),
                               atol=tol(dtype) * 10, rtol=tol(dtype) * 10)


def test_ssm_chunking_invariance():
    B, S, DI, N = 1, 128, 256, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, DI))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, DI))) * 0.1
    Bm = jax.random.normal(ks[2], (B, S, N))
    Cm = jax.random.normal(ks[3], (B, S, N))
    A = -jnp.exp(jax.random.normal(ks[4], (DI, N)) * 0.5)
    y1, h1 = ssm_scan_pallas(x, dt, Bm, Cm, A, chunk=32, interpret=True)
    y2, h2 = ssm_scan_pallas(x, dt, Bm, Cm, A, chunk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5)


def test_ssm_matches_model_layer_scan():
    """The model's mamba_scan_ref and the kernel ref must agree."""
    from repro.models.layers import mamba_scan_ref
    B, S, DI, N = 2, 64, 128, 16
    ks = jax.random.split(KEY, 5)
    xc = jax.random.normal(ks[0], (B, S, DI))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, DI))) * 0.1
    Bm = jax.random.normal(ks[2], (B, S, N))
    Cm = jax.random.normal(ks[3], (B, S, N))
    A = -jnp.exp(jax.random.normal(ks[4], (DI, N)) * 0.5)
    y1, h1 = mamba_scan_ref(xc, dt, Bm, Cm, A)
    y2, h2 = ssm_scan_ref(xc, dt, Bm, Cm, A)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5)


# ---------------------------------------------------------------- pool ----
POOL_CASES = [
    # B, S, D, block_b
    (2, 33, 128, 2),      # ragged rows + batch-block padding
    (5, 64, 256, 8),      # block_b > B
    (1, 16, 64, 1),
    (9, 40, 128, 4),      # B not a multiple of block_b
]


@pytest.mark.parametrize("case", POOL_CASES)
@pytest.mark.parametrize("pool", ["mean", "cls"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pool_norm_vs_ref(case, pool, dtype):
    B, S, D, bb = case
    ks = jax.random.split(KEY, 2)
    h = jax.random.normal(ks[0], (B, S, D), jnp.float32).astype(dtype)
    lens = jax.random.randint(ks[1], (B,), 1, S + 1)
    mask = (jnp.arange(S)[None, :] < lens[:, None]).astype(jnp.float32)
    ref = pool_norm_ref(h, mask, pool)
    got = pool_norm_pallas(h, mask, pool, block_b=bb, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=tol(dtype), rtol=tol(dtype))
    assert got.dtype == jnp.float32            # paper: fp32 output vectors
    norms = np.linalg.norm(np.asarray(got), axis=-1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-5)


def test_pool_norm_fully_masked_row_is_zero():
    """A bucketed batch's padding row (all-zero mask) pools to the zero
    vector in both modes — no NaNs, no garbage unit vectors."""
    h = jax.random.normal(KEY, (2, 8, 16))
    mask = jnp.zeros((2, 8)).at[0, :3].set(1.0)
    for pool in ("mean", "cls"):
        for fn in (pool_norm_ref,
                   lambda a, b, p: pool_norm_pallas(a, b, p, interpret=True)):
            out = np.asarray(fn(h, mask, pool))
            assert np.isfinite(out).all()
            assert np.linalg.norm(out[0]) == pytest.approx(1.0, abs=1e-5)
            assert np.abs(out[1]).max() == 0.0


def test_pool_norm_matches_embedder_tail():
    """The ops wrapper (backend dispatch) is what models.embedder calls; its
    'ref' route must equal the kernel route."""
    h = jax.random.normal(KEY, (3, 24, 64))
    mask = (jnp.arange(24)[None, :] <
            jnp.asarray([[24], [10], [1]])).astype(jnp.float32)
    a = pool_norm(h, mask, pool="mean", backend="ref")
    b = pool_norm(h, mask, pool="mean", backend="interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_pool_norm_rejects_unknown_mode():
    h = jnp.zeros((1, 4, 8))
    m = jnp.ones((1, 4))
    with pytest.raises(ValueError):
        pool_norm_ref(h, m, "max")
    with pytest.raises(ValueError):
        pool_norm_pallas(h, m, "max", interpret=True)


# ---------------------------------------------------------------- quant ----
QM_CASES = [
    # M, K, N, block_m, block_n, block_k
    (128, 128, 128, 128, 128, 128),   # exactly one block
    (200, 96, 260, 128, 128, 64),     # every dim ragged vs its block
    (7, 48, 130, 8, 128, 32),         # small M, K split across steps
    (256, 320, 64, 64, 64, 128),      # multi-block M and K
    (1, 16, 24, 128, 128, 128),       # single row, tiny dims
]


@pytest.mark.parametrize("case", QM_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_vs_ref(case, dtype):
    """Pallas (interpret) fused int8 matmul == the jnp oracle across block
    raggedness and both activation dtypes (fp32 accumulation in both)."""
    M, K, N, bm, bn, bk = case
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (M, K), jnp.float32).astype(dtype)
    w8 = jax.random.randint(ks[1], (K, N), -127, 128, jnp.int32
                            ).astype(jnp.int8)
    scale = jnp.abs(jax.random.normal(KEY, (N,))) * 0.01 + 1e-4
    ref = quant_matmul_ref(x, w8, scale)
    got = quant_matmul_pallas(x, w8, scale, block_m=bm, block_n=bn,
                              block_k=bk, interpret=True)
    assert got.dtype == ref.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol(dtype), rtol=tol(dtype))


def test_quant_matmul_leading_batch_dims():
    x = jax.random.normal(KEY, (2, 9, 48))
    w8 = jax.random.randint(KEY, (48, 64), -127, 128, jnp.int32
                            ).astype(jnp.int8)
    s = jnp.full((64,), 0.02)
    a = quant_matmul_ref(x, w8, s)
    b = quant_matmul_pallas(x, w8, s, interpret=True)
    assert a.shape == b.shape == (2, 9, 64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_quant_matmul_ops_backend_dispatch():
    """The jit ops wrapper: 'ref' and 'interpret' routes agree; int8 weights
    are mandatory (a float weight means the caller forgot to quantize)."""
    x = jax.random.normal(KEY, (5, 32))
    w8 = jax.random.randint(KEY, (32, 40), -127, 128, jnp.int32
                            ).astype(jnp.int8)
    s = jnp.full((40,), 0.03)
    a = quant_matmul(x, w8, s, backend="ref")
    b = quant_matmul(x, w8, s, backend="interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    with pytest.raises(TypeError, match="int8"):
        quant_matmul_ref(x, x, s)
    with pytest.raises(TypeError, match="int8"):
        quant_matmul_pallas(x, x.astype(jnp.float32), s, interpret=True)


def test_quant_matmul_block_size_invariance():
    x = jax.random.normal(KEY, (96, 160))
    w8 = jax.random.randint(KEY, (160, 192), -127, 128, jnp.int32
                            ).astype(jnp.int8)
    s = jnp.abs(jax.random.normal(KEY, (192,))) * 0.01 + 1e-4
    a = quant_matmul_pallas(x, w8, s, block_m=32, block_n=64, block_k=32,
                            interpret=True)
    b = quant_matmul_pallas(x, w8, s, block_m=96, block_n=192, block_k=160,
                            interpret=True)
    # K-split changes fp32 accumulation order only
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                               rtol=1e-5)


def test_quant_matmul_matches_dense_apply_contract():
    """quantize_dense + quant_matmul approximates the float projection the
    way models.layers.dense_apply relies on (error bounded by the
    per-channel scale)."""
    from repro.models.quantize import quantize_dense
    w = jax.random.normal(KEY, (64, 96)) * jnp.linspace(0.2, 2.0, 96)
    q, s = quantize_dense(w)
    x = jax.random.normal(KEY, (8, 64))
    got = np.asarray(quant_matmul_pallas(x, q, s, interpret=True))
    want = np.asarray(x @ w)
    # |err| <= sum_k |x_k| * scale_n / 2 elementwise
    bound = (np.abs(np.asarray(x)).sum(-1, keepdims=True)
             * np.asarray(s)[None, :] * 0.5 + 1e-5)
    assert (np.abs(got - want) <= bound).all()


# ---------------------------------------------------------------- w8a8 -----
W8A8_CASES = [
    # M, K, N, block_m, block_n, block_k
    (128, 128, 128, 128, 128, 128),   # exactly one block
    (200, 96, 260, 128, 128, 64),     # every dim ragged vs its block
    (7, 48, 130, 8, 128, 32),         # small M, K split across steps
    (256, 320, 64, 64, 64, 128),      # multi-block M and K
    (1, 16, 24, 128, 128, 128),       # single row, tiny dims
    (33, 512, 48, 16, 32, 128),       # deep K: int16 accumulation would clip
]


def _np_w8a8_oracle(x8, w8, xs, ws):
    """Exact numpy int32-accumulation oracle (int64 overflow check)."""
    acc64 = np.asarray(x8, np.int64) @ np.asarray(w8, np.int64)
    assert np.abs(acc64).max() < 2 ** 31, "oracle itself would overflow"
    acc = acc64.astype(np.int32)
    return (acc.astype(np.float32) * np.asarray(xs, np.float32)[:, None]
            * np.asarray(ws, np.float32)[None, :])


@pytest.mark.parametrize("case", W8A8_CASES)
def test_w8a8_matmul_vs_int32_oracle(case):
    """Pallas (interpret) and jnp W8A8 routes == the exact numpy int32
    oracle across block raggedness.  The contraction is integer, so the
    match is exact up to the final fp32 dequant rounding."""
    M, K, N, bm, bn, bk = case
    ks = jax.random.split(KEY, 4)
    x8 = jax.random.randint(ks[0], (M, K), -127, 128, jnp.int32
                            ).astype(jnp.int8)
    w8 = jax.random.randint(ks[1], (K, N), -127, 128, jnp.int32
                            ).astype(jnp.int8)
    xs = jnp.abs(jax.random.normal(ks[2], (M,))) * 0.02 + 1e-4
    ws = jnp.abs(jax.random.normal(ks[3], (N,))) * 0.01 + 1e-4
    want = _np_w8a8_oracle(x8, w8, xs, ws)
    got_p = w8a8_matmul_pallas(x8, w8, xs, ws, block_m=bm, block_n=bn,
                               block_k=bk, interpret=True)
    got_r = w8a8_matmul_ref(x8, w8, xs, ws)
    assert got_p.dtype == got_r.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got_p), want, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(got_r), want, rtol=1e-6)


def test_w8a8_accumulates_in_int32_not_float():
    """Constructed so a float32 running accumulator would round: all-positive
    int8 operands drive the partial sums past 2^24 (fp32 integer-exactness
    limit) with odd per-tile increments, while the exact int32 sum converted
    ONCE to fp32 is what both routes must return bit-exactly."""
    rng = np.random.default_rng(0)
    M, K, N = 2, 6144, 8
    x8 = jnp.asarray(rng.integers(1, 128, (M, K)).astype(np.int8))
    w8 = jnp.asarray(rng.integers(1, 128, (K, N)).astype(np.int8))
    ones_m, ones_n = jnp.ones((M,)), jnp.ones((N,))
    acc64 = np.asarray(x8, np.int64) @ np.asarray(w8, np.int64)
    assert acc64.max() > 2 ** 24, "case must exceed fp32 exact-int range"
    assert acc64.max() < 2 ** 31
    want = acc64.astype(np.int32).astype(np.float32)   # single final rounding
    got_p = w8a8_matmul_pallas(x8, w8, ones_m, ones_n, block_m=8,
                               block_n=8, block_k=64, interpret=True)
    got_r = w8a8_matmul_ref(x8, w8, ones_m, ones_n)
    np.testing.assert_array_equal(np.asarray(got_p), want)
    np.testing.assert_array_equal(np.asarray(got_r), want)


def test_quantize_activations_extreme_ranges():
    """absmax≈0 rows must not NaN (guarded scale divide), subnormal rows
    must not overflow the int8 clip, huge rows stay finite."""
    K = 64
    x = jnp.stack([
        jnp.zeros((K,)),                                  # exactly zero
        jnp.full((K,), 1e-42),                            # subnormal absmax
        jnp.full((K,), 1e30),                             # huge
        jnp.linspace(-3.0, 3.0, K),                       # ordinary
        jnp.zeros((K,)).at[0].set(1e-45),                 # one denormal elt
    ])
    x8, scale = quantize_activations(x)
    assert x8.dtype == jnp.int8 and scale.dtype == jnp.float32
    assert bool(jnp.isfinite(scale).all())
    assert bool((scale > 0).all())
    assert int(jnp.abs(x8).max()) <= 127
    assert int(jnp.abs(x8[0]).max()) == 0                 # zero row -> zeros
    # dequant round-trips ordinary rows within scale/2 per element
    err = jnp.abs(x8[3].astype(jnp.float32) * scale[3] - x[3])
    assert float(err.max()) <= float(scale[3]) * 0.5 + 1e-7
    # end-to-end: extreme rows stay finite through the kernel
    w8 = jax.random.randint(KEY, (K, 16), -127, 128, jnp.int32
                            ).astype(jnp.int8)
    ws = jnp.full((16,), 0.01)
    out = quant_matmul_w8a8(x, w8, ws)
    assert bool(jnp.isfinite(out).all())
    assert bool((out[0] == 0).all())


def test_w8a8_matmul_leading_batch_dims():
    x = jax.random.normal(KEY, (2, 9, 48))
    w8 = jax.random.randint(KEY, (48, 64), -127, 128, jnp.int32
                            ).astype(jnp.int8)
    s = jnp.full((64,), 0.02)
    out = quant_matmul_w8a8(x, w8, s)
    assert out.shape == (2, 9, 64) and out.dtype == x.dtype
    x8, xs = quantize_activations(x)
    # fp32 dequant-epilogue fusion order may differ under jit: atol covers
    # the last-ulp wobble, the integer contraction itself is exact
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(w8a8_matmul_ref(x8, w8, xs, s)),
        atol=1e-4)


def test_w8a8_rejects_unquantized_operands():
    x8 = jnp.zeros((4, 32), jnp.int8)
    xf = jnp.zeros((4, 32), jnp.float32)
    w8 = jnp.zeros((32, 16), jnp.int8)
    s = jnp.ones((16,))
    xs = jnp.ones((4,))
    with pytest.raises(TypeError, match="int8"):
        w8a8_matmul_ref(xf, w8, xs, s)
    with pytest.raises(TypeError, match="int8"):
        w8a8_matmul_pallas(x8, xf.T, xs, s, interpret=True)
    with pytest.raises(TypeError, match="int8"):
        w8a8_matmul_pallas(xf, w8, xs, s, interpret=True)


def test_w8a8_block_size_invariance():
    """Integer accumulation makes the K-split bitwise irrelevant (unlike
    the fp32-accumulating weight-only kernel, which only matches to
    rounding): any block tiling returns the identical result."""
    ks = jax.random.split(KEY, 2)
    x8 = jax.random.randint(ks[0], (96, 160), -127, 128, jnp.int32
                            ).astype(jnp.int8)
    w8 = jax.random.randint(ks[1], (160, 192), -127, 128, jnp.int32
                            ).astype(jnp.int8)
    xs = jnp.abs(jax.random.normal(KEY, (96,))) * 0.02 + 1e-4
    ws = jnp.abs(jax.random.normal(KEY, (192,))) * 0.01 + 1e-4
    a = w8a8_matmul_pallas(x8, w8, xs, ws, block_m=32, block_n=64,
                           block_k=32, interpret=True)
    b = w8a8_matmul_pallas(x8, w8, xs, ws, block_m=96, block_n=192,
                           block_k=160, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------- quant dispatch ----------
def test_quant_interpret_default_resolves_from_backend(monkeypatch):
    """Satellite: quant_matmul_pallas / w8a8_matmul_pallas must not default
    to the interpreter on a TPU backend — interpret=None resolves compiled
    there and interpreted everywhere else."""
    import importlib

    # the package re-exports the jitted entry under the same name, so the
    # kernel MODULE must be resolved explicitly
    kmod = importlib.import_module("repro.kernels.quant_matmul.quant_matmul")

    assert kmod._default_interpret() is (jax.default_backend() != "tpu")
    monkeypatch.setattr(kmod.jax, "default_backend", lambda: "tpu")
    assert kmod._default_interpret() is False
    monkeypatch.setattr(kmod.jax, "default_backend", lambda: "cpu")
    assert kmod._default_interpret() is True


def test_quant_ops_auto_routes_pallas_compiled_on_tpu(monkeypatch):
    """The ops auto route on a (mocked) TPU backend must call the Pallas
    kernel with interpret=False — the TPU path can never silently run
    interpreted — and the ref oracle elsewhere."""
    from repro.kernels.quant_matmul import ops as qm_ops

    seen = []
    monkeypatch.setattr(qm_ops._kmod, "quant_matmul_pallas",
                        lambda x, w8, s, interpret, **kw:
                        seen.append(("w8-pallas", interpret)) or x)
    monkeypatch.setattr(qm_ops._kmod, "w8a8_matmul_pallas",
                        lambda x8, w8, xs, ws, interpret, **kw:
                        seen.append(("w8a8-pallas", interpret)) or x8)
    monkeypatch.setattr(qm_ops._rmod, "quant_matmul_ref",
                        lambda *a, **kw: seen.append(("w8-ref", None)) or a[0])
    monkeypatch.setattr(qm_ops._rmod, "w8a8_matmul_ref",
                        lambda *a, **kw: seen.append(("w8a8-ref", None))
                        or a[0])
    x = jnp.ones((4, 32))
    w8 = jnp.zeros((32, 16), jnp.int8)
    s = jnp.ones((16,))

    monkeypatch.setattr(qm_ops.jax, "default_backend", lambda: "tpu")
    qm_ops._quant_matmul(x, w8, s)
    qm_ops._quant_matmul_w8a8(x, w8, s)
    monkeypatch.setattr(qm_ops.jax, "default_backend", lambda: "cpu")
    qm_ops._quant_matmul(x, w8, s)
    qm_ops._quant_matmul_w8a8(x, w8, s)
    assert seen == [("w8-pallas", False), ("w8a8-pallas", False),
                    ("w8-ref", None), ("w8a8-ref", None)]


def test_w8a8_ops_backend_dispatch():
    """The jit ops wrapper: 'ref' and 'interpret' routes agree bitwise
    (integer accumulation on both)."""
    x = jax.random.normal(KEY, (5, 32))
    w8 = jax.random.randint(KEY, (32, 40), -127, 128, jnp.int32
                            ).astype(jnp.int8)
    s = jnp.full((40,), 0.03)
    a = quant_matmul_w8a8(x, w8, s, backend="ref")
    b = quant_matmul_w8a8(x, w8, s, backend="interpret")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------- rmsnorm --
@pytest.mark.parametrize("shape", [(4, 128, 512), (2, 100, 384), (300, 256),
                                   (1, 1, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_vs_ref(shape, dtype):
    x = jax.random.normal(KEY, shape, jnp.float32).astype(dtype)
    s = jax.random.normal(KEY, (shape[-1],), jnp.float32)
    ref = rmsnorm_ref(x, s)
    got = rmsnorm_pallas(x, s, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol(dtype), rtol=tol(dtype))


def test_rmsnorm_matches_model_layer():
    from repro.configs import get_config
    from repro.models.layers import apply_norm
    cfg = get_config("stablelm-1.6b").smoke()
    x = jax.random.normal(KEY, (2, 8, cfg.d_model))
    scale = jnp.ones((cfg.d_model,)) * 1.3
    a = apply_norm({"scale": scale}, cfg, x)
    b = rmsnorm_ref(x, scale, eps=cfg.norm_eps)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------- decode ---
from repro.kernels.flash_decode import decode_attention_ref, flash_decode_pallas

FD_CASES = [
    # B, KV, G, S, hd, pos, window, block_k
    (2, 2, 4, 512, 64, 300, 0, 256),      # partial-filled cache
    (1, 4, 2, 384, 128, 383, 0, 128),     # full cache, ragged blocks
    (2, 1, 8, 256, 64, 200, 64, 256),     # sliding window
    (1, 2, 1, 100, 32, 50, 0, 64),        # padding + small dims
]


@pytest.mark.parametrize("case", FD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_vs_ref(case, dtype):
    B, KV, G, S, hd, pos, window, bk = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32).astype(dtype)
    kpos = jnp.where(jnp.arange(S) <= pos, jnp.arange(S), -1)
    ref = decode_attention_ref(q, k, v, kpos, pos, window=window)
    got = flash_decode_pallas(q, k, v, kpos, pos, window=window, block_k=bk,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol(dtype) * 2, rtol=tol(dtype) * 2)


def test_flash_decode_ring_buffer_positions():
    """Slots hold non-monotonic absolute positions (sliding-window ring)."""
    B, KV, G, S, hd, W = 1, 2, 2, 128, 64, 128
    pos = 200                      # wrapped: slot i holds pos (200-127..200)
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    base = jnp.arange(S)
    kpos = jnp.where(base <= pos % S, base + (pos // S) * S,
                     base + (pos // S - 1) * S)
    ref = decode_attention_ref(q, k, v, kpos, pos, window=W)
    got = flash_decode_pallas(q, k, v, kpos, pos, window=W, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


def test_flash_decode_matches_model_attn_decode_read():
    """Kernel == the serving path's attention math (layers.attn_decode_read
    modulo the wo projection)."""
    from repro.configs import get_config
    from repro.models import layers as L
    cfg = get_config("stablelm-1.6b").smoke()
    hd = cfg.resolved_head_dim
    B, S = 2, 64
    ks = jax.random.split(KEY, 4)
    p = L.init_attention(ks[0], cfg, jnp.float32)
    x1 = jax.random.normal(ks[1], (B, 1, cfg.d_model))
    ck = jax.random.normal(ks[2], (B, S, cfg.num_kv_heads, hd))
    cv = jax.random.normal(ks[3], (B, S, cfg.num_kv_heads, hd))
    pos = jnp.asarray(40)
    kpos = jnp.where(jnp.arange(S) <= pos, jnp.arange(S), -1)
    want = L.attn_decode_read(p, cfg, x1, pos, ck, cv, kpos)
    q = L.project_q(p, cfg, x1, pos).reshape(B, cfg.num_kv_heads, -1, hd)
    out = flash_decode_pallas(q, ck, cv, kpos, pos, interpret=True)
    got = out.reshape(B, 1, -1) @ p["wo"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)
