"""Zero-cost cache tier: LRU semantics, dispatch integration, both drivers.

The exact-match embedding cache (``repro.core.cache``) is a first-class
``TierSpec`` consulted by ``QueueManager.dispatch`` before policy dispatch.
These tests pin its contracts: LRU/byte-budget eviction, exact-match keying,
policies never routing to it, hit-at-dispatch completion in the engine and
+0-service-time completion in the DES, admission-before-future-resolution,
the Eq. 12 / deployment-cost repricing helpers, and — property-based — that
serving with the cache on is bitwise-indistinguishable from serving with it
off for ANY interleaving of repeated queries.
"""
import sys

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import cost_model, estimator
from repro.core.cache import (CACHE, CacheEntry, EmbeddingCache, cache_key,
                              cache_tier)
from repro.core.routing import (BUSY, CPU, NPU, CascadePolicy,
                                LeastLoadedPolicy, LengthAwarePolicy,
                                PredictivePolicy, Query, QueueManager,
                                TierSpec, dispatchable)
from repro.core.simulator import DeviceModel, ServingSimulator
from repro.core.windve import Backend, ModeledBackend, WindVE
from repro.data.workload import query_lengths, zipf_queries


def q(qid=0, payload=None, length=75, arrival_t=0.0):
    return Query(qid=qid, payload=payload, length=length,
                 arrival_t=arrival_t)


def toks(*ids):
    return np.asarray(ids, dtype=np.int32)


# ---------------------------------------------------------------- cache_key
def test_cache_key_payloadless_keys_on_length():
    assert cache_key(q(length=75)) == cache_key(q(qid=9, length=75))
    assert cache_key(q(length=75)) != cache_key(q(length=76))


def test_cache_key_container_and_dtype_insensitive():
    a = cache_key(q(payload=[3, 1, 4]))
    b = cache_key(q(payload=np.array([3, 1, 4], np.int64)))
    c = cache_key(q(payload=np.array([3, 1, 4], np.int16)))
    assert a == b == c


def test_cache_key_content_sensitive():
    assert cache_key(q(payload=[3, 1, 4])) != cache_key(q(payload=[3, 1, 5]))
    assert cache_key(q(payload=[3, 1])) != cache_key(q(payload=[3, 1, 0]))
    # payload-carrying never collides with payload-less
    assert cache_key(q(payload=[75])) != cache_key(q(length=75))


# ---------------------------------------------------------- EmbeddingCache
def test_lru_eviction_order_with_get_refresh():
    c = EmbeddingCache(capacity=2)
    c.put(q(payload=[1]), np.zeros(2))
    c.put(q(payload=[2]), np.zeros(2))
    assert c.get(q(payload=[1])) is not None      # refresh: [2] is now LRU
    assert c.put(q(payload=[3]), np.zeros(2)) == 1
    assert c.get(q(payload=[2])) is None          # evicted
    assert c.get(q(payload=[1])) is not None
    assert c.get(q(payload=[3])) is not None
    assert c.evictions == 1 and len(c) == 2


def test_byte_capacity_evicts_and_tracks_nbytes():
    v = np.zeros(4, np.float32)                   # 16 bytes each
    c = EmbeddingCache(capacity=100, capacity_bytes=40)
    c.put(q(payload=[1]), v)
    c.put(q(payload=[2]), v)
    assert c.nbytes == 32
    assert c.put(q(payload=[3]), v) == 1          # 48 > 40: evict oldest
    assert c.nbytes == 32 and len(c) == 2
    assert c.get(q(payload=[1])) is None


def test_oversized_value_rejected_not_admitted():
    c = EmbeddingCache(capacity=8, capacity_bytes=8)
    c.put(q(payload=[1]), np.zeros(1, np.float32))    # 4 bytes: fits
    assert c.put(q(payload=[2]), np.zeros(64, np.float32)) == 0
    assert c.get(q(payload=[2])) is None
    assert c.get(q(payload=[1])) is not None          # untouched


def test_put_same_key_refreshes_not_duplicates():
    c = EmbeddingCache(capacity=4)
    c.put(q(payload=[1]), np.zeros(2), now=1.0)
    c.put(q(payload=[1]), np.ones(2), now=2.0)
    assert len(c) == 1 and c.inserts == 2 and c.evictions == 0
    e = c.get(q(payload=[1]))
    assert e.t == 2.0 and np.array_equal(e.value, np.ones(2))


def test_stored_values_are_readonly_copies():
    c = EmbeddingCache(capacity=4)
    src = np.arange(4, dtype=np.float32)
    c.put(q(payload=[1]), src)
    src[:] = -1                                   # caller mutates its array
    e = c.get(q(payload=[1]))
    assert np.array_equal(e.value, np.arange(4, dtype=np.float32))
    with pytest.raises(ValueError):
        e.value[0] = 99                           # stored copy is immutable


def test_clear_drops_entries_and_counters():
    c = EmbeddingCache(capacity=2)
    c.put(q(payload=[1]), np.zeros(2))
    c.get(q(payload=[1]))
    c.get(q(payload=[2]))
    c.clear()
    assert len(c) == 0 and c.nbytes == 0
    assert c.hits == c.misses == c.inserts == c.evictions == 0


def test_cache_validation_errors():
    with pytest.raises(ValueError):
        EmbeddingCache(capacity=0)
    with pytest.raises(ValueError):
        EmbeddingCache(capacity=4, capacity_bytes=0)


# ----------------------------------------------------- QueueManager + cache
def two_tier_qm(entries=8, policy=None):
    return QueueManager([cache_tier(entries),
                         TierSpec(NPU, 4), TierSpec(CPU, 2)], policy=policy)


def test_dispatch_miss_falls_through_then_admit_then_hit():
    qm = two_tier_qm()
    q1 = q(qid=1, payload=[7, 7], arrival_t=1.0)
    assert qm.dispatch(q1) == NPU                 # cold: miss -> policy
    q1.done_t = 1.5
    assert qm.admit(q1, np.full(3, 2.5)) == CACHE
    q2 = q(qid=2, payload=[7, 7], arrival_t=5.0)
    assert qm.dispatch(q2) == CACHE               # exact-match hit
    assert np.array_equal(q2.emb, np.full(3, 2.5))
    s = qm.stats
    assert dict(s.cache_hits) == {CACHE: 1}
    assert dict(s.cache_misses) == {CACHE: 1}
    assert dict(s.cache_inserts) == {CACHE: 1}
    assert s.cache_hit_rate() == 0.5
    assert s.cache_staleness(50) == pytest.approx(3.5)   # 5.0 - 1.5
    assert s.dispatched[CACHE] == 1 and s.dispatched[NPU] == 1
    assert "cache_hit_rate" in s.summary()


def test_cache_tier_holds_no_queue_or_concurrency():
    qm = two_tier_qm()
    assert CACHE not in qm.queues
    assert qm.depth(CACHE) == 0
    assert qm.max_concurrency == 6                # 4 + 2, cache adds none
    assert qm.is_cache_tier(CACHE) and not qm.is_cache_tier(NPU)
    assert [t.name for t in dispatchable(qm.tiers)] == [NPU, CPU]


def test_reset_clears_cache_state():
    qm = two_tier_qm()
    q1 = q(qid=1, payload=[3])
    qm.dispatch(q1)
    qm.admit(q1, np.zeros(2))
    qm.reset()
    assert qm.dispatch(q(qid=2, payload=[3])) == NPU   # cold again
    assert dict(qm.stats.cache_hits) == {}


def test_topology_of_only_cache_tiers_rejected():
    with pytest.raises(ValueError, match="non-cache"):
        QueueManager([cache_tier(8)])


def test_admit_without_cache_tier_is_noop():
    qm = QueueManager([TierSpec(NPU, 4)])
    q1 = q(qid=1, payload=[3])
    qm.dispatch(q1)
    assert qm.admit(q1, np.zeros(2)) is None
    assert "cache_hit_rate" not in qm.stats.summary()


@pytest.mark.parametrize("policy", [
    CascadePolicy(), LengthAwarePolicy(long_threshold=50),
    LeastLoadedPolicy(),
    PredictivePolicy(fits={NPU: DeviceModel(NPU, beta=0.1, b=0.0, a=0.0),
                           CPU: DeviceModel(CPU, beta=0.2, b=0.0, a=0.0)}),
])
def test_every_policy_skips_cache_tiers(policy):
    qm = two_tier_qm(policy=policy)
    tiers = qm.tiers
    for ln in (10, 400):
        names = list(policy.candidates(q(length=ln), tiers, qm))
        assert CACHE not in names and names
    # and dispatch on a cold cache routes to a real tier
    assert qm.dispatch(q(qid=1, payload=[1], length=400)) in (NPU, CPU)


def test_length_aware_fast_tiers_count_real_tiers_only():
    # fast_tiers=1 must mean "first REAL tier", not the cache head
    qm = two_tier_qm(policy=LengthAwarePolicy(long_threshold=50,
                                              fast_tiers=1))
    short = list(qm.policy.candidates(q(length=10), qm.tiers, qm))
    long_ = list(qm.policy.candidates(q(length=100), qm.tiers, qm))
    assert short == [NPU, CPU]      # short queries may use every tier
    assert long_ == [NPU]           # long ones fit only the fast tier


# ------------------------------------------------------------------- DES
def des(entries=64, depth=4, slo=100.0):
    dev = DeviceModel("npu", beta=0.05, b=0.01, a=0.0)
    tiers = [TierSpec(NPU, depth, model=dev, max_batch=depth)]
    if entries:
        tiers.insert(0, cache_tier(entries))
    return ServingSimulator(tiers=tiers, slo_s=slo)


def test_des_repeat_after_completion_hits_at_zero_service_time():
    sim = des()
    res = sim.run([(0.0, 75, 1), (0.0, 75, 1), (5.0, 75, 1), (5.0, 80, 2)])
    # the two t=0 arrivals both miss (insertion happens at completion);
    # the t=5 repeat of key 1 hits, key 2 misses
    assert dict(res.cache_hits) == {CACHE: 1}
    assert res.cache_misses[CACHE] == 3
    assert res.dispatched[CACHE] == 1
    assert res.n_completed == 4 and res.rejected == 0
    hit = [l for l in res.latencies if l == 0.0]
    assert len(hit) == 1                        # the hit completed at +0


def test_des_seeded_runs_replay_identically():
    arrivals = [(i * 0.01, 75, i % 5) for i in range(60)]
    a = des().run(arrivals).summary()
    b = des().run(arrivals).summary()
    assert a == b and a["cache_hit_rate"] > 0


def test_des_cache_raises_accepted_concurrency_at_identical_load():
    arrivals = [(i * 0.02, 75, i % 6) for i in range(200)]
    off = des(entries=0).run(arrivals)
    on = des(entries=64).run(arrivals)
    assert on.rejected < off.rejected
    assert on.accepted > off.accepted
    assert "cache_hit_rate" not in off.summary()    # cache-less: unchanged


# ---------------------------------------------------------------- engine
class TokenSumBackend(Backend):
    """Deterministic pure function of the payload — embeddings are checkable
    bitwise without jax, and any cache corruption shows immediately."""
    name = "token-sum"

    def embed_batch(self, queries):
        out = []
        for qq in queries:
            p = np.zeros(4, np.float64) if qq.payload is None else \
                np.asarray(qq.payload, np.float64)
            h = np.array([p.sum(), p.prod(), len(p), qq.length], np.float64)
            out.append(h)
        return out


def engine(entries):
    tiers = [TierSpec(CPU, 64, backend=TokenSumBackend())]
    if entries:
        tiers.insert(0, cache_tier(entries))
    return WindVE(tiers=tiers)


def test_engine_hit_resolves_immediately_and_bitwise():
    ve = engine(entries=8)
    try:
        r1 = ve.submit(payload=np.array([2, 3, 4])).result(timeout=30)
        r2 = ve.submit(payload=np.array([2, 3, 4])).result(timeout=30)
        assert np.array_equal(r1, r2)
        assert dict(ve.stats.cache_hits) == {CACHE: 1}
        assert ve.stats.dispatched[CACHE] == 1
        assert ve.stats.summary()["cache_hit_rate"] == 0.5
    finally:
        ve.shutdown()


def test_engine_admits_before_resolving_future():
    # the determinism linchpin: any client that HAS a result must get a
    # cache hit for the same tokens on its very next submission
    ve = engine(entries=8)
    try:
        for k in range(6):
            ve.submit(payload=np.array([k])).result(timeout=30)
            ve.submit(payload=np.array([k])).result(timeout=30)
        assert ve.stats.cache_hits[CACHE] == 6
    finally:
        ve.shutdown()


@settings(max_examples=8, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=4),
                min_size=1, max_size=24))
def test_property_cache_on_serving_is_bitwise_identical(key_seq):
    """For ANY interleaving of repeated queries, cache-on serving returns
    exactly the bytes cache-off serving computes."""
    pool = {k: np.arange(3 + k) + 10 * k for k in range(5)}
    payloads = [pool[k] for k in key_seq]
    results = {}
    for entries in (0, 16):
        ve = engine(entries)
        try:
            results[entries] = [
                np.asarray(ve.submit(payload=p, length=len(p))
                           .result(timeout=30)) for p in payloads]
            if entries:
                srv = ve.stats
                assert srv.cache_hits[CACHE] + srv.cache_misses[CACHE] \
                    == len(payloads)
        finally:
            ve.shutdown()
    for off, on in zip(results[0], results[16]):
        assert off.dtype == on.dtype and np.array_equal(off, on)


# ------------------------------------------------- Eq.12 / cost repricing
def test_cached_fit_scales_alpha_only():
    fit = estimator.LatencyFit(alpha=0.2, beta=1.0, r2=0.99)
    f2 = estimator.cached_fit(fit, 0.75)
    assert f2.alpha == pytest.approx(0.05)
    assert f2.beta == 1.0 and f2.r2 == 0.99
    assert estimator.cached_fit(fit, 0.0).alpha == fit.alpha
    for bad in (-0.1, 1.0, 1.5):
        with pytest.raises(ValueError):
            estimator.cached_fit(fit, bad)


def test_cache_uplift_and_cached_depth():
    assert cost_model.cache_uplift(0.0) == 1.0
    assert cost_model.cache_uplift(0.5) == pytest.approx(2.0)
    assert cost_model.cached_depth(10, 0.5) == 20
    assert cost_model.cached_depth(7, 0.0) == 7
    assert cost_model.cached_depth(0, 0.9) == 0
    for bad in (-0.1, 1.0):
        with pytest.raises(ValueError):
            cost_model.cache_uplift(bad)
    with pytest.raises(ValueError):
        cost_model.cached_depth(-1, 0.5)


# --------------------------------------------------------------- workload
def test_zipf_queries_deterministic_and_skewed():
    a = zipf_queries(200, 1000, alpha=1.1, unique=16, seed=3)
    b = zipf_queries(200, 1000, alpha=1.1, unique=16, seed=3)
    assert len(a) == 200
    assert all(np.array_equal(x, y) for x, y in zip(a, b))
    distinct = {p.tobytes() for p in a}
    assert len(distinct) <= 16
    # rank-1 key dominates: repeat rate far above uniform draws
    assert 1.0 - len(distinct) / 200 >= 0.5
    assert all(p.max() < 1000 and p.min() >= 0 for p in a)


def test_zipf_queries_alpha_zero_is_uniform_pool_draws():
    a = zipf_queries(64, 500, alpha=0.0, unique=8, seed=0, length=20)
    assert all(len(p) == 20 for p in a)
    assert len({p.tobytes() for p in a}) <= 8


def test_zipf_queries_validation():
    with pytest.raises(ValueError):
        zipf_queries(-1, 100)
    with pytest.raises(ValueError):
        zipf_queries(10, 100, unique=0)
    with pytest.raises(ValueError):
        zipf_queries(10, 100, alpha=-0.5)


def test_query_lengths_jitter_clamped_symmetric():
    ls = query_lengths(2000, mean=75, jitter=200.0, seed=1)
    assert min(ls) >= 1 and max(ls) <= 2 * 75 - 1
    assert query_lengths(50, mean=75, jitter=30.0, seed=9) == \
        query_lengths(50, mean=75, jitter=30.0, seed=9)
