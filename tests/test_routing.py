"""The scheduling core: TierSpec topologies, dispatch policies, telemetry."""
import pytest

from repro.core.routing import (BUSY, CPU, NPU, CascadePolicy,
                                LeastLoadedPolicy, LengthAwarePolicy, Query,
                                QueueManager, TierSpec)
from repro.core.telemetry import Telemetry


def q(i: int, length: int = 75) -> Query:
    return Query(qid=i, length=length)


class TestCascadeIsAlgorithm1:
    def test_verdict_for_verdict_vs_reference(self):
        """Scripted arrival/completion sequence: the generalized cascade must
        reproduce the paper's Algorithm 1 decision sequence exactly."""
        def reference_alg1(events, c_npu, c_cpu, heter):
            # the paper's two-counter formulation (occupancy == queued +
            # in-flight, C^max bounds concurrency)
            occ = {"NPU": 0, "CPU": 0}
            depths = {"NPU": c_npu, "CPU": c_cpu if heter else 0}
            out = []
            for kind, arg in events:
                if kind == "finish":
                    if occ.get(arg, 0) > 0:
                        occ[arg] -= 1
                    continue
                if occ["NPU"] < depths["NPU"]:
                    occ["NPU"] += 1
                    out.append(NPU)
                elif depths["CPU"] > 0 and occ["CPU"] < depths["CPU"]:
                    occ["CPU"] += 1
                    out.append(CPU)
                else:
                    out.append(BUSY)
            return out

        events = ([("arrive", i) for i in range(6)] +
                  [("finish", "NPU"), ("arrive", 6), ("arrive", 7),
                   ("finish", "CPU"), ("finish", "NPU"), ("arrive", 8),
                   ("arrive", 9), ("arrive", 10)])
        for c_npu, c_cpu, heter in [(3, 2, True), (3, 2, False), (1, 0, True),
                                    (4, 4, True), (0, 2, True)]:
            qm = QueueManager(c_npu, c_cpu, heter_enable=heter)
            got = []
            for kind, arg in events:
                if kind == "finish":
                    if arg in qm.queues and qm.queues[arg].pop_batch(1):
                        qm.queues[arg].finish(1)
                    continue
                got.append(qm.dispatch(q(arg)))
            assert got == reference_alg1(events, c_npu, c_cpu, heter), \
                f"diverged for C_NPU={c_npu} C_CPU={c_cpu} heter={heter}"

    def test_three_tier_overflow_ordering(self):
        qm = QueueManager([TierSpec("NPU", 2), TierSpec("CPU-big", 2),
                           TierSpec("CPU-little", 1)])
        verdicts = [qm.dispatch(q(i)) for i in range(6)]
        assert verdicts == ["NPU", "NPU", "CPU-big", "CPU-big",
                            "CPU-little", BUSY]
        assert qm.max_concurrency == 5
        assert qm.stats.dispatched == {"NPU": 2, "CPU-big": 2,
                                       "CPU-little": 1}

    def test_legacy_two_arg_constructor(self):
        qm = QueueManager(npu_depth=1, cpu_depth=1)
        assert [qm.dispatch(q(i)) for i in range(3)] == [NPU, CPU, BUSY]
        assert qm.heter_enable
        assert not QueueManager(4, 0).heter_enable

    def test_duplicate_tier_names_rejected(self):
        with pytest.raises(ValueError):
            QueueManager([TierSpec("NPU", 1), TierSpec("NPU", 2)])


class TestLengthAwarePolicy:
    def test_long_queries_pinned_to_fast_tier(self):
        qm = QueueManager([TierSpec(NPU, 1), TierSpec(CPU, 4)],
                          policy=LengthAwarePolicy(long_threshold=300))
        assert qm.dispatch(q(1, length=500)) == NPU
        # fast tier full: a long query is rejected, NOT offloaded (§5.4 —
        # on the slow tier it would be a guaranteed SLO violation)
        assert qm.dispatch(q(2, length=500)) == BUSY
        # short queries still cascade into the slow tier
        assert qm.dispatch(q(3, length=75)) == CPU

    def test_short_queries_follow_cascade(self):
        qm = QueueManager([TierSpec(NPU, 1), TierSpec(CPU, 1)],
                          policy=LengthAwarePolicy(long_threshold=300))
        assert [qm.dispatch(q(i, length=75)) for i in range(3)] == \
            [NPU, CPU, BUSY]

    def test_fast_tiers_window(self):
        qm = QueueManager([TierSpec("NPU", 1), TierSpec("CPU-big", 1),
                           TierSpec("CPU-little", 8)],
                          policy=LengthAwarePolicy(long_threshold=200,
                                                   fast_tiers=2))
        assert qm.dispatch(q(1, length=400)) == "NPU"
        assert qm.dispatch(q(2, length=400)) == "CPU-big"
        assert qm.dispatch(q(3, length=400)) == BUSY

    def test_validation(self):
        with pytest.raises(ValueError):
            LengthAwarePolicy(long_threshold=0)
        with pytest.raises(ValueError):
            LengthAwarePolicy(fast_tiers=0)


class TestBucketedPopBatch:
    """Length-aware batch formation: FIFO picks the bucket, the bucket
    fills the batch, everyone else keeps their place in line."""

    BUCKET = staticmethod(lambda q: 32 * ((q.length + 31) // 32))

    def push(self, qm, lengths):
        for i, ln in enumerate(lengths):
            assert qm.dispatch(q(i + 1, length=ln)) == NPU

    def test_head_of_line_picks_the_bucket(self):
        qm = QueueManager([TierSpec(NPU, 100, bucket_fn=self.BUCKET)])
        self.push(qm, [10, 70, 20, 30, 80])
        batch = qm.pop_batch(NPU)
        # oldest query (len 10, bucket 32) decides; 70/80 stay queued
        assert [x.qid for x in batch] == [1, 3, 4]
        batch2 = qm.pop_batch(NPU)
        assert [x.qid for x in batch2] == [2, 5]       # FIFO preserved

    def test_max_batch_respected_within_bucket(self):
        qm = QueueManager([TierSpec(NPU, 100, max_batch=2,
                                    bucket_fn=self.BUCKET)])
        self.push(qm, [10, 12, 14, 70])
        assert [x.qid for x in qm.pop_batch(NPU)] == [1, 2]
        assert [x.qid for x in qm.pop_batch(NPU)] == [3]
        assert [x.qid for x in qm.pop_batch(NPU)] == [4]

    def test_leftovers_keep_arrival_order(self):
        qm = QueueManager([TierSpec(NPU, 100, max_batch=1,
                                    bucket_fn=self.BUCKET)])
        self.push(qm, [10, 20, 30])
        assert [x.qid for x in qm.pop_batch(NPU)] == [1]
        assert [x.qid for x in qm.pop_batch(NPU)] == [2]
        assert [x.qid for x in qm.pop_batch(NPU)] == [3]

    def test_in_flight_accounting_unchanged(self):
        qm = QueueManager([TierSpec(NPU, 4, bucket_fn=self.BUCKET)])
        self.push(qm, [10, 70, 20])
        batch = qm.pop_batch(NPU)                      # pops 2 (bucket 32)
        assert len(batch) == 2
        assert len(qm.queues[NPU]) == 3                # 1 queued + 2 in flight
        assert qm.dispatch(q(9)) == NPU                # depth 4: one slot left
        assert qm.dispatch(q(10)) == BUSY
        qm.queues[NPU].finish(len(batch))
        assert qm.dispatch(q(11)) == NPU

    def test_no_bucket_fn_is_plain_fifo(self):
        qm = QueueManager([TierSpec(NPU, 100)])
        self.push(qm, [10, 70, 20])
        assert [x.qid for x in qm.pop_batch(NPU)] == [1, 2, 3]


class TestLeastLoadedPolicy:
    def test_balances_by_free_share(self):
        qm = QueueManager([TierSpec("A", 4), TierSpec("B", 2)],
                          policy=LeastLoadedPolicy())
        # free shares: A 4/4 vs B 2/2 -> tie, cascade order -> A
        assert qm.dispatch(q(1)) == "A"
        # A 3/4 vs B 2/2 -> B
        assert qm.dispatch(q(2)) == "B"
        # A 3/4 vs B 1/2 -> A
        assert qm.dispatch(q(3)) == "A"

    def test_fills_everything_then_busy(self):
        qm = QueueManager([TierSpec("A", 2), TierSpec("B", 2)],
                          policy=LeastLoadedPolicy())
        verdicts = [qm.dispatch(q(i)) for i in range(5)]
        assert verdicts.count("A") == 2 and verdicts.count("B") == 2
        assert verdicts[-1] == BUSY


class TestDepthManagement:
    def test_set_depth_resizes_contract(self):
        qm = QueueManager([TierSpec(NPU, 2)])
        qm.dispatch(q(1)), qm.dispatch(q(2))
        assert qm.dispatch(q(3)) == BUSY
        qm.set_depth(NPU, 4)
        assert qm.dispatch(q(4)) == NPU
        assert qm.tier(NPU).depth == 4          # spec stays in sync
        with pytest.raises(ValueError):
            qm.set_depth(NPU, -1)

    def test_max_batch_tracks_live_depth(self):
        qm = QueueManager([TierSpec(NPU, 8)])
        assert qm.max_batch(NPU) == 8
        qm.set_depth(NPU, 3)
        assert qm.max_batch(NPU) == 3
        qm2 = QueueManager([TierSpec(NPU, 8, max_batch=2)])
        assert qm2.max_batch(NPU) == 2

    def test_reset_keeps_depths_fresh_stats(self):
        qm = QueueManager([TierSpec(NPU, 2)])
        qm.set_depth(NPU, 5)
        qm.dispatch(q(1))
        stats = qm.reset()
        assert qm.depth(NPU) == 5
        assert len(qm.queues[NPU]) == 0
        assert stats.accepted == 0 and qm.stats is stats


class TestTelemetryUnification:
    def test_legacy_dispatch_counters(self):
        qm = QueueManager(2, 1)
        for i in range(4):
            qm.dispatch(q(i))
        s = qm.stats
        assert (s.to_npu, s.to_cpu, s.busy) == (2, 1, 1)
        assert s.accepted == 3 and s.rejected == 1

    def test_completion_counters_and_slo(self):
        t = Telemetry(slo=1.0)
        fast = Query(qid=1, arrival_t=0.0, done_t=0.5)
        slow = Query(qid=2, arrival_t=0.0, done_t=2.0)
        t.record_completion(fast, NPU)
        t.record_completion(slow, CPU)
        assert t.n_completed == 2
        assert t.violations == 1
        assert t.max_ok_concurrency == 1
        assert t.per_device == {NPU: 1, CPU: 1}
        assert t.p(50) == pytest.approx(1.25)
        assert t.throughput(2.0) == 0.0          # nothing dispatched yet

    def test_engine_sim_dispatch_records_are_one_object(self):
        """DispatchStats / EngineStats / SimResult are literally Telemetry."""
        from repro.core.queue_manager import DispatchStats
        from repro.core.telemetry import EngineStats, SimResult
        assert DispatchStats is Telemetry
        assert EngineStats is Telemetry
        assert SimResult is Telemetry


class TestPredictivePolicy:
    """Latency-predictive dispatch: minimal predicted completion time
    (queue backlog priced on the tier's calibrated service curve)."""

    def _fits(self):
        from repro.core.simulator import DeviceModel

        # fast: t(c) = 0.2 + 0.01c ; slow: t(c) = 0.5 + 0.05c
        return {NPU: DeviceModel("fast", beta=0.2, b=0.01, a=0.0),
                CPU: DeviceModel("slow", beta=0.5, b=0.05, a=0.0)}

    def _qm(self, policy, d_npu=10, d_cpu=10):
        return QueueManager([TierSpec(NPU, d_npu), TierSpec(CPU, d_cpu)],
                            policy=policy)

    def test_prefers_fast_tier_when_idle(self):
        from repro.core.routing import PredictivePolicy

        qm = self._qm(PredictivePolicy(fits=self._fits()))
        assert qm.dispatch(q(1)) == NPU

    def test_spills_when_backlog_prices_fast_tier_above_slow(self):
        from repro.core.routing import PredictivePolicy

        qm = self._qm(PredictivePolicy(fits=self._fits()), d_npu=100)
        # fast predicted passes slow t(1)=0.55 at backlog 34:
        # 0.2 + 0.01*(34+1) = 0.55
        got = [qm.dispatch(q(i)) for i in range(40)]
        assert got[:34] == [NPU] * 34
        assert got[35] == CPU        # backlog 35 -> 0.56 > 0.55
        assert CPU in got

    def test_unfitted_tiers_trail_in_cascade_order(self):
        from repro.core.routing import PredictivePolicy

        fits = {CPU: self._fits()[CPU]}       # NPU never calibrated
        qm = self._qm(PredictivePolicy(fits=fits), d_cpu=2)
        # CPU has a fit -> priced and preferred; NPU only as overflow
        assert [qm.dispatch(q(i)) for i in range(3)] == [CPU, CPU, NPU]

    def test_no_fits_degrades_to_cascade(self):
        from repro.core.routing import PredictivePolicy

        qm = self._qm(PredictivePolicy(), d_npu=2, d_cpu=2)
        assert [qm.dispatch(q(i)) for i in range(5)] == \
            [NPU, NPU, CPU, CPU, BUSY]

    def test_per_bucket_fits_override_tier_fit(self):
        from repro.core.bucketing import length_bucket_fn
        from repro.core.routing import PredictivePolicy
        from repro.core.simulator import DeviceModel

        bucket = length_bucket_fn(min_bucket=32, max_bucket=128)
        pol = PredictivePolicy(fits=self._fits(), bucket_fn=bucket)
        # long queries are catastrophically slow on the slow tier (5.4):
        # install a per-bucket fit that prices bucket-128 CPU service high
        pol.update(CPU, DeviceModel("slow@128", beta=9.0, b=0.5, a=0.0),
                   bucket=128)
        qm = self._qm(pol, d_npu=100)
        assert qm.dispatch(q(1, length=120)) == NPU     # priced per bucket
        for i in range(2, 40):
            qm.dispatch(q(i, length=120))
        # long queries stay off the poisoned bucket while the fast tier has
        # room (the policy orders candidates; admission stays depth-bound)
        assert qm.stats.dispatched.get(CPU, 0) == 0
        # short queries still use the CPU's tier-level fit and spill there
        # once the fast tier's backlog prices above it
        assert qm.dispatch(q(50, length=10)) == CPU

    def test_update_swaps_fit_atomically(self):
        from repro.core.routing import PredictivePolicy
        from repro.core.simulator import DeviceModel

        pol = PredictivePolicy(fits=self._fits())
        qm = self._qm(pol)
        assert qm.dispatch(q(1)) == NPU
        # online calibrator observed the fast tier collapsing: refit flips
        # the ordering for the very next dispatch
        pol.update(NPU, DeviceModel("degraded", beta=2.0, b=0.2, a=0.0))
        assert qm.dispatch(q(2)) == CPU

    def test_latency_fit_objects_work_as_fits(self):
        from repro.core.estimator import fit_latency
        from repro.core.routing import PredictivePolicy

        fit = fit_latency([1, 4, 16], [0.21, 0.24, 0.36])
        pol = PredictivePolicy(fits={NPU: fit})
        qm = self._qm(pol)
        p = pol.predicted_completion_s(NPU, q(1), qm)
        assert p == pytest.approx(fit.alpha + fit.beta, abs=1e-9)
