"""Minimal ``hypothesis`` fallback so property tests run without the dep.

The container image has no ``hypothesis`` wheel and nothing may be pip
installed, so four test modules used to die at collection.  This stub
implements just the surface this repo uses — ``given`` / ``settings`` and
the ``integers`` / ``floats`` / ``booleans`` / ``lists`` / ``tuples`` /
``sampled_from`` / ``just`` strategies — with deterministic seeded random
sampling (no shrinking).  When the real hypothesis is installed (CI), the
stub is never registered.

``conftest.install()`` must run before test modules import, which pytest
guarantees for conftest-level imports.
"""
from __future__ import annotations

import random
import sys
import types


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def example(self, rng: random.Random):
        return self._sample(rng)

    def map(self, fn):
        return _Strategy(lambda r: fn(self._sample(r)))

    def filter(self, pred, tries: int = 100):
        def sample(r):
            for _ in range(tries):
                v = self._sample(r)
                if pred(v):
                    return v
            raise ValueError("filter predicate too strict for stub")
        return _Strategy(sample)


def integers(min_value=0, max_value=1000):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value=0.0, max_value=1.0, **_kw):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def booleans():
    return _Strategy(lambda r: r.random() < 0.5)


def lists(elements, min_size=0, max_size=10, **_kw):
    return _Strategy(lambda r: [elements.example(r)
                                for _ in range(r.randint(min_size, max_size))])


def tuples(*elems):
    return _Strategy(lambda r: tuple(e.example(r) for e in elems))


def sampled_from(seq):
    items = list(seq)
    return _Strategy(lambda r: r.choice(items))


def just(value):
    return _Strategy(lambda r: value)


def settings(**kwargs):
    """Decorator form only (standalone profiles are not needed here)."""
    def deco(fn):
        fn._stub_settings = kwargs
        return fn
    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        def wrapper(*args, **kwargs):
            conf = getattr(wrapper, "_stub_settings",
                           getattr(fn, "_stub_settings", {}))
            n = int(conf.get("max_examples") or 25)
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for _ in range(min(n, 200)):
                pos = tuple(s.example(rng) for s in arg_strategies)
                kws = {k: s.example(rng) for k, s in kw_strategies.items()}
                fn(*args, *pos, **kwargs, **kws)
        # no functools.wraps: __wrapped__ would make pytest introspect the
        # original signature and demand fixtures named after the strategies
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._stub_settings = getattr(fn, "_stub_settings", {})
        return wrapper
    return deco


def install() -> bool:
    """Register the stub as ``hypothesis`` if the real one is missing."""
    try:
        import hypothesis  # noqa: F401
        return False
    except ImportError:
        pass
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "lists", "tuples",
                 "sampled_from", "just"):
        setattr(st, name, globals()[name])
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
    return True
