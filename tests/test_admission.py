"""SLO-aware admission control + brownout: units and dispatch integration.

Covers the overload-control stack end to end at the unit level:
``AdmissionController`` (watermark math, SLO-violation pricing, rejection
economics), ``BrownoutController`` (stage machine, hysteresis, deadline
tightening, quantized-tier re-rank), their wiring into
``QueueManager.dispatch`` (the ADMISSION verdict, rejection-reason
telemetry, cache-hits-always-served), the engine's client-visible
``ServeError(kind="admission")``, and engine-vs-DES counter parity on a
seeded overload plan.  The bench (``benchmarks/capacity_plan_microbench``)
asserts the macro behaviour; these tests pin the mechanisms.
"""
import sys

import pytest

from repro.core.admission import AdmissionController
from repro.core.cache import cache_tier
from repro.core.health import (DEGRADED, NORMAL, SHEDDING,
                               BrownoutController)
from repro.core.routing import (ADMISSION, BUSY, Query, QueueManager,
                                ServeError, TierSpec)
from repro.core.simulator import DeviceModel, ServingSimulator
from repro.core.windve import ModeledBackend, WindVE

T0, T1 = "T0", "T1"


def flat_models(b0=0.1, b1=0.15):
    """Flat service curves double as exact LatencyFits for the controller."""
    return {T0: DeviceModel(T0, beta=b0, b=0.0, a=0.0),
            T1: DeviceModel(T1, beta=b1, b=0.0, a=0.0)}


def make_qm(depths=(4, 4), models=None, **kw):
    models = models or flat_models()
    tiers = [TierSpec(T0, depths[0], model=models[T0]),
             TierSpec(T1, depths[1], model=models[T1], quantized=True)]
    return QueueManager(tiers, **kw)


# ---------------------------------------------------------------------------
# AdmissionController units
# ---------------------------------------------------------------------------

class TestWatermarkSlots:
    def test_fraction_floors(self):
        adm = AdmissionController(watermark=0.5)
        assert adm.watermark_slots(6) == 3
        assert adm.watermark_slots(7) == 3

    def test_full_watermark_is_full_depth(self):
        assert AdmissionController().watermark_slots(8) == 8

    def test_at_least_one_slot_for_usable_tier(self):
        assert AdmissionController(watermark=0.01).watermark_slots(10) == 1

    def test_depth_zero_tier_has_zero_slots(self):
        assert AdmissionController(watermark=0.5).watermark_slots(0) == 0

    def test_shedding_tightens_by_shed_scale(self):
        adm = AdmissionController(watermark=1.0, shed_scale=0.5)
        assert adm.watermark_slots(8, stage=SHEDDING) == 4
        assert adm.watermark_slots(8, stage=NORMAL) == 8

    def test_no_float_cliff(self):
        # 10 * 0.3 is 2.9999...: the epsilon must keep the floor at 3
        assert AdmissionController(watermark=0.3).watermark_slots(10) == 3


class TestAdmissionValidation:
    @pytest.mark.parametrize("kw", [dict(slo_s=0), dict(reject_cost=-1),
                                    dict(violation_cost=0),
                                    dict(watermark=0), dict(watermark=1.5),
                                    dict(shed_scale=0)])
    def test_rejects_bad_config(self, kw):
        with pytest.raises(ValueError):
            AdmissionController(**kw)


class TestDecide:
    def test_under_capacity_admits_everywhere(self):
        m = flat_models()
        qm = make_qm(models=m)
        adm = AdmissionController(fits=m, slo_s=100.0)
        got = adm.decide(Query(qid=0), qm.tiers, qm, now=0.0)
        assert got == {T0, T1}

    def test_over_watermark_rejects_while_hard_slots_remain(self):
        m = flat_models()
        qm = make_qm(depths=(4, 4), models=m)
        adm = AdmissionController(fits=m, slo_s=100.0, watermark=0.5)
        for i in range(2):           # fill both tiers to their watermark (2)
            qm.queues[T0].push(Query(qid=i))
            qm.queues[T1].push(Query(qid=10 + i))
        assert adm.decide(Query(qid=9), qm.tiers, qm, now=0.0) is None

    def test_hard_full_falls_through_to_busy(self):
        m = flat_models()
        qm = make_qm(depths=(1, 1), models=m)
        adm = AdmissionController(fits=m, slo_s=100.0)
        for i in range(2):
            qm.dispatch(Query(qid=i))
        # empty set: dispatch's push loop reports the classic no_capacity
        assert adm.decide(Query(qid=9), qm.tiers, qm, now=0.0) == set()

    def test_predictably_late_is_rejected_when_rejection_is_cheaper(self):
        m = flat_models(b0=2.0, b1=3.0)     # every tier predicts past 1s
        qm = make_qm(models=m)
        adm = AdmissionController(fits=m, slo_s=1.0, reject_cost=0.5)
        assert adm.decide(Query(qid=0), qm.tiers, qm, now=0.0) is None

    def test_pricing_disabled_when_rejection_costs_more(self):
        m = flat_models(b0=2.0, b1=3.0)
        qm = make_qm(models=m)
        adm = AdmissionController(fits=m, slo_s=1.0, reject_cost=1.0)
        # reject_cost >= violation_cost: serving late is the cheaper bet
        assert adm.decide(Query(qid=0), qm.tiers, qm, now=0.0) == {T0, T1}

    def test_shedding_stage_forces_pricing_rejection(self):
        m = flat_models(b0=2.0, b1=3.0)
        qm = make_qm(models=m)
        adm = AdmissionController(fits=m, slo_s=1.0, reject_cost=1.0)
        assert adm.decide(Query(qid=0), qm.tiers, qm, now=0.0,
                          stage=SHEDDING) is None

    def test_unfitted_tier_is_optimistic(self):
        m = flat_models(b0=2.0, b1=3.0)
        qm = make_qm(models=m)
        adm = AdmissionController(fits={T0: m[T0]}, slo_s=1.0,
                                  reject_cost=0.5)
        # T1 has no fit: calibration earns the right to reject, so admit
        assert adm.decide(Query(qid=0), qm.tiers, qm, now=0.0) == {T0, T1}

    def test_deadline_tightens_the_budget(self):
        m = flat_models(b0=0.5, b1=0.6)     # fine for the 1s SLO...
        qm = make_qm(models=m)
        adm = AdmissionController(fits=m, slo_s=1.0, reject_cost=0.5)
        q = Query(qid=0, deadline=0.2)      # ...but not for 0.2s remaining
        assert adm.decide(q, qm.tiers, qm, now=0.0) is None

    def test_update_fit_recalibrates(self):
        m = flat_models()
        qm = make_qm(models=m)
        adm = AdmissionController(fits=dict(m), slo_s=1.0, reject_cost=0.5)
        assert adm.decide(Query(qid=0), qm.tiers, qm, now=0.0) == {T0, T1}
        adm.update_fit(T0, DeviceModel(T0, beta=5.0, b=0.0, a=0.0))
        adm.update_fit(T1, DeviceModel(T1, beta=5.0, b=0.0, a=0.0))
        assert adm.decide(Query(qid=1), qm.tiers, qm, now=0.0) is None


# ---------------------------------------------------------------------------
# BrownoutController units
# ---------------------------------------------------------------------------

class TestBrownoutStages:
    def test_escalates_through_stages(self):
        bro = BrownoutController(ewma_alpha=1.0)
        assert bro.observe(0.5) == NORMAL
        assert bro.observe(0.75) == DEGRADED
        assert bro.observe(0.95) == SHEDDING
        assert bro.transitions == 2

    def test_ewma_smooths_a_single_spike(self):
        # the first sample seeds the EWMA; later spikes fold in at alpha
        bro = BrownoutController(ewma_alpha=0.3)
        bro.observe(0.0)
        assert bro.observe(1.0) == NORMAL       # 0.3 after one spike
        assert bro.utilization_ewma == pytest.approx(0.3)

    def test_hysteresis_blocks_flapping_deescalation(self):
        bro = BrownoutController(degraded_at=0.7, shedding_at=0.9,
                                 ewma_alpha=1.0, hysteresis=0.1)
        assert bro.observe(0.75) == DEGRADED
        # below degraded_at but inside the hysteresis band: stage holds
        assert bro.observe(0.65) == DEGRADED
        assert bro.observe(0.55) == NORMAL

    def test_deescalation_is_stepwise_from_shedding(self):
        bro = BrownoutController(degraded_at=0.7, shedding_at=0.9,
                                 ewma_alpha=1.0, hysteresis=0.1)
        assert bro.observe(0.95) == SHEDDING
        # clears shedding's band (< 0.8) -> lands on degraded
        assert bro.observe(0.75) == DEGRADED
        assert bro.observe(0.5) == NORMAL
        assert bro.transitions == 3

    def test_tighten_scales_remaining_budget(self):
        bro = BrownoutController(ewma_alpha=1.0, deadline_scale=0.5)
        bro.observe(0.8)                        # -> degraded
        assert bro.tighten(10.0, now=2.0) == pytest.approx(6.0)
        assert bro.tighten(None, now=2.0) is None

    def test_tighten_identity_in_normal(self):
        bro = BrownoutController()
        assert bro.tighten(10.0, now=2.0) == 10.0

    def test_reorder_prefers_quantized_at_equal_backlog(self):
        qm = make_qm()                          # T1 is quantized, both empty
        bro = BrownoutController(ewma_alpha=1.0)
        assert list(bro.reorder([T0, T1], qm)) == [T0, T1]  # normal: as-is
        bro.observe(0.8)
        assert list(bro.reorder([T0, T1], qm)) == [T1, T0]

    def test_reorder_backlog_dominates_quantization(self):
        qm = make_qm()
        for i in range(2):                      # load the quantized tier
            qm.queues[T1].push(Query(qid=i))
        bro = BrownoutController(ewma_alpha=1.0)
        bro.observe(0.8)
        assert list(bro.reorder([T1, T0], qm)) == [T0, T1]

    def test_reset_and_snapshot(self):
        bro = BrownoutController(ewma_alpha=1.0)
        bro.observe(0.95)
        assert bro.snapshot()["stage"] == SHEDDING
        bro.reset()
        assert bro.stage == NORMAL and bro.utilization_ewma is None
        assert bro.transitions == 0

    @pytest.mark.parametrize("kw", [dict(degraded_at=0.9, shedding_at=0.7),
                                    dict(degraded_at=0.0),
                                    dict(ewma_alpha=0.0),
                                    dict(ewma_alpha=1.5),
                                    dict(hysteresis=-0.1),
                                    dict(deadline_scale=0.0)])
    def test_rejects_bad_config(self, kw):
        with pytest.raises(ValueError):
            BrownoutController(**kw)


# ---------------------------------------------------------------------------
# dispatch integration: verdicts, telemetry reasons, cache immunity
# ---------------------------------------------------------------------------

class TestDispatchIntegration:
    def test_admission_verdict_and_reason(self):
        m = flat_models(b0=5.0, b1=5.0)
        qm = make_qm(models=m, admission=AdmissionController(
            fits=m, slo_s=1.0, reject_cost=0.5))
        assert qm.dispatch(Query(qid=0)) == ADMISSION
        assert qm.stats.rejections == {"admission": 1}
        assert qm.stats.rejected == 0           # BUSY back-compat untouched

    def test_busy_records_no_capacity_reason(self):
        qm = make_qm(depths=(1, 1))
        for i in range(2):
            qm.dispatch(Query(qid=i))
        assert qm.dispatch(Query(qid=9)) == BUSY
        assert qm.stats.rejections.get("no_capacity") == 1
        assert qm.stats.rejected == 1

    def test_expired_records_reason(self):
        qm = make_qm()
        q = Query(qid=0, deadline=1.0, arrival_t=2.0)
        assert qm.dispatch(q) == "EXPIRED"
        assert qm.stats.rejections.get("expired") == 1

    def test_utilization_tracks_backlog(self):
        qm = make_qm(depths=(4, 4))
        assert qm.utilization() == 0.0
        for i in range(4):
            qm.dispatch(Query(qid=i))
        assert qm.utilization() == pytest.approx(0.5)

    def test_brownout_transitions_counted_once_per_stage_change(self):
        qm = make_qm(depths=(2, 2), admission=None,
                     brownout=BrownoutController(degraded_at=0.4,
                                                 shedding_at=0.9,
                                                 ewma_alpha=1.0))
        for i in range(4):
            qm.dispatch(Query(qid=i))
        assert qm.stats.brownout_transitions == {DEGRADED: 1}

    def test_cache_hits_served_under_shedding(self):
        m = flat_models()
        adm = AdmissionController(fits=m, slo_s=1e-6)  # rejects everything
        bro = BrownoutController(degraded_at=0.01, shedding_at=0.02,
                                 ewma_alpha=1.0)
        ct = cache_tier(8)
        qm = QueueManager([ct, TierSpec(T0, 2, model=m[T0])],
                          admission=adm, brownout=bro)
        import numpy as np
        hot_p, cold_p = np.array([1, 2], np.int64), np.array([3, 4], np.int64)
        ct.cache.put(Query(qid=0, payload=hot_p, length=8), [1.0, 2.0])
        qm.queues[T0].push(Query(qid=50))       # drive utilization over 0.02
        assert qm.dispatch(Query(qid=2, payload=cold_p, length=8)) \
            == ADMISSION
        # the identical-payload repeat is a hit: served at every stage
        assert qm.dispatch(Query(qid=3, payload=hot_p, length=8)) \
            == ct.name

    def test_reset_clears_brownout_stage(self):
        bro = BrownoutController(degraded_at=0.1, shedding_at=0.9,
                                 ewma_alpha=1.0)
        qm = make_qm(depths=(2, 2), brownout=bro)
        for i in range(3):
            qm.dispatch(Query(qid=i))
        assert bro.stage == DEGRADED
        qm.reset()
        assert bro.stage == NORMAL
        assert qm.stats.brownout_transitions == {}

    def test_summary_shape_clean_run_has_no_overload_keys(self):
        qm = make_qm()
        qm.dispatch(Query(qid=0))
        s = qm.stats.summary()
        assert not any(k.startswith(("rejections_", "brownout_to_"))
                       for k in s)

    def test_summary_reports_nonzero_reasons(self):
        m = flat_models(b0=5.0, b1=5.0)
        qm = make_qm(models=m, admission=AdmissionController(
            fits=m, slo_s=1.0, reject_cost=0.5))
        qm.dispatch(Query(qid=0))
        s = qm.stats.summary()
        assert s["rejections_admission"] == 1
        assert "rejections_no_capacity" not in s


# ---------------------------------------------------------------------------
# drivers: the client-visible error and cross-driver counter parity
# ---------------------------------------------------------------------------

class TestDrivers:
    def test_engine_admission_rejection_is_a_serve_error(self):
        m = flat_models(b0=5.0, b1=5.0)
        ve = WindVE(
            tiers=[TierSpec(T0, 4, backend=ModeledBackend(m[T0],
                                                          embed_dim=4)),
                   TierSpec(T1, 4, backend=ModeledBackend(m[T1],
                                                          embed_dim=4))],
            admission=AdmissionController(fits=m, slo_s=1.0,
                                          reject_cost=0.5))
        try:
            fut = ve.submit(length=16)
            with pytest.raises(ServeError) as ei:
                fut.result(timeout=5)
            assert ei.value.kind == "admission"
            assert ve.stats.rejections == {"admission": 1}
            # a rejection is not a failure: nothing was accepted then lost
            assert ve.stats.failed == 0
        finally:
            ve.shutdown()

    def test_seeded_overload_plan_counters_match_across_drivers(self):
        N, DEPTH = 12, 6

        def controllers(m):
            return (AdmissionController(fits=m, slo_s=100.0,
                                        reject_cost=0.5, watermark=0.5),
                    BrownoutController(degraded_at=0.3, shedding_at=0.6,
                                       ewma_alpha=1.0, hysteresis=0.05))

        def counters(t):
            return {"dispatched": dict(t.dispatched),
                    "rejections": {k: v for k, v in t.rejections.items()
                                   if v},
                    "brownout": dict(t.brownout_transitions),
                    "completed": t.n_completed, "failed": t.failed}

        m = flat_models()
        adm, bro = controllers(m)
        sim = ServingSimulator(
            tiers=[TierSpec(T0, DEPTH, model=m[T0]),
                   TierSpec(T1, DEPTH, model=m[T1], quantized=True)],
            slo_s=100.0, admission=adm, brownout=bro)
        des = counters(sim.run([(0.0, 16)] * N))

        m2 = flat_models()
        adm2, bro2 = controllers(m2)
        ve = WindVE(
            tiers=[TierSpec(T0, DEPTH,
                            backend=ModeledBackend(m2[T0], embed_dim=4)),
                   TierSpec(T1, DEPTH,
                            backend=ModeledBackend(m2[T1], embed_dim=4),
                            quantized=True)],
            admission=adm2, brownout=bro2)
        old = sys.getswitchinterval()
        try:
            sys.setswitchinterval(5.0)   # pinned burst, like the DES's
            try:                         # same-instant arrivals
                futs = [ve.submit(length=16) for _ in range(N)]
            finally:
                sys.setswitchinterval(old)
            for f in futs:
                if f is not None:
                    try:
                        f.result(timeout=10)
                    except ServeError:
                        pass
            eng = counters(ve.stats)
        finally:
            sys.setswitchinterval(old)
            ve.shutdown()
        assert eng == des
        # the watermark held half of each tier back for retry headroom
        assert des["rejections"] == {"admission": N - 2 * (DEPTH // 2)}

    def test_des_retry_redispatch_admission_is_terminal(self):
        # a retried query rejected at re-dispatch must count failed, like
        # a BUSY re-dispatch (the arrival-time rejection never does)
        m = flat_models(b0=0.1, b1=0.1)
        from repro.core.faults import FaultModel, FaultPlan
        from repro.core.routing import RetryPolicy
        adm = AdmissionController(fits=m, slo_s=100.0, watermark=0.5)
        sim = ServingSimulator(
            tiers=[TierSpec(T0, 4, model=m[T0]),
                   TierSpec(T1, 4, model=m[T1])],
            slo_s=100.0, admission=adm,
            retry=RetryPolicy(max_retries=1, backoff_s=0.0),
            faults={T0: FaultModel(plan=FaultPlan(fail=(0,)))})
        res = sim.run([(0.0, 16)] * 4)
        assert res.n_completed + res.failed == 4 - \
            res.rejections.get("admission", 0)
