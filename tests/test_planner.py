"""Capacity planner + flash-crowd workload + unit-economics cost helpers.

Pins the sizing toolchain under the capacity-plan bench: deterministic
flash-crowd traces, model-derived latency fits (``fit_from_model``),
SLO-calibrated topologies (``calibrated_tiers``), the DES-backed
``evaluate``/``sweep``/``best`` reduction, and the
``cost_per_million_queries`` / ``overload_shed_fraction`` closed forms —
including the invariant the bench guards at macro scale: an outage arm
delivers FEWER accepted queries than its fault-free twin, never more.
"""
import math

import pytest

from repro.core.admission import AdmissionController
from repro.core.cost_model import (cost_per_million_queries,
                                   overload_shed_fraction)
from repro.core.estimator import fit_from_model
from repro.core.faults import FaultModel, FaultSchedule
from repro.core.health import BrownoutController
from repro.core.planner import (PlanArm, best, calibrated_tiers, evaluate,
                                sweep)
from repro.core.routing import RetryPolicy
from repro.core.simulator import DeviceModel
from repro.data.workload import flash_crowd_trace

NPU = lambda: DeviceModel("npu", beta=0.05, b=0.01, a=0.0)
CPU = lambda: DeviceModel("cpu", beta=0.10, b=0.05, a=0.0)


# ---------------------------------------------------------------------------
# flash-crowd trace
# ---------------------------------------------------------------------------

class TestFlashCrowdTrace:
    def test_deterministic_in_seed(self):
        a = flash_crowd_trace(10, 20.0, 4.0, 3, 4, seed=7)
        b = flash_crowd_trace(10, 20.0, 4.0, 3, 4, seed=7)
        assert a == b
        assert a != flash_crowd_trace(10, 20.0, 4.0, 3, 4, seed=8)

    def test_sorted_and_in_range(self):
        tr = flash_crowd_trace(10, 20.0, 4.0, 3, 4, seed=1)
        times = [t for t, _ in tr]
        assert times == sorted(times)
        assert all(0.0 <= t < 10.0 for t in times)
        assert all(ln == 75 for _, ln in tr)

    def test_burst_window_rate_ratio(self):
        tr = flash_crowd_trace(40, 30.0, 6.0, 10, 10, seed=2)
        inside = sum(1 for t, _ in tr if 10 <= t < 20) / 10.0
        outside = sum(1 for t, _ in tr if not 10 <= t < 20) / 30.0
        # Poisson noise: the realized ratio just needs to be burst-sized
        assert 4.0 < inside / outside < 8.0

    def test_no_burst_when_mult_is_one(self):
        tr = flash_crowd_trace(20, 30.0, 1.0, 5, 10, seed=3)
        inside = sum(1 for t, _ in tr if 5 <= t < 15) / 10.0
        outside = sum(1 for t, _ in tr if not 5 <= t < 15) / 10.0
        assert 0.6 < inside / outside < 1.6

    def test_custom_length(self):
        tr = flash_crowd_trace(5, 10.0, 2.0, 1, 2, length=32, seed=0)
        assert all(ln == 32 for _, ln in tr)

    @pytest.mark.parametrize("kw", [dict(n_seconds=-1), dict(base_rate=-1.0),
                                    dict(burst_mult=0.5),
                                    dict(burst_len=-1.0)])
    def test_rejects_bad_config(self, kw):
        base = dict(n_seconds=5, base_rate=10.0, burst_mult=2.0,
                    burst_start=1, burst_len=2)
        base.update(kw)
        with pytest.raises(ValueError):
            flash_crowd_trace(**base)


# ---------------------------------------------------------------------------
# fit_from_model / calibrated_tiers
# ---------------------------------------------------------------------------

class TestCalibration:
    def test_fit_recovers_linear_model(self):
        m = NPU()                       # t(C) = 0.05 + 0.01 C, noise-free
        fit = fit_from_model(m)
        for c in (1, 10, 50):
            assert fit.latency(c) == pytest.approx(m.latency(c, 75),
                                                   rel=1e-6)
        assert fit.max_concurrency(1.0) == 95

    def test_calibrated_depths_are_eq12_max_concurrency(self):
        tiers, fits = calibrated_tiers({"NPU": NPU(), "CPU": CPU()}, 1.0,
                                       quantized={"CPU"})
        by = {t.name: t for t in tiers}
        assert by["NPU"].depth == fits["NPU"].max_concurrency(1.0) == 95
        assert by["CPU"].depth == fits["CPU"].max_concurrency(1.0) == 18
        assert by["CPU"].quantized and not by["NPU"].quantized

    def test_raises_when_no_tier_meets_slo(self):
        slow = DeviceModel("s", beta=5.0, b=1.0, a=0.0)
        with pytest.raises(ValueError, match="SLO"):
            calibrated_tiers({"S": slow}, 1.0)


# ---------------------------------------------------------------------------
# unit-economics closed forms
# ---------------------------------------------------------------------------

class TestCostHelpers:
    def test_cost_per_million_math(self):
        # 10/s for 100s serving 1e6 queries: 1000 per million
        assert cost_per_million_queries(10.0, 100.0, 10 ** 6) == \
            pytest.approx(1000.0)
        assert cost_per_million_queries(10.0, 100.0, 500) == \
            pytest.approx(10.0 * 100.0 / 500 * 1e6)

    def test_zero_accepted_is_infinite(self):
        assert cost_per_million_queries(10.0, 100.0, 0) == math.inf

    @pytest.mark.parametrize("kw", [dict(price_per_s=-1),
                                    dict(horizon_s=0),
                                    dict(accepted=-1)])
    def test_rejects_bad_inputs(self, kw):
        base = dict(price_per_s=1.0, horizon_s=1.0, accepted=1)
        base.update(kw)
        with pytest.raises(ValueError):
            cost_per_million_queries(**base)

    def test_shed_fraction_bound(self):
        assert overload_shed_fraction(100.0, 40.0) == pytest.approx(0.6)
        assert overload_shed_fraction(100.0, 100.0) == 0.0
        assert overload_shed_fraction(50.0, 100.0) == 0.0
        with pytest.raises(ValueError):
            overload_shed_fraction(0.0, 10.0)


# ---------------------------------------------------------------------------
# evaluate / sweep / best
# ---------------------------------------------------------------------------

def controlled_arm(name, price, faults=None, retry=None):
    tiers, fits = calibrated_tiers({"NPU": NPU(), "CPU": CPU()}, 1.0,
                                   quantized={"CPU"})
    return PlanArm(name, tiers=tiers, price_per_s=price,
                   admission=AdmissionController(fits=fits, slo_s=1.0,
                                                 reject_cost=0.5),
                   brownout=BrownoutController(), deadline_s=2.0,
                   faults=faults or {}, retry=retry)


class TestEvaluate:
    def test_under_capacity_accepts_everything(self):
        trace = flash_crowd_trace(10, 10.0, 1.0, 0, 0, seed=4)
        p = evaluate(controlled_arm("calm", 10.0), trace, slo_s=1.0,
                     trace_name="calm")
        assert p.arrivals == len(trace)
        assert p.accepted == p.arrivals == p.completed
        assert p.slo_attainment == 1.0
        assert p.deadline_misses == 0 and p.failed == 0
        assert p.cost == pytest.approx(10.0 * p.horizon_s)
        assert p.cost_per_m_accepted == pytest.approx(
            cost_per_million_queries(10.0, p.horizon_s, p.accepted))

    def test_row_is_flat_and_json_ready(self):
        trace = flash_crowd_trace(5, 10.0, 1.0, 0, 0, seed=4)
        row = evaluate(controlled_arm("calm", 10.0), trace).row()
        assert row["arm"] == "calm"
        assert all(isinstance(v, (str, int, float)) for v in row.values())

    def test_overload_sheds_and_reduces_accepted(self):
        trace = flash_crowd_trace(10, 200.0, 1.0, 0, 0, seed=4)
        p = evaluate(controlled_arm("storm", 10.0), trace)
        assert p.rejections.get("admission", 0) > 0
        assert p.accepted < p.arrivals
        assert p.accepted + sum(p.rejections.values()) + p.failed \
            >= p.arrivals

    def test_outage_arm_delivers_less_than_fault_free_twin(self):
        trace = flash_crowd_trace(20, 60.0, 4.0, 5, 10, seed=5)
        clean = evaluate(controlled_arm("clean", 10.0), trace)
        sched = FaultSchedule.from_mttf(mttf_s=6.0, mttr_s=2.0,
                                        horizon_s=20.0, seed=7)
        faulty = evaluate(controlled_arm(
            "outage", 10.0,
            faults={"NPU": FaultModel(schedule=sched, fail_latency_s=0.05)},
            retry=RetryPolicy(max_retries=1, backoff_s=0.0)), trace)
        assert faulty.accepted < clean.accepted
        assert faulty.cost_per_m_accepted > clean.cost_per_m_accepted

    def test_empty_trace_rejected(self):
        with pytest.raises(ValueError):
            evaluate(controlled_arm("x", 10.0), [])

    def test_arm_validation(self):
        tiers, _ = calibrated_tiers({"NPU": NPU()}, 1.0)
        with pytest.raises(ValueError):
            PlanArm("x", tiers=tiers, price_per_s=-1.0)
        with pytest.raises(ValueError):
            PlanArm("x", tiers=[], price_per_s=1.0)


class TestSweepAndBest:
    def test_sweep_grid_and_best_pick(self):
        traces = {"calm": flash_crowd_trace(8, 10.0, 1.0, 0, 0, seed=4),
                  "storm": flash_crowd_trace(8, 150.0, 1.0, 0, 0, seed=4)}
        arms = [controlled_arm("one-npu", 10.0),
                controlled_arm("pricey", 20.0)]
        pts = sweep(arms, traces, slo_s=1.0)
        assert len(pts) == 4
        assert {(p.arm, p.trace) for p in pts} == \
            {(a, t) for a in ("one-npu", "pricey")
             for t in ("calm", "storm")}
        calm = [p for p in pts if p.trace == "calm"]
        assert best(calm).arm == "one-npu"   # same served load, half price

    def test_best_enforces_attainment_bar(self):
        trace = flash_crowd_trace(8, 10.0, 1.0, 0, 0, seed=4)
        pts = [evaluate(controlled_arm("a", 10.0), trace)]
        assert best(pts, min_attainment=0.99).arm == "a"
        with pytest.raises(ValueError, match="attainment"):
            best(pts, min_attainment=1.1)

    def test_one_arm_many_traces_resets_between_runs(self):
        # the same live arm object must give identical results on repeat
        arm = controlled_arm("reused", 10.0)
        trace = flash_crowd_trace(8, 50.0, 2.0, 2, 3, seed=6)
        p1 = evaluate(arm, trace)
        p2 = evaluate(arm, trace)
        assert p1 == p2
