import os
import sys

# Smoke tests and benches must see ONE device (the dry-run sets its own 512
# via launch/dryrun.py before importing jax — never set that globally here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Property tests import hypothesis; on containers without the wheel, fall
# back to the deterministic stub so collection (and the tests) still run.
sys.path.insert(0, os.path.dirname(__file__))
import _hypothesis_stub

_hypothesis_stub.install()

import jax
import pytest


@pytest.fixture(scope="session")
def host_mesh():
    from repro.launch.mesh import make_host_mesh

    return make_host_mesh()


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
