"""The multi-replica layer: replica-tagged TierSpecs, round-robin baseline,
per-replica pricing helpers, and the utilization clamp.

Replicas are ordinary tiers to the scheduler — that is the design — so
these tests pin the parts that make them *replicas*: the expansion rules
(``replicate`` / ``ReplicaSet``), the name <-> logical-tier mapping every
roll-up depends on, the independently-failing-unit invariant (per-replica
backends/breakers, never shared), the 1x1 bitwise degrade, and the
closed-form pricing (``replica_fits`` / ``mesh_overhead`` /
``replica_capacity``) the predictive router and the capacity planner read.
"""
import pytest

from repro.core.estimator import fanout_probe_points, replica_fits
from repro.core.cost_model import mesh_overhead, replica_capacity
from repro.core.health import CircuitBreaker
from repro.core.routing import (BUSY, CascadePolicy, Query, QueueManager,
                                ReplicaSet, RoundRobinPolicy, TierSpec,
                                dispatchable, replica_base, replica_name,
                                replicate)
from repro.core.simulator import (DeviceModel, FanOutModel, ServingSimulator,
                                  sharded_model)
from repro.core.telemetry import Telemetry


def base_model(beta=0.05, b=0.01):
    return DeviceModel("dev", beta=beta, b=b, a=0.0)


class TestReplicate:
    def test_one_by_one_is_the_original_spec(self):
        # bitwise today's path: same object, same name, factories unread
        spec = TierSpec("NPU", 4, model=base_model())
        out = replicate(spec, 1, 1,
                        backend=lambda h, r: pytest.fail("factory consulted"))
        assert out == [spec] and out[0] is spec

    def test_expansion_is_host_major_with_identity_tags(self):
        spec = TierSpec("NPU", 4, model=base_model(), quantized=True)
        out = replicate(spec, 2, 3)
        assert [t.name for t in out] == [
            replica_name("NPU", h, r) for h in range(2) for r in range(3)]
        assert all(t.replica_of == "NPU" for t in out)
        assert [t.host for t in out] == [0, 0, 0, 1, 1, 1]
        # per-replica policy knobs copy through
        assert all(t.depth == 4 and t.quantized for t in out)

    def test_factories_build_independent_units(self):
        # one backend / breaker INSTANCE per replica: a shared breaker
        # would quarantine every replica when one host dies
        spec = TierSpec("NPU", 4)
        out = replicate(spec, 2, 2,
                        model=lambda h, r: base_model(),
                        breaker=lambda h, r: CircuitBreaker())
        models = [t.model for t in out]
        breakers = [t.breaker for t in out]
        assert len(set(map(id, models))) == 4
        assert len(set(map(id, breakers))) == 4

    def test_rejects_bad_shapes_and_cache_tiers(self):
        spec = TierSpec("NPU", 4)
        with pytest.raises(ValueError):
            replicate(spec, 0, 1)
        with pytest.raises(ValueError):
            replicate(spec, 1, 0)
        with pytest.raises(ValueError):
            replicate(TierSpec("C", 0, cache=object()), 2, 1)

    def test_replica_base_round_trips(self):
        assert replica_base(replica_name("NPU", 1, 0)) == "NPU"
        assert replica_base(replica_name("CPU@big", 0, 7)) == "CPU@big"
        assert replica_base("NPU") == "NPU"       # identity on plain tiers
        assert replica_base("arrival") == "arrival"

    def test_replica_set_lenses(self):
        rs = ReplicaSet.build(TierSpec("NPU", 4, model=base_model()), 2, 2)
        assert rs.base == "NPU" and len(rs) == 4
        assert rs.names == [t.name for t in rs.specs]
        assert [t.name for t in rs.on_host(1)] == ["NPU@h1r0", "NPU@h1r1"]
        assert list(rs) == list(rs.specs)
        one = ReplicaSet.build(TierSpec("NPU", 4), 1, 1)
        assert one.names == ["NPU"]


class TestReplicasAreFirstClassTiers:
    """The scheduling core sees each replica as an independently-failing
    capacity unit: its own queue slot accounting, its own breaker gate."""

    def _tiers(self, depth=2, breakers=False):
        return replicate(
            TierSpec("NPU", depth, model=base_model()), 2, 2,
            model=lambda h, r: base_model(),
            breaker=(lambda h, r: CircuitBreaker(failure_threshold=1,
                                                 cooldown_s=1e9))
            if breakers else None)

    def test_capacity_sums_over_replicas(self):
        qm = QueueManager(self._tiers(depth=3))
        assert qm.max_concurrency == 12
        assert qm.degraded_max_concurrency == 12

    def test_tripped_replica_leaves_siblings_dispatchable(self):
        tiers = self._tiers(breakers=True)
        qm = QueueManager(tiers)
        qm.tier_failure("NPU@h0r1", now=0.0)
        up = [t.name for t in dispatchable(qm.tiers)]
        assert "NPU@h0r1" not in up and len(up) == 3
        assert qm.tripped() == ["NPU@h0r1"]
        # dispatch routes around the quarantined replica
        for i in range(6):
            assert qm.dispatch(Query(qid=i)) in up
        assert qm.dispatch(Query(qid=99)) == BUSY
        assert len(qm.queues["NPU@h0r1"]) == 0

    def test_per_replica_telemetry_and_rollup(self):
        qm = QueueManager(self._tiers())
        for i in range(8):
            qm.dispatch(Query(qid=i))
        names = [t.name for t in qm.tiers]
        assert sorted(qm.stats.dispatched) == sorted(names)
        roll = qm.stats.replica_rollup()
        assert set(roll) == {"NPU"}
        assert roll["NPU"]["dispatched"] == 8
        assert roll["NPU"]["replicas"] == sorted(names)
        assert sum(roll["NPU"]["dispatched_by_replica"].values()) == 8

    def test_des_runs_a_replica_topology(self):
        tiers = replicate(TierSpec("NPU", 4, model=base_model()), 2, 2,
                          model=lambda h, r: base_model())
        sim = ServingSimulator(tiers=tiers, slo_s=1.0)
        res = sim.run_burst(16)
        assert res.accepted == 16 and res.n_completed == 16
        assert sum(res.per_device.values()) == 16


class TestRoundRobinPolicy:
    def test_rotates_deterministically(self):
        tiers = [TierSpec(n, 8, model=base_model()) for n in ("A", "B", "C")]
        qm = QueueManager(tiers, policy=RoundRobinPolicy())
        got = [qm.dispatch(Query(qid=i)) for i in range(6)]
        assert got == ["A", "B", "C", "A", "B", "C"]

    def test_skips_tripped_tiers(self):
        br = CircuitBreaker(failure_threshold=1, cooldown_s=1e9)
        tiers = [TierSpec("A", 8, model=base_model(), breaker=br),
                 TierSpec("B", 8, model=base_model())]
        qm = QueueManager(tiers, policy=RoundRobinPolicy())
        qm.tier_failure("A", now=0.0)
        assert [qm.dispatch(Query(qid=i)) for i in range(3)] == ["B"] * 3

    def test_empty_when_nothing_dispatchable(self):
        br = CircuitBreaker(failure_threshold=1, cooldown_s=1e9)
        tiers = [TierSpec("A", 8, model=base_model(), breaker=br)]
        qm = QueueManager(tiers, policy=RoundRobinPolicy())
        qm.tier_failure("A", now=0.0)
        assert qm.dispatch(Query(qid=0)) == BUSY


class TestUtilizationClamp:
    """Regression for the brownout over-drive bug: queued + in-flight can
    stack above the live dispatchable capacity (a tripped tier shrinks the
    denominator while retry/failover re-dispatch keeps the survivors full,
    and an online ``set_depth`` can drop a tier's depth below its live
    backlog) — ``utilization()`` must report a FRACTION, never > 1."""

    def test_depth_shrink_below_live_backlog_clamps_to_one(self):
        qm = QueueManager([TierSpec("NPU", 4, model=base_model())],
                          policy=CascadePolicy())
        for i in range(4):
            assert qm.dispatch(Query(qid=i)) == "NPU"
        assert len(qm.pop_batch("NPU")) == 4       # all in-flight
        qm.set_depth("NPU", 2)                     # online recalibration
        # raw load/cap would be 4/2 = 2.0
        assert qm.utilization() == 1.0

    def test_tripped_tier_plus_retry_backlog_stays_in_unit_interval(self):
        brA = CircuitBreaker(failure_threshold=1, cooldown_s=1e9)
        tiers = [TierSpec("A", 4, model=base_model(), breaker=brA),
                 TierSpec("B", 4, model=base_model())]
        qm = QueueManager(tiers, policy=CascadePolicy())
        for i in range(4):
            assert qm.dispatch(Query(qid=i)) == "A"
        batch = qm.pop_batch("A")                  # in-flight on A
        qm.tier_failure("A", now=0.0)              # A trips mid-batch
        # failover re-dispatch fills the survivor to its watermark
        for q in batch:
            q.attempts += 1
            assert qm.dispatch(q, now=0.1) == "B"
        qm.set_depth("B", 2)    # survivor recalibrated below its backlog
        u = qm.utilization()
        assert 0.0 <= u <= 1.0 and u == 1.0

    def test_brownout_ewma_not_overdriven_in_one_sample(self):
        from repro.core.health import BrownoutController, NORMAL

        qm = QueueManager([TierSpec("NPU", 4, model=base_model())])
        for i in range(4):
            qm.dispatch(Query(qid=i))
        qm.pop_batch("NPU")
        qm.set_depth("NPU", 1)                     # raw ratio would be 4.0
        bo = BrownoutController(ewma_alpha=0.3)
        bo.observe(0.0, 0.0)                       # calm history
        # the clamped sample moves the EWMA by at most ewma_alpha * 1.0 —
        # a raw 4.0 would jump it to 1.2, straight through the 0.9
        # shedding threshold in a single dispatch
        assert bo.observe(qm.utilization(), 0.0) == NORMAL
        assert bo.utilization_ewma <= 0.3 + 1e-9

    def test_fully_tripped_topology_reads_one(self):
        br = CircuitBreaker(failure_threshold=1, cooldown_s=1e9)
        qm = QueueManager([TierSpec("A", 4, model=base_model(), breaker=br)])
        qm.tier_failure("A", now=0.0)
        assert qm.utilization() == 1.0


class TestReplicaPricing:
    def test_replica_fits_key_by_replica_name(self):
        tiers = replicate(TierSpec("NPU", 4), 1, 2,
                          model=lambda h, r: sharded_model(base_model(), 4))
        fits = replica_fits({t.name: t.model for t in tiers},
                            probe_points=fanout_probe_points(4))
        assert set(fits) == {"NPU@h0r0", "NPU@h0r1"}
        for f in fits.values():
            assert f.alpha > 0 and f.max_concurrency(1.0) > 0

    def test_replica_fits_price_degraded_replicas_individually(self):
        healthy = sharded_model(base_model(), 8)
        degraded = sharded_model(base_model(), 6)   # one host quarantined
        fits = replica_fits({"NPU@h0r0": healthy, "NPU@h1r0": degraded},
                            probe_points=fanout_probe_points(8))
        assert fits["NPU@h1r0"].alpha > fits["NPU@h0r0"].alpha
        assert fits["NPU@h1r0"].max_concurrency(1.0) < \
            fits["NPU@h0r0"].max_concurrency(1.0)

    def test_mesh_overhead_closed_form_matches_fanout_model(self):
        f = FanOutModel(base_model(), 8, fanout_beta_s=0.01,
                        hosts=2, interhost_beta_s=0.1)
        assert mesh_overhead(0.01, 8, 0.1, 2) == pytest.approx(f.overhead_s)
        assert mesh_overhead(0.01, 1) == 0.0
        assert mesh_overhead(0.01, 8) == pytest.approx(0.03)
        with pytest.raises(ValueError):
            mesh_overhead(0.01, 8, 0.1, 3)

    def test_replica_capacity(self):
        assert replica_capacity(44, 4) == 176
        assert replica_capacity(44, 4, down=1) == 132
        assert replica_capacity(44, 4, down=4) == 0
        with pytest.raises(ValueError):
            replica_capacity(44, 4, down=5)
        with pytest.raises(ValueError):
            replica_capacity(-1, 4)


def test_rollup_is_identity_shaped_on_plain_topologies():
    t = Telemetry()
    t.record_dispatch("NPU")
    t.record_dispatch("CPU")
    roll = t.replica_rollup()
    assert set(roll) == {"NPU", "CPU"}
    assert roll["NPU"]["replicas"] == ["NPU"]
    assert roll["NPU"]["dispatched"] == 1
