"""Golden-embedding regression: served vectors pinned against a checked-in
artifact.

``tests/golden/golden_embed.npz`` carries a tiny seeded embedder param tree,
8 fixed query payloads and the fp32 vectors the serving stack produced when
the golden was minted.  Every serving backend must keep reproducing them:

* fp32 (``JaxEmbedderBackend`` / ``BucketedEmbedderBackend`` /
  ``ShardedEmbedderBackend`` on a 1-device mesh) within 1e-6 — kernel,
  bucketing or sharding refactors cannot silently drift embeddings;
* bf16 within its documented 1e-2 cosine bar;
* int8 within its documented >= 0.99 cosine bar.

The params are LOADED, not regenerated: a jax PRNG change would otherwise
silently re-mint the baseline and the test would guard nothing.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.bucketing import BucketedEmbedderBackend
from repro.core.routing import Query
from repro.core.sharded_backend import ShardedEmbedderBackend
from repro.core.windve import JaxEmbedderBackend

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "golden_embed.npz")
MAX_TOKENS = 32


def golden_config():
    return dataclasses.replace(get_config("bge-large-zh-v1.5").smoke(),
                               name="bge-golden", num_layers=1, d_model=32,
                               num_heads=2, num_kv_heads=1, head_dim=16,
                               d_ff=64, vocab_size=128, embed_dim=16)


@pytest.fixture(scope="module")
def golden():
    data = np.load(GOLDEN)
    params: dict = {}
    for key in data.files:
        if not key.startswith("param:"):
            continue
        node, parts = params, key[len("param:"):].split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = data[key]
    queries = [Query(qid=i, payload=data[f"query:{i}"],
                     length=len(data[f"query:{i}"]))
               for i in range(8)]
    return golden_config(), params, queries, data["golden"]


def serve(backend, queries):
    # fresh Query objects: backends must not depend on shared identity
    out = backend.embed_batch([Query(qid=q.qid, payload=q.payload,
                                     length=q.length) for q in queries])
    return np.stack(out)


def max_cosine_distance(a, b):
    return float((1.0 - (a * b).sum(-1) /
                  (np.linalg.norm(a, axis=-1) *
                   np.linalg.norm(b, axis=-1))).max())


class TestFp32Golden:
    @pytest.mark.parametrize("backend_cls,kw", [
        (JaxEmbedderBackend, {}),
        (BucketedEmbedderBackend, {"min_seq_bucket": 8}),
        (ShardedEmbedderBackend, {"min_seq_bucket": 8}),
    ])
    def test_fp32_backends_match_golden(self, golden, backend_cls, kw):
        cfg, params, queries, want = golden
        be = backend_cls(cfg, params, max_tokens=MAX_TOKENS, dtype="fp32",
                         **kw)
        if backend_cls is ShardedEmbedderBackend:
            assert be.device_count == 1
        got = serve(be, queries)
        assert got.dtype == np.float32
        np.testing.assert_allclose(got, want, atol=1e-6,
                                   err_msg=f"{backend_cls.__name__} drifted "
                                           f"from the checked-in golden")

    def test_golden_vectors_are_unit_norm(self, golden):
        *_, want = golden
        np.testing.assert_allclose(np.linalg.norm(want, axis=-1), 1.0,
                                   atol=1e-5)


class TestReducedPrecisionBars:
    def test_bf16_within_documented_cosine_bar(self, golden):
        cfg, params, queries, want = golden
        be = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                    min_seq_bucket=8, dtype="bf16")
        got = serve(be, queries)
        assert got.dtype == np.float32          # fp32 pool_norm epilogue
        assert max_cosine_distance(got, want) <= 1e-2

    def test_int8_within_documented_cosine_bar(self, golden):
        cfg, params, queries, want = golden
        be = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                    min_seq_bucket=8, dtype="int8")
        got = serve(be, queries)
        assert got.dtype == np.float32
        assert max_cosine_distance(got, want) <= 0.01   # >= 0.99 cosine

    def test_w8a8_within_documented_cosine_bar(self, golden):
        """W8A8 (int8 weights AND dynamically quantized activations) serves
        within its documented >= 0.98 cosine bar against the pinned fp32
        golden vectors, still as fp32 unit vectors."""
        cfg, params, queries, want = golden
        be = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                    min_seq_bucket=8, dtype="int8_w8a8")
        got = serve(be, queries)
        assert got.dtype == np.float32
        np.testing.assert_allclose(np.linalg.norm(got, axis=-1), 1.0,
                                   atol=1e-3)
        assert max_cosine_distance(got, want) <= 0.02   # >= 0.98 cosine
