"""Sharding-rule tests: every param/cache spec must exactly divide on the
production mesh for EVERY assigned arch (jit input shardings require it)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P
from jax.tree_util import tree_flatten_with_path

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.models import api
from repro.parallel import sharding
from repro.steps.inputs import cache_specs


class FakeMesh:
    """Mesh stand-in (shape/axis names only) so tests don't need 512 devs."""

    def __init__(self, shape_map):
        self.axis_names = tuple(shape_map)
        self.shape = dict(shape_map)

        class _D:
            def __init__(self, s):
                self.shape = s

        self.devices = _D(tuple(shape_map.values()))

    @property
    def size(self):
        n = 1
        for v in self.shape.values():
            n *= v
        return n


MESH_1POD = FakeMesh({"data": 16, "model": 16})
MESH_2POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _axis_size(mesh, entry):
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _check_divisible(mesh, tree_shape, specs):
    flat_s, _ = tree_flatten_with_path(tree_shape)
    flat_p, _ = tree_flatten_with_path(specs,
                                       is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    for (path, leaf), (_, spec) in zip(flat_s, flat_p):
        assert len(spec) <= leaf.ndim, f"{path}: spec longer than rank"
        for d, entry in zip(leaf.shape, tuple(spec)):
            size = _axis_size(mesh, entry)
            assert d % size == 0, \
                f"{jax.tree_util.keystr(path)}: dim {d} not divisible by {size}"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("mesh", [MESH_1POD, MESH_2POD],
                         ids=["16x16", "2x16x16"])
def test_param_specs_divide(arch, mesh):
    cfg = get_config(arch)
    params_shape = jax.eval_shape(
        lambda: api.init_params(jax.random.PRNGKey(0), cfg, jnp.float32))
    specs = sharding.param_pspecs(mesh, params_shape)
    _check_divisible(mesh, params_shape, specs)


@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "hymba-1.5b",
                                  "qwen2-72b", "whisper-tiny",
                                  "qwen3-moe-30b-a3b"])
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_divide(arch, shape_name, ):
    from repro.configs import get_shape, shape_supported
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, _ = shape_supported(cfg, shape)
    if not ok:
        pytest.skip("shape unsupported for arch (by design)")
    cs = cache_specs(cfg, shape)
    for mesh in (MESH_1POD, MESH_2POD):
        specs = sharding.cache_pspecs(cfg, shape, mesh, cs)
        _check_divisible(mesh, cs, specs)


def test_moe_expert_sharding_primary_and_fallback():
    qwen = get_config("qwen3-moe-30b-a3b")     # 128 experts: divides 16
    granite = get_config("granite-moe-3b-a800m")  # 40 experts: does not
    for cfg, expect_expert_sharded in ((qwen, True), (granite, False)):
        ps = jax.eval_shape(
            lambda c=cfg: api.init_params(jax.random.PRNGKey(0), c))
        specs = sharding.param_pspecs(MESH_1POD, ps)
        spec = specs["blocks"]["ffn"]["w_gate"]
        if expect_expert_sharded:
            assert spec[1] == "model"          # (L, E, D, F): E on model
        else:
            assert spec[1] is None             # fallback: F on model instead
            assert spec[3] == "model"


def test_embed_vocab_fallback_on_odd_vocab():
    hymba = get_config("hymba-1.5b")           # vocab 32001: prime-ish
    ps = jax.eval_shape(
        lambda: api.init_params(jax.random.PRNGKey(0), hymba))
    specs = sharding.param_pspecs(MESH_1POD, ps)
    assert specs["embed"][0] is None           # can't shard 32001 by 16
    assert specs["embed"][1] == "data"


def test_batch_specs_by_kind():
    cfg = get_config("internvl2-2b")
    for name, shape in INPUT_SHAPES.items():
        specs = sharding.batch_pspecs(cfg, shape, MESH_1POD)
        if shape.kind == "decode":
            assert set(specs) == {"token"}      # stub patches live in cache
        else:
            assert "patches" in specs
    long = INPUT_SHAPES["long_500k"]
    specs = sharding.batch_pspecs(cfg, long, MESH_1POD)
    assert specs["token"] == P(None)            # batch=1: no batch sharding


def test_long_context_cache_seq_sharded_over_all_axes():
    cfg = get_config("falcon-mamba-7b")
    from repro.configs import get_shape
    shape = get_shape("long_500k")
    cs = cache_specs(cfg, shape)
    specs = sharding.cache_pspecs(cfg, shape, MESH_1POD, cs)
    # ssm state: DI over model
    assert specs["ssm"][2] == "model"
    shape32 = get_shape("decode_32k")
    cfg2 = get_config("qwen2-72b")
    cs2 = cache_specs(cfg2, shape32)
    specs2 = sharding.cache_pspecs(cfg2, shape32, MESH_1POD, cs2)
    assert specs2["k"][2] == "model"            # cache seq over model
    assert specs2["k"][1] == "data"             # batch over data
