"""Engine fault tolerance (``repro.core.windve``): structured failures,
retry/failover, deadlines, worker-death recovery, hook isolation and
shutdown hygiene.

The regression at the heart of this suite: a raising backend — or a dying
worker thread — must NEVER strand a client future.  Every submitted query
ends in a result or a structured :class:`ServeError` within a bounded wait.
"""
import sys
import time
import threading
import warnings

import numpy as np
import pytest

from repro.core.faults import BackendError
from repro.core.health import CircuitBreaker
from repro.core.routing import DeadlineExceeded, RetryPolicy, ServeError, \
    TierSpec
from repro.core.windve import WindVE

T0, T1 = "T0", "T1"


class OkBackend:
    """Serves instantly: distinct embedding per qid."""

    name = "ok"
    telemetry = None

    def embed_batch(self, queries):
        return [np.full(4, float(q.qid), np.float32) for q in queries]


class SlowBackend(OkBackend):
    """Serves after a fixed wall-clock sleep (occupies its worker)."""

    name = "slow"

    def __init__(self, delay_s):
        self.delay_s = delay_s

    def embed_batch(self, queries):
        time.sleep(self.delay_s)
        return super().embed_batch(queries)


class FailBackend:
    """Every execution raises — a permanently dead device pool."""

    name = "fail"
    telemetry = None

    def embed_batch(self, queries):
        raise BackendError("device pool down")


class KillerBackend:
    """Raises a non-Exception BaseException: the worker THREAD dies."""

    name = "killer"
    telemetry = None

    def embed_batch(self, queries):
        raise SystemExit("worker killed")


class WedgedBackend(OkBackend):
    """Blocks until released — a worker stuck inside a device call."""

    name = "wedged"

    def __init__(self):
        self.release = threading.Event()

    def embed_batch(self, queries):
        self.release.wait(timeout=30.0)
        return super().embed_batch(queries)


def pinned_submit(ve, n, **kw):
    """Submit a burst while holding the GIL so no worker acts mid-burst."""
    old = sys.getswitchinterval()
    sys.setswitchinterval(5.0)
    try:
        return [ve.submit(length=16, **kw) for _ in range(n)]
    finally:
        sys.setswitchinterval(old)


# ---------------------------------------------------------------------------
# structured failures + retry/failover
# ---------------------------------------------------------------------------

def test_backend_failure_is_a_structured_serve_error():
    ve = WindVE(tiers=[TierSpec(T0, 4, backend=FailBackend())])
    try:
        fut = ve.submit(length=16)
        with pytest.raises(ServeError) as ei:
            fut.result(timeout=10)
        err = ei.value
        assert err.kind == "backend_error"
        assert err.tier == T0
        assert err.attempts == 1              # default policy: one attempt
        assert isinstance(err.cause, BackendError)
        assert ve.stats.failed == 1
        assert ve.stats.backend_errors == {T0: 1}
        assert ve.stats.retries == {}
    finally:
        ve.shutdown()


def test_retry_fails_over_to_healthy_tier():
    ve = WindVE(
        tiers=[TierSpec(T0, 4, backend=FailBackend(),
                        breaker=CircuitBreaker(failure_threshold=1,
                                               cooldown_s=60.0)),
               TierSpec(T1, 4, backend=OkBackend())],
        retry=RetryPolicy(max_retries=3))
    try:
        fut = ve.submit(length=16)
        emb = fut.result(timeout=10)
        assert emb is not None
        assert ve.stats.failed == 0
        assert sum(ve.stats.retries.values()) >= 1
        assert ve.stats.backend_errors.get(T0, 0) >= 1
        assert ve.stats.breaker_trips == {T0: 1}
        assert ve.stats.per_device == {T1: 1}  # served by the healthy tier
    finally:
        ve.shutdown()


def test_retry_exhaustion_reports_attempt_count():
    ve = WindVE(tiers=[TierSpec(T0, 4, backend=FailBackend())],
                retry=RetryPolicy(max_retries=2))
    try:
        fut = ve.submit(length=16)
        with pytest.raises(ServeError) as ei:
            fut.result(timeout=10)
        assert ei.value.kind == "backend_error"
        assert ei.value.attempts == 3          # initial + 2 retries
        assert sum(ve.stats.retries.values()) == 2
    finally:
        ve.shutdown()


def test_retry_into_full_topology_is_no_capacity():
    # T0 (healthy, slow) is busy for the whole test; T1 fails and trips its
    # breaker, so the retry re-dispatch finds no surviving capacity
    ve = WindVE(
        tiers=[TierSpec(T0, 1, backend=SlowBackend(1.0)),
               TierSpec(T1, 1, backend=FailBackend(),
                        breaker=CircuitBreaker(failure_threshold=1,
                                               cooldown_s=60.0))],
        retry=RetryPolicy(max_retries=2))
    try:
        futs = pinned_submit(ve, 2)            # q1 -> T0 (slow), q2 -> T1
        assert all(f is not None for f in futs)
        with pytest.raises(ServeError) as ei:
            futs[1].result(timeout=10)
        assert ei.value.kind == "no_capacity"
        assert futs[0].result(timeout=10) is not None
        assert ve.stats.failed == 1
    finally:
        ve.shutdown()


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------

def test_dead_on_arrival_future_fails_immediately():
    ve = WindVE(tiers=[TierSpec(T0, 4, backend=OkBackend())])
    try:
        fut = ve.submit(length=16, deadline_s=0.0)
        assert fut is not None and fut.done()
        with pytest.raises(DeadlineExceeded) as ei:
            fut.result(timeout=1)
        assert ei.value.kind == "deadline"
        assert ve.stats.deadline_misses == {"arrival": 1}
        assert ve.stats.failed == 1
        assert ve.stats.dispatched == {}       # it never entered a queue
    finally:
        ve.shutdown()


def test_queued_query_expires_in_flight_completes_late():
    ve = WindVE(tiers=[TierSpec(T0, 4, backend=SlowBackend(0.3),
                                max_batch=1)],
                default_deadline_s=0.15)
    try:
        futs = pinned_submit(ve, 2)
        assert all(f is not None for f in futs)
        # one of the two went in-flight immediately and completes LATE (an
        # SLO violation, not a miss: a batch on a device can't be recalled);
        # the other sat queued past the deadline and was swept out
        results, errors = [], []
        for f in futs:
            try:
                results.append(f.result(timeout=10))
            except DeadlineExceeded as e:
                errors.append(e)
        assert len(results) == 1 and len(errors) == 1
        assert errors[0].tier == T0            # the tier it waited on
        assert ve.stats.deadline_misses == {T0: 1}
        assert ve.stats.failed == 1
        assert ve.stats.n_completed == 1
    finally:
        ve.shutdown()


# ---------------------------------------------------------------------------
# worker death — the "never strand a client" regression
# ---------------------------------------------------------------------------

def test_worker_death_never_strands_clients():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        ve = WindVE(tiers=[TierSpec(T0, 4, backend=KillerBackend(),
                                    max_batch=1)])
        try:
            futs = pinned_submit(ve, 4)
            assert all(f is not None for f in futs)
            kinds = []
            for f in futs:
                # bounded wait: before the drain existed these hung forever
                with pytest.raises(ServeError) as ei:
                    f.result(timeout=10)
                kinds.append(ei.value.kind)
            # the batch the dying worker owned fails as backend_error; the
            # stranded queued queries fail as worker_death via the drain
            assert "worker_death" in kinds
            assert ve.stats.failed == 4
            # the dead tier is quarantined: no future dispatch can land
            assert ve.qm.depth(T0) == 0
        finally:
            ve.shutdown()
    assert any("lost its last worker" in str(x.message) for x in w)


def test_worker_death_fails_over_queued_queries():
    ve = WindVE(
        tiers=[TierSpec(T0, 4, backend=KillerBackend(), max_batch=1),
               TierSpec(T1, 8, backend=OkBackend())],
        retry=RetryPolicy(max_retries=3))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        try:
            futs = pinned_submit(ve, 4)        # all land on T0 (cascade)
            assert all(f is not None for f in futs)
            for f in futs:
                assert f.result(timeout=10) is not None
            assert ve.stats.failed == 0
            assert ve.stats.per_device == {T1: 4}
            assert sum(ve.stats.retries.values()) >= 4
        finally:
            ve.shutdown()


# ---------------------------------------------------------------------------
# batch hooks + shutdown hygiene
# ---------------------------------------------------------------------------

def test_raising_hook_is_counted_and_serving_unaffected():
    ve = WindVE(tiers=[TierSpec(T0, 4, backend=OkBackend())])
    try:
        seen = []

        def bad_hook(tier, batch, lat):
            raise RuntimeError("hook bug")

        ve.add_batch_hook(bad_hook)
        ve.add_batch_hook(lambda tier, batch, lat: seen.append(len(batch)))
        futs = [ve.submit(length=16) for _ in range(3)]
        for f in futs:
            assert f.result(timeout=10) is not None
        assert ve.stats.hook_errors >= 1
        assert sum(seen) == 3                  # later hooks still ran
        assert ve.stats.failed == 0
        assert ve.stats.summary()["hook_errors"] == ve.stats.hook_errors
    finally:
        ve.shutdown()


def test_fault_free_run_keeps_summary_shape():
    ve = WindVE(tiers=[TierSpec(T0, 4, backend=OkBackend())])
    try:
        ve.submit(length=16).result(timeout=10)
        s = ve.stats.summary()
        # fault counters are omitted entirely on a fault-free run so
        # existing consumers see an unchanged record shape
        for key in ("failed", "deadline_misses", "retries",
                    "backend_errors", "clean_shutdown"):
            assert key not in s
    finally:
        ve.shutdown()


def test_clean_shutdown_flag():
    ve = WindVE(tiers=[TierSpec(T0, 4, backend=OkBackend())])
    ve.submit(length=16).result(timeout=10)
    assert ve.stats.clean_shutdown is None     # not shut down yet
    ve.shutdown()
    assert ve.stats.clean_shutdown is True
    assert ve.stats.summary()["clean_shutdown"] == 1.0


def test_leaked_worker_is_detected_and_named():
    be = WedgedBackend()
    ve = WindVE(tiers=[TierSpec(T0, 4, backend=be)])
    try:
        fut = ve.submit(length=16)
        time.sleep(0.05)                       # let the worker wedge
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            ve.shutdown()                      # join(2.0) times out
        assert ve.stats.clean_shutdown is False
        assert ve.stats.summary()["clean_shutdown"] == 0.0
        assert any("leaked" in str(x.message) and T0 in str(x.message)
                   for x in w)
    finally:
        be.release.set()                       # unwedge the daemon thread
        fut.result(timeout=10)
