"""End-to-end threaded WindVE engine tests (real JAX embedder on CPU)."""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.queue_manager import CPU, NPU
from repro.core.simulator import DeviceModel
from repro.core.windve import (JaxEmbedderBackend, ModeledBackend, WindVE,
                               calibrate_depths)
from repro.models import embedder

FAST_NPU = DeviceModel("fast-npu", beta=0.01, b=0.001, a=0.0)
SLOW_CPU = DeviceModel("slow-cpu", beta=0.05, b=0.01, a=0.0)


@pytest.fixture(scope="module")
def bge_smoke():
    cfg = get_config("bge-large-zh-v1.5").smoke()
    params = embedder.init_embedder(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_offload_and_busy(bge_smoke):
    cfg, params = bge_smoke
    ve = WindVE(ModeledBackend(FAST_NPU, embed_dim=cfg.d_model),
                JaxEmbedderBackend(cfg, params, max_tokens=16),
                npu_depth=4, cpu_depth=2)
    try:
        futs = [ve.submit(length=8) for _ in range(8)]
        accepted = [f for f in futs if f is not None]
        assert len(accepted) == 6              # 4 NPU + 2 CPU
        assert ve.stats.rejected == 2
        res = [f.result(timeout=30) for f in accepted]
        assert all(isinstance(r, np.ndarray) for r in res)
        assert ve.stats.per_device[NPU] == 4
        assert ve.stats.per_device[CPU] == 2
    finally:
        ve.shutdown()


def test_real_embedder_output_is_normalized(bge_smoke):
    cfg, params = bge_smoke
    be = JaxEmbedderBackend(cfg, params, max_tokens=16)
    from repro.core.queue_manager import Query
    out = be.embed_batch([Query(qid=1, length=8), Query(qid=2, length=12)])
    for e in out:
        assert e.shape == (cfg.d_model,)
        assert np.linalg.norm(e) == pytest.approx(1.0, abs=1e-3)


def test_single_backend_fallback():
    ve = WindVE(None, ModeledBackend(FAST_NPU, embed_dim=8),
                npu_depth=0, cpu_depth=3)
    try:
        futs = [ve.submit() for _ in range(4)]
        assert sum(f is not None for f in futs) == 3   # sole queue depth 3
        assert CPU not in ve.backends                  # promoted to main
    finally:
        ve.shutdown()


def test_calibrate_depths_linear():
    depths = calibrate_depths(lambda c: 0.02 * c + 0.2,
                              lambda c: 0.1 * c + 0.4, slo_s=1.0)
    assert depths[NPU] == 40
    assert depths[CPU] == 6


def test_queue_drains_and_accepts_again(bge_smoke):
    cfg, params = bge_smoke
    ve = WindVE(ModeledBackend(FAST_NPU, embed_dim=cfg.d_model), None,
                npu_depth=2, cpu_depth=0)
    try:
        f1, f2 = ve.submit(), ve.submit()
        assert ve.submit() is None
        f1.result(timeout=10), f2.result(timeout=10)
        deadline = time.monotonic() + 5
        while time.monotonic() < deadline:
            f3 = ve.submit()
            if f3 is not None:
                f3.result(timeout=10)
                break
            time.sleep(0.01)
        else:
            pytest.fail("queue never freed capacity")
    finally:
        ve.shutdown()
