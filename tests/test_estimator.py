"""Linear-regression queue-depth estimator (Eq. 12) tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.estimator import (LatencyFit, estimate_depth, fine_tune_depth,
                                  fit_latency, stress_test_depth)


class TestFit:
    def test_exact_linear_recovery(self):
        c = [1, 4, 16, 64]
        t = [0.3 + 0.02 * x for x in c]
        fit = fit_latency(c, t)
        assert fit.alpha == pytest.approx(0.02, abs=1e-9)
        assert fit.beta == pytest.approx(0.3, abs=1e-9)
        assert fit.r2 == pytest.approx(1.0, abs=1e-9)

    def test_nonnegative_constraint(self):
        fit = fit_latency([1, 2, 3, 4], [1.0, 0.8, 0.6, 0.4])  # negative slope
        assert fit.alpha >= 0 and fit.beta >= 0

    def test_depth_formula(self):
        fit = LatencyFit(alpha=0.0166, beta=0.27, r2=1.0)
        # paper V100/bge ballpark: (1 - 0.27)/0.0166 = 43.9 -> 43
        assert fit.max_concurrency(1.0) == 43

    def test_eq11_single_query_timeout(self):
        # paper Eq. 11: t^1_proc > T -> CPU unusable, depth 0
        fit = LatencyFit(alpha=0.2, beta=0.9, r2=1.0)
        assert fit.max_concurrency(1.0) == 0

    def test_needs_two_points(self):
        with pytest.raises(ValueError):
            fit_latency([1], [0.5])


@given(alpha=st.floats(0.001, 0.5), beta=st.floats(0.0, 0.9),
       slo=st.floats(1.0, 4.0))
@settings(max_examples=200, deadline=None)
def test_estimator_exact_on_linear_devices(alpha, beta, slo):
    """On a truly linear device the estimator IS the ground truth."""
    profile = lambda c: alpha * c + beta
    depth, fit = estimate_depth(profile, slo)
    assert fit.alpha == pytest.approx(alpha, rel=1e-6)
    # the returned depth meets the SLO and depth+1 would break it
    if depth > 0:
        assert profile(depth) <= slo + 1e-9
        assert profile(depth + 1) > slo - 1e-9


@given(alpha=st.floats(0.01, 0.2), beta=st.floats(0.0, 0.5),
       step=st.integers(1, 16))
@settings(max_examples=100, deadline=None)
def test_stress_test_never_exceeds_truth(alpha, beta, step):
    profile = lambda c: alpha * c + beta
    truth = int(np.floor((2.0 - beta) / alpha))
    st_depth = stress_test_depth(profile, 2.0, step=step)
    assert st_depth <= truth + 1         # +1 for exact-boundary float error
    assert truth - st_depth <= step      # at most one step of undershoot


def test_fine_tune_finds_peak():
    profile = lambda c: 0.05 * c + 0.2
    truth = int((1.0 - 0.2) / 0.05)      # 16
    assert fine_tune_depth(profile, 1.0, start=12, radius=8) == truth
    assert fine_tune_depth(profile, 1.0, start=30, radius=8) == truth


def test_estimator_beats_stress_test_on_convex_device():
    """The paper's Table 3 story: stress test with step 8 misses the peak."""
    profile = lambda c: 0.25 + 0.0154 * c + 2.75e-5 * c * c
    est, _ = estimate_depth(profile, 1.0)
    stress = stress_test_depth(profile, 1.0, step=8)
    fine = fine_tune_depth(profile, 1.0, start=est, radius=16)
    assert stress < fine                 # step-8 undershoots
    assert abs(est - fine) <= 8          # regression lands near the peak
