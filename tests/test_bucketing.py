"""Shape-bucketed execution layer: compile cache, padding waste, equality.

The contract under test: the bucketed backend serves embeddings NUMERICALLY
EQUAL to the fixed-max_tokens path (padding invariance via masked
attention), while compiling one executable per (B_bucket, S_bucket) instead
of one per raw batch size, and padding only to the bucket.
"""
import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.core.bucketing import (BucketedEmbedderBackend, bucket_length,
                                  default_buckets, length_bucket_fn,
                                  next_pow2)
from repro.core.routing import NPU, Query, TierSpec
from repro.core.telemetry import Telemetry
from repro.core.windve import JaxEmbedderBackend, WindVE
from repro.models import embedder

MAX_TOKENS = 64


@pytest.fixture(scope="module")
def bge_smoke():
    cfg = get_config("bge-large-zh-v1.5").smoke()
    params = embedder.init_embedder(jax.random.PRNGKey(0), cfg)
    return cfg, params


def queries(lengths, base_qid=0):
    return [Query(qid=base_qid + i, length=ln)
            for i, ln in enumerate(lengths)]


# ---------------------------------------------------------------- helpers --
class TestBucketHelpers:
    def test_next_pow2(self):
        assert [next_pow2(n) for n in (0, 1, 2, 3, 5, 8, 9, 1000)] == \
            [1, 1, 2, 4, 8, 8, 16, 1024]

    def test_bucket_length_clamps(self):
        assert bucket_length(10, min_bucket=16, max_bucket=128) == 16
        assert bucket_length(70, min_bucket=16, max_bucket=128) == 128
        assert bucket_length(500, min_bucket=16, max_bucket=128) == 128

    def test_length_bucket_fn(self):
        fn = length_bucket_fn(16, 128)
        assert fn(Query(qid=1, length=20)) == 32
        assert fn(Query(qid=2, length=33)) == 64

    def test_default_buckets_grid(self):
        grid = default_buckets(16, 128, min_seq_bucket=32)
        assert (1, 32) in grid and (16, 128) in grid
        assert len(grid) == 5 * 3            # B {1,2,4,8,16} x S {32,64,128}
        assert all(b == next_pow2(b) and s == next_pow2(s) for b, s in grid)

    def test_batch_plan_binary_decomposition(self, bge_smoke):
        cfg, params = bge_smoke
        be = BucketedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS)
        assert be._batch_plan(8) == [8]
        assert be._batch_plan(9) == [8, 1]        # no padding rows
        assert be._batch_plan(13) == [8, 4, 1]
        assert sum(be._batch_plan(7)) == 7        # decomposition: zero pad
        # min_batch_bucket trades padding rows for fewer launches
        be4 = BucketedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                      min_batch_bucket=4)
        assert be4._batch_plan(9) == [8, 4]       # tail rounded up to min
        assert be4._batch_plan(2) == [4]
        assert be4._batch_plan(13) == [16]        # ties prefer ONE launch


# ---------------------------------------------------------- compile cache --
class TestCompileCache:
    def test_same_bucket_no_retrace_new_bucket_retraces(self, bge_smoke):
        cfg, params = bge_smoke
        be = BucketedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                     min_seq_bucket=8)
        be.embed_batch(queries([10, 12, 9, 15]))       # bucket (4, 16)
        assert be.traces == 1
        be.embed_batch(queries([16, 11, 13, 14]))      # same bucket (4, 16)
        assert be.traces == 1, "retraced inside a warm bucket"
        assert be.bucket_hits == 1
        be.embed_batch(queries([30, 20]))              # new bucket (2, 32)
        assert be.traces == 2
        assert (4, 16) in be.warm_buckets and (2, 32) in be.warm_buckets

    def test_fixed_backend_retraces_per_batch_size(self, bge_smoke):
        cfg, params = bge_smoke
        be = JaxEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS)
        be.embed_batch(queries([10, 12, 9]))
        be.embed_batch(queries([16, 11]))              # new raw B -> retrace
        be.embed_batch(queries([30, 20]))              # same raw B -> cached
        assert be.traces == 2

    def test_prewarm_kills_compile_stalls(self, bge_smoke):
        cfg, params = bge_smoke
        be = BucketedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                     min_seq_bucket=8)
        grid = default_buckets(4, MAX_TOKENS, min_seq_bucket=8)
        n = be.prewarm(grid)
        assert n == len(grid) == be.traces
        for lens in ([5], [9, 9], [40, 33, 20], [7, 7, 7, 60]):
            be.embed_batch(queries(lens))
        assert be.traces == n, "serving retraced despite prewarm"
        assert be.prewarm(grid) == 0               # idempotent

    def test_prewarm_via_constructor(self, bge_smoke):
        cfg, params = bge_smoke
        be = BucketedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                     prewarm_buckets=[(2, 16), (2, 32)])
        assert be.traces == 2
        be.embed_batch(queries([10, 12]))
        assert be.traces == 2


# ------------------------------------------------------- numeric equality --
class TestBucketedEquality:
    def test_embeddings_equal_fixed_path(self, bge_smoke):
        """Bucket-padded batches must embed IDENTICALLY to max-padded ones
        (attention masks padded keys, so pad width is invisible)."""
        cfg, params = bge_smoke
        fixed = JaxEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS)
        buck = BucketedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                       min_seq_bucket=8)
        for lens in ([10, 40, 25], [5], [9, 9, 9, 9, 9],
                     [33, 7, 60, 12, 50, 21, 44]):     # plan [4,2,1]
            a = np.stack(fixed.embed_batch(queries(lens)))
            b = np.stack(buck.embed_batch(queries(lens)))
            np.testing.assert_allclose(a, b, atol=1e-5)

    def test_padded_waste_lower_than_fixed(self, bge_smoke):
        cfg, params = bge_smoke
        fixed = JaxEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS)
        buck = BucketedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                       min_seq_bucket=8)
        for lens in ([10, 12, 9], [8, 8, 8, 8], [20, 25]):
            fixed.embed_batch(queries(lens))
            buck.embed_batch(queries(lens))
        assert buck.real_tokens == fixed.real_tokens
        assert buck.padded_waste < fixed.padded_waste / 2


# ------------------------------------------------ truncation + telemetry --
class TestTruncationTelemetry:
    def test_both_backends_count_truncations(self, bge_smoke):
        cfg, params = bge_smoke
        tel = Telemetry()
        fixed = JaxEmbedderBackend(cfg, params, max_tokens=16, telemetry=tel)
        long_payload = [Query(qid=1, payload=np.arange(1, 40), length=39),
                        Query(qid=2, length=10)]
        fixed.embed_batch(long_payload)
        assert fixed.truncated == 1 and tel.truncated == 1
        buck = BucketedEmbedderBackend(cfg, params, max_tokens=16,
                                       telemetry=tel)
        buck.embed_batch(long_payload)
        assert buck.truncated == 1 and tel.truncated == 2

    def test_summary_surfaces_truncations(self):
        t = Telemetry(slo=1.0)
        t.record_dispatch(NPU)
        t.record_truncations(3)
        t.record_completion(Query(qid=1, arrival_t=0.0, done_t=0.5), NPU)
        s = t.summary()
        assert s["truncated"] == 3
        assert s["accepted"] == 1 and s["completed"] == 1
        assert s["violations"] == 0 and s["p50_s"] == pytest.approx(0.5)
        assert s[f"dispatched_{NPU}"] == 1

    def test_engine_wires_backend_telemetry(self, bge_smoke):
        """WindVE attaches its shared stats to backends, so truncations show
        up in the engine's Telemetry.summary()."""
        cfg, params = bge_smoke
        be = BucketedEmbedderBackend(cfg, params, max_tokens=16)
        ve = WindVE(tiers=[TierSpec(NPU, 8, backend=be,
                                    bucket_fn=length_bucket_fn(8, 16))])
        try:
            assert be.telemetry is ve.stats
            f = ve.submit(payload=np.arange(1, 40), length=39)
            f.result(timeout=30)
            assert ve.stats.summary()["truncated"] == 1
        finally:
            ve.shutdown()
