"""Training-loop integration: loss decreases, checkpoint resume is exact."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import train
from repro.steps import checkpoint


def test_loss_decreases(tmp_path):
    _, _, losses = train("stablelm-1.6b", steps=12, batch=4, seq=32,
                         smoke=True, lr=1e-3, log_every=100)
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


def test_checkpoint_resume_bitexact(tmp_path):
    ck = str(tmp_path / "ck.npz")
    # 6 straight steps
    p_full, o_full, l_full = train("stablelm-1.6b", steps=6, batch=2, seq=32,
                                   smoke=True, seed=3, log_every=100)
    # 3 steps -> checkpoint -> resume 3 steps
    train("stablelm-1.6b", steps=3, batch=2, seq=32, smoke=True, seed=3,
          ckpt=ck, log_every=100)
    p_res, o_res, l_res = train("stablelm-1.6b", steps=3, batch=2, seq=32,
                                smoke=True, seed=3, resume=ck, log_every=100)
    assert l_res == pytest.approx(l_full[3:], abs=1e-5)
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_checkpoint_shape_validation(tmp_path):
    path = str(tmp_path / "x.npz")
    tree = {"a": jnp.ones((2, 3)), "b": {"c": jnp.zeros((4,))}}
    checkpoint.save(path, tree, {"step": 7})
    back, meta = checkpoint.load(path, tree)
    assert meta["step"] == 7
    np.testing.assert_array_equal(np.asarray(back["a"]), np.ones((2, 3)))
    bad = {"a": jnp.ones((2, 4)), "b": {"c": jnp.zeros((4,))}}
    with pytest.raises(ValueError):
        checkpoint.load(path, bad)


def test_workload_stream_deterministic_and_restorable():
    from repro.data.workload import TokenStream, TrainBatchSpec
    spec = TrainBatchSpec(2, 16, 100)
    s1 = TokenStream(spec, seed=1)
    batches = [next(s1) for _ in range(4)]
    s2 = TokenStream(spec, seed=1)
    s2.restore(2)
    b2 = next(s2)
    np.testing.assert_array_equal(b2["tokens"], batches[2]["tokens"])
    assert batches[0]["tokens"].max() < 100
    # labels are next-token shifted
    np.testing.assert_array_equal(batches[0]["labels"][:, :-1],
                                  batches[0]["tokens"][:, 1:])
