"""Algorithm 2 branch coverage."""
from repro.core.device_detector import DeviceInventory, detect


def test_npu_and_cpu_heter_on():
    r = detect(DeviceInventory(npus=2, cpus=1), heter_requested=True)
    assert (r.device_main, r.device_auxiliary) == ("npu", "cpu")
    assert (r.worker_num_main, r.worker_num_auxiliary) == (2, 1)
    assert r.heter_enable


def test_npu_and_cpu_heter_off():
    r = detect(DeviceInventory(npus=2, cpus=1), heter_requested=False)
    assert (r.device_main, r.device_auxiliary) == ("npu", "none")
    assert not r.heter_enable
    assert r.worker_num_auxiliary == 0


def test_cpu_only_forces_heter_off():
    r = detect(DeviceInventory(npus=0, cpus=4), heter_requested=True)
    assert (r.device_main, r.device_auxiliary) == ("cpu", "none")
    assert not r.heter_enable
    assert r.worker_num_main == 4


def test_no_devices():
    r = detect(DeviceInventory(npus=0, cpus=0))
    assert r.device_main == "none"
    assert not r.heter_enable


def test_probe_on_this_container_is_cpu_only():
    r = detect()  # jax sees only CpuDevice here
    assert r.device_main == "cpu"
    assert not r.heter_enable
