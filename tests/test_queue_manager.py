"""Algorithm 1 semantics + property-based invariants."""
import threading

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.queue_manager import (BUSY, CPU, NPU, BoundedQueue, Query,
                                      QueueManager)


def q(i: int) -> Query:
    return Query(qid=i)


class TestAlgorithm1:
    def test_npu_priority(self):
        qm = QueueManager(npu_depth=2, cpu_depth=2)
        assert qm.dispatch(q(1)) == NPU
        assert qm.dispatch(q(2)) == NPU

    def test_overflow_to_cpu_then_busy(self):
        qm = QueueManager(npu_depth=1, cpu_depth=1)
        assert qm.dispatch(q(1)) == NPU
        assert qm.dispatch(q(2)) == CPU
        assert qm.dispatch(q(3)) == BUSY

    def test_heter_disabled_rejects_on_npu_full(self):
        qm = QueueManager(npu_depth=1, cpu_depth=8, heter_enable=False)
        assert qm.dispatch(q(1)) == NPU
        assert qm.dispatch(q(2)) == BUSY

    def test_zero_cpu_depth_means_no_cpu_queue(self):
        qm = QueueManager(npu_depth=1, cpu_depth=0)
        assert not qm.heter_enable
        assert qm.dispatch(q(1)) == NPU
        assert qm.dispatch(q(2)) == BUSY

    def test_max_concurrency(self):
        assert QueueManager(44, 8).max_concurrency == 52
        assert QueueManager(96, 22).max_concurrency == 118

    def test_inflight_counts_toward_depth(self):
        # paper: C^max bounds concurrency, not just waiting items
        qm = QueueManager(npu_depth=2, cpu_depth=0)
        qm.dispatch(q(1))
        qm.dispatch(q(2))
        batch = qm.queues[NPU].pop_batch(2)
        assert len(batch) == 2
        assert qm.dispatch(q(3)) == BUSY       # still in flight
        qm.queues[NPU].finish(2)
        assert qm.dispatch(q(4)) == NPU


@given(npu_depth=st.integers(0, 20), cpu_depth=st.integers(0, 20),
       n=st.integers(0, 100))
@settings(max_examples=200, deadline=None)
def test_dispatch_invariants(npu_depth, cpu_depth, n):
    """Invariants: queues never exceed depth; counts conserve; BUSY only
    when every queue is full; NPU fills before CPU receives anything."""
    if npu_depth <= 0:
        npu_depth = max(npu_depth, 0)
    qm = QueueManager(npu_depth, cpu_depth)
    results = [qm.dispatch(q(i)) for i in range(n)]
    n_npu = results.count(NPU)
    n_cpu = results.count(CPU)
    n_busy = results.count(BUSY)
    assert n_npu + n_cpu + n_busy == n
    assert n_npu <= npu_depth
    assert n_cpu <= (cpu_depth if qm.heter_enable else 0)
    assert n_npu == min(n, npu_depth)                     # NPU priority
    if qm.heter_enable:
        assert n_cpu == min(max(n - npu_depth, 0), cpu_depth)
    if n_busy:
        assert len(qm.queues[NPU]) >= npu_depth
        if qm.heter_enable:
            assert len(qm.queues[CPU]) >= cpu_depth
    assert qm.stats.accepted == n_npu + n_cpu
    assert qm.stats.busy == n_busy


@given(depth=st.integers(1, 16), ops=st.lists(
    st.tuples(st.booleans(), st.integers(1, 4)), max_size=50))
@settings(max_examples=100, deadline=None)
def test_bounded_queue_never_overflows(depth, ops):
    bq = BoundedQueue(depth)
    pushed = 0
    for is_push, k in ops:
        if is_push:
            for i in range(k):
                if bq.push(q(pushed)):
                    pushed += 1
                assert len(bq) <= depth
        else:
            batch = bq.pop_batch(k)
            assert len(bq) <= depth
            bq.finish(len(batch))
    assert len(bq) <= depth


def test_thread_safety_under_concurrent_dispatch():
    qm = QueueManager(50, 25)
    results = []
    lock = threading.Lock()

    def worker(base):
        local = [qm.dispatch(q(base + i)) for i in range(30)]
        with lock:
            results.extend(local)

    threads = [threading.Thread(target=worker, args=(i * 100,))
               for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert results.count(NPU) == 50
    assert results.count(CPU) == 25
    assert results.count(BUSY) == 120 - 75
