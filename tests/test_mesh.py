"""Mesh builders: up-front validation and the replica mesh carver.

The in-process tests run on this container's single CPU device, which is
exactly the regime the validation bugfix targets: requesting a 16x16
production mesh (or a 2x2 replica topology) used to die inside
``jax.make_mesh`` with an opaque reshape error; now every builder raises a
``ValueError`` naming required vs available device counts BEFORE touching
jax.  The multi-device paths (carving a forced 8-device pool into replica
groups) run in a subprocess under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` because the flag
must be set before jax initializes its backends.
"""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch.mesh import (make_host_mesh, make_production_mesh,
                               make_replica_meshes, make_serve_mesh)

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run_forced(devices: int, body: str) -> str:
    """Run a snippet in a subprocess with a forced CPU device count."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", "")).strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src")] +
        env.get("PYTHONPATH", "").split(os.pathsep))
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                          env=env, capture_output=True, text=True,
                          timeout=300)
    assert proc.returncode == 0, f"{proc.stdout}\n{proc.stderr}"
    return proc.stdout


class TestValidation:
    def test_production_mesh_error_names_both_counts(self):
        with pytest.raises(ValueError) as e:
            make_production_mesh()
        msg = str(e.value)
        assert "256" in msg and "1" in msg       # required vs available

    def test_multi_pod_error_names_both_counts(self):
        with pytest.raises(ValueError) as e:
            make_production_mesh(multi_pod=True)
        assert "512" in str(e.value)

    def test_host_mesh_fits_one_device(self):
        assert make_host_mesh().devices.size == 1

    def test_serve_mesh_rejects_empty_pool(self):
        with pytest.raises(ValueError):
            make_serve_mesh([])

    def test_replica_meshes_one_by_one_degrades_to_serve_mesh(self):
        ms = make_replica_meshes(1, 1)
        assert len(ms) == 1
        assert ms[0].shape == make_serve_mesh().shape

    def test_replica_meshes_reject_oversubscription(self):
        with pytest.raises(ValueError) as e:
            make_replica_meshes(2, 2)            # 4 groups, 1 device
        msg = str(e.value)
        assert "4" in msg and "1" in msg and "replica" in msg

    def test_replica_meshes_reject_bad_shape(self):
        with pytest.raises(ValueError):
            make_replica_meshes(0, 1)
        with pytest.raises(ValueError):
            make_replica_meshes(1, -1)


class TestForcedMultiDevice:
    """Real carving over a forced 8-device CPU pool (subprocess: XLA_FLAGS
    must precede jax backend init)."""

    def test_carves_disjoint_equal_groups(self):
        out = _run_forced(8, """
            import jax
            from repro.launch.mesh import make_replica_meshes
            ms = make_replica_meshes(2, 2)
            assert len(ms) == 4
            seen = []
            for m in ms:
                devs = list(m.devices.flat)
                assert len(devs) == 2, m
                assert m.shape == {"data": 2, "model": 1}
                seen += [d.id for d in devs]
            assert sorted(seen) == [d.id for d in jax.local_devices()]
            # host-major order: group g = h * replicas + r
            assert seen == sorted(seen)
            print("OK", len(ms))
        """)
        assert "OK 4" in out

    def test_uneven_split_raises_named_error(self):
        out = _run_forced(8, """
            from repro.launch.mesh import make_replica_meshes
            try:
                make_replica_meshes(3, 1)
            except ValueError as e:
                assert "8" in str(e) and "3" in str(e), e
                print("RAISED")
        """)
        assert "RAISED" in out

    def test_pool_subset_and_full_serve_mesh(self):
        out = _run_forced(8, """
            import jax
            from repro.launch.mesh import make_replica_meshes, make_serve_mesh
            full = make_serve_mesh()
            assert full.devices.size == 8
            half = make_replica_meshes(1, 2, jax.local_devices()[:4])
            assert [m.devices.size for m in half] == [2, 2]
            print("OK")
        """)
        assert "OK" in out
