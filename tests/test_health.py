"""Circuit-breaker state machine (``repro.core.health``).

The breaker is clock-free (callers pass ``now``), so every transition here
is driven explicitly — the same contract both drivers rely on for
deterministic trip/recover sequences.
"""
import pytest

from repro.core.health import CLOSED, HALF_OPEN, OPEN, CircuitBreaker
from repro.core.routing import QueueManager, TierSpec, dispatchable


def test_starts_closed_and_dispatchable():
    br = CircuitBreaker()
    assert br.state == CLOSED
    assert br.dispatchable
    assert br.trips == 0 and br.recoveries == 0


def test_trips_after_consecutive_failures():
    br = CircuitBreaker(failure_threshold=3, cooldown_s=1.0)
    br.record_failure(now=0.0)
    br.record_failure(now=0.1)
    assert br.state == CLOSED
    br.record_failure(now=0.2)
    assert br.state == OPEN
    assert not br.dispatchable
    assert br.trips == 1
    assert br.last_trip_reason == "failures"


def test_success_resets_failure_streak():
    br = CircuitBreaker(failure_threshold=2)
    br.record_failure(now=0.0)
    br.record_success(0.01, now=0.1)
    br.record_failure(now=0.2)          # streak restarts at 1
    assert br.state == CLOSED
    br.record_failure(now=0.3)
    assert br.state == OPEN


def test_cooldown_then_half_open_probe_recovers():
    br = CircuitBreaker(failure_threshold=1, cooldown_s=1.0)
    br.record_failure(now=0.0)
    assert br.state == OPEN
    assert br.tick(0.5) == OPEN          # cooldown not elapsed
    assert br.tick(1.0) == HALF_OPEN     # dispatchable again: the probe
    assert br.dispatchable
    br.record_success(0.02, now=1.1)
    assert br.state == CLOSED
    assert br.recoveries == 1
    # recovery restarts the latency EWMA from the probe, not the stale
    # pre-trip history
    assert br.latency_ewma_s == pytest.approx(0.02)


def test_half_open_probe_failure_reopens():
    br = CircuitBreaker(failure_threshold=1, cooldown_s=1.0)
    br.record_failure(now=0.0)
    br.tick(1.0)
    br.record_failure(now=1.0)
    assert br.state == OPEN
    assert br.trips == 2
    assert br.last_trip_reason == "probe-failure"
    # the new cooldown runs from the probe failure
    assert br.tick(1.5) == OPEN
    assert br.tick(2.0) == HALF_OPEN


def test_failure_while_open_extends_cooldown():
    br = CircuitBreaker(failure_threshold=1, cooldown_s=1.0)
    br.record_failure(now=0.0)           # open until 1.0
    br.record_failure(now=0.8)           # in-flight stragglers: until 1.8
    assert br.tick(1.0) == OPEN
    assert br.tick(1.8) == HALF_OPEN


def test_latency_ewma_stall_trip():
    br = CircuitBreaker(latency_trip_s=0.5, ewma_alpha=1.0)
    br.record_success(0.1, now=0.0)
    assert br.state == CLOSED
    br.record_success(0.9, now=0.1)      # alpha=1: EWMA == last sample
    assert br.state == OPEN
    assert br.last_trip_reason == "latency"


def test_no_latency_trip_when_unset():
    br = CircuitBreaker()                # latency_trip_s=None
    for i in range(10):
        br.record_success(100.0, now=float(i))
    assert br.state == CLOSED


def test_clock_is_monotone():
    br = CircuitBreaker(failure_threshold=1, cooldown_s=1.0)
    br.tick(5.0)
    br.record_failure(now=0.0)           # stale now: clock stays at 5.0
    assert br.tick(5.9) == OPEN          # open until 5.0 + 1.0
    assert br.tick(6.0) == HALF_OPEN


def test_reset_restores_fresh_closed_state():
    br = CircuitBreaker(failure_threshold=1)
    br.record_failure(now=0.0)
    br.reset()
    assert br.state == CLOSED
    assert br.trips == 0 and br.consecutive_failures == 0
    assert br.latency_ewma_s is None


def test_snapshot_fields():
    br = CircuitBreaker(failure_threshold=1)
    br.record_failure(now=0.0)
    snap = br.snapshot()
    assert snap["state"] == OPEN
    assert snap["trips"] == 1
    assert snap["last_trip_reason"] == "failures"


@pytest.mark.parametrize("kw", [
    dict(failure_threshold=0), dict(cooldown_s=0.0),
    dict(latency_trip_s=-1.0), dict(ewma_alpha=0.0), dict(ewma_alpha=1.5),
])
def test_constructor_validation(kw):
    with pytest.raises(ValueError):
        CircuitBreaker(**kw)


# ---------------------------------------------------------------------------
# routing integration: dispatchable() filtering + degraded capacity
# ---------------------------------------------------------------------------

def two_tier_qm():
    tiers = [TierSpec("A", 4, breaker=CircuitBreaker(failure_threshold=1,
                                                     cooldown_s=1.0)),
             TierSpec("B", 6)]
    return QueueManager(tiers), tiers


def test_open_breaker_removed_from_dispatchable():
    qm, tiers = two_tier_qm()
    assert [t.name for t in dispatchable(tiers)] == ["A", "B"]
    qm.tier_failure("A", now=0.0)
    assert [t.name for t in dispatchable(tiers)] == ["B"]
    assert qm.tripped() == ["A"]
    # the queue still exists — the breaker gates admission, not drain
    assert "A" in qm.queues


def test_degraded_max_concurrency_tracks_breaker_state():
    qm, tiers = two_tier_qm()
    assert qm.degraded_max_concurrency == 10
    assert qm.max_concurrency == 10
    qm.tier_failure("A", now=0.0)
    assert qm.degraded_max_concurrency == 6
    assert qm.max_concurrency == 10      # the structural contract is intact
    # recovery: cooldown elapses (half-open) and the probe succeeds
    tiers[0].breaker.tick(1.0)
    qm.tier_success("A", 0.01, now=1.1)
    assert qm.degraded_max_concurrency == 10
    assert qm.stats.breaker_trips == {"A": 1}
    assert qm.stats.breaker_recoveries == {"A": 1}


def test_tier_failure_counts_backend_error_even_without_breaker():
    qm = QueueManager([TierSpec("A", 2)])
    qm.tier_failure("A", now=0.0)
    assert qm.stats.backend_errors == {"A": 1}
    assert qm.stats.breaker_trips == {}


def test_reset_closes_breakers():
    qm, tiers = two_tier_qm()
    qm.tier_failure("A", now=0.0)
    assert qm.tripped() == ["A"]
    qm.reset()
    assert qm.tripped() == []
    assert tiers[0].breaker.state == CLOSED
