"""Optimized-path correctness: every §Perf flag must be numerically
equivalent to the baseline path (fp32; bf16 MoE routing ties excepted —
see EXPERIMENTS.md §Perf notes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import perf_flags
from repro.configs import get_config
from repro.models import api, layers as L, lm


@pytest.fixture(autouse=True)
def _reset():
    perf_flags.reset_flags()
    yield
    perf_flags.reset_flags()


KEY = jax.random.PRNGKey(0)


def _fwd(arch, **flags):
    cfg = get_config(arch).smoke()
    if cfg.is_moe:
        cfg = cfg.replace(capacity_factor=float(cfg.num_experts))
    params = api.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 48), 0, cfg.vocab_size)
    base, _ = lm.forward(params, cfg, toks)
    perf_flags.set_flags(**flags)
    opt, _ = lm.forward(params, cfg, toks)
    perf_flags.reset_flags()
    return float(jnp.abs(base.astype(jnp.float32) -
                         opt.astype(jnp.float32)).max())


def test_attn_band_skip_exact():
    assert _fwd("stablelm-1.6b", attn_band_skip=True) == 0.0
    assert _fwd("starcoder2-7b", attn_band_skip=True) == 0.0   # window
    assert _fwd("hymba-1.5b", attn_band_skip=True) == 0.0


def test_mamba_chunked_scan_exact():
    assert _fwd("falcon-mamba-7b", mamba_chunk=16) == 0.0
    assert _fwd("hymba-1.5b", mamba_chunk=32) == 0.0


def test_moe_row_dispatch_fp32_exact():
    """fp32 single layer: row dispatch == global dispatch == per-token ref."""
    cfg = get_config("qwen3-moe-30b-a3b").smoke().replace(capacity_factor=4.0)
    p = L.init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (4, 32, cfg.d_model))
    ya, aux_a = L.apply_moe(p, cfg, x)
    perf_flags.set_flags(moe_row_dispatch=True)
    yb, aux_b = L.apply_moe(p, cfg, x)
    perf_flags.reset_flags()
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb), atol=1e-6)
    assert float(aux_a) == pytest.approx(float(aux_b), abs=1e-6)


def test_decode_fori_exact():
    for arch in ("stablelm-1.6b", "hymba-1.5b", "starcoder2-7b"):
        cfg = get_config(arch).smoke()
        params = api.init_params(KEY, cfg)
        toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
        _, cache = lm.prefill(params, cfg, toks, max_len=24,
                              cache_dtype=jnp.float32)
        nxt = jnp.array([1, 2], dtype=jnp.int32)
        lg1, c1 = lm.decode_step(params, cfg, nxt, cache)
        perf_flags.set_flags(decode_fori=True)
        lg2, c2 = lm.decode_step(params, cfg, nxt, cache)
        perf_flags.reset_flags()
        assert float(jnp.abs(lg1.astype(jnp.float32) -
                             lg2.astype(jnp.float32)).max()) == 0.0
        for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_decode_shard_map_single_device_mesh():
    """Flash-decode path on the host mesh (1x1 shards = trivial combine)."""
    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import make_host_mesh
    from repro.steps.serve import build_decode_step

    mesh = make_host_mesh()
    cfg = get_config("stablelm-1.6b").smoke()
    params = api.init_params(KEY, cfg, jnp.float32)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    _, cache = lm.prefill(params, cfg, toks, max_len=32,
                          cache_dtype=jnp.float32)
    nxt = jnp.array([1, 2], dtype=jnp.int32)
    shape = ShapeConfig("t", 32, 2, "decode")
    from repro.launch.mesh import mesh_context
    with mesh_context(mesh):
        step0 = build_decode_step(cfg, shape, mesh)
        t0, c0 = jax.jit(step0)(params, cache, {"token": nxt})
        perf_flags.set_flags(decode_shard_map=True)
        step1 = build_decode_step(cfg, shape, mesh)
        t1, c1 = jax.jit(step1)(params, cache, {"token": nxt})
        perf_flags.reset_flags()
    assert bool((t0 == t1).all())
    np.testing.assert_allclose(np.asarray(c0["k"]), np.asarray(c1["k"]),
                               atol=1e-6)


def test_serve_tp_only_specs_drop_data_axis():
    from repro.parallel import sharding
    from tests.test_sharding import MESH_1POD

    cfg = get_config("qwen2-72b")
    ps = jax.eval_shape(lambda: api.init_params(KEY, cfg, jnp.bfloat16))
    train_specs = sharding.param_pspecs(MESH_1POD, ps)
    serve_specs = sharding.param_pspecs(MESH_1POD, ps, mode="serve")
    assert train_specs["blocks"]["attn"]["wq"][1] == "data"
    assert serve_specs["blocks"]["attn"]["wq"][1] is None
    assert serve_specs["blocks"]["attn"]["wq"][2] == "model"


def test_parse_opt_roundtrip():
    kw = perf_flags.parse_opt("mamba_chunk=32,attn_band_skip=1,"
                              "remat_policy=dots,serve_tp_only=0")
    assert kw == {"mamba_chunk": 32, "attn_band_skip": True,
                  "remat_policy": "dots", "serve_tp_only": False}


def test_parse_opt_embed_serving_flags():
    kw = perf_flags.parse_opt("embed_dtype=bf16,embed_donate=1,embed_async=0")
    assert kw == {"embed_dtype": "bf16", "embed_donate": True,
                  "embed_async": False}
    flags = perf_flags.set_flags(**kw)
    assert flags.embed_dtype == "bf16" and flags.embed_donate
    perf_flags.reset_flags()
    assert perf_flags.FLAGS.embed_dtype == "fp32"   # baseline oracle


def test_parse_opt_unknown_flag_lists_valid_names():
    with pytest.raises(ValueError) as ei:
        perf_flags.parse_opt("mamba_chunk=16,no_such_flag=1")
    msg = str(ei.value)
    assert "no_such_flag" in msg
    assert "mamba_chunk" in msg and "embed_dtype" in msg  # lists valid flags
