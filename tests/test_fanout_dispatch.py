"""Acceptance tests for the sharded-aware DES fan-out + predictive dispatch.

Two claims from the PR contract:

1. With ``devices=8``, ``estimate_depth`` fitted on the fan-out
   ``ModeledBackend`` matches the depth fitted directly on MEASURED
   ``ShardedEmbedderBackend`` service times (forced 8-device host mesh)
   within +-1 depth unit — i.e. the fan-out model reproduces the real
   sharded service curve rather than distorting it (wrong per-device row
   mapping, wrong chunking, wrong probe alignment all break this), and its
   per-chunk latency predictions stay within a factor-2 band of an
   independent measurement run (loose enough for a 2-core CI box, tight
   enough to kill a model that forgot to divide rows by devices — that one
   is ~8x off at depth).

2. ``--policy predictive`` beats the cascade on p95 e2e latency at equal
   concurrency in the DES A/B that lands in
   ``BENCH_table3_queue_depth.json`` (same depths, same diurnal Poisson
   trace, deterministic seed).
"""
import os
import subprocess
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
if ROOT not in sys.path:                      # benchmarks/ is a namespace
    sys.path.insert(0, ROOT)                  # package under the repo root


# ------------------------------------------------- predictive vs cascade --
class TestPredictiveBeatsCascade:
    def _ab(self):
        from benchmarks.table3_queue_depth import policy_ab

        return policy_ab(policies=("cascade", "predictive"))

    def test_p95_beats_cascade_at_equal_concurrency(self):
        ab = self._ab()
        c, p = ab["cascade"], ab["predictive"]
        assert p["p95_s"] < c["p95_s"], (p["p95_s"], c["p95_s"])
        # the margin is deterministic (seeded DES): keep a real gap so a
        # pricing regression cannot hide inside float jitter
        assert c["p95_s"] / p["p95_s"] >= 1.05

    def test_predictive_does_not_trade_the_tail_for_rejections(self):
        ab = self._ab()
        c, p = ab["cascade"], ab["predictive"]
        assert p["rejected"] <= c["rejected"]
        assert p["violations"] < c["violations"]
        assert p["accepted"] >= c["accepted"]


# --------------------------------------------- 8-device depth calibration --
_SUBPROCESS_PROBE = r"""
import os
os.environ["JAX_PLATFORMS"] = "cpu"
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")
import time
import numpy as np
import jax
from repro.configs import get_config
from repro.core.estimator import (estimate_depth, fanout_probe_points,
                                  fit_latency)
from repro.core.routing import Query
from repro.core.sharded_backend import ShardedEmbedderBackend
from repro.core.simulator import DeviceModel, profile_fn_for
from repro.core.windve import ModeledBackend
from repro.models import embedder

assert len(jax.devices()) == 8
cfg = get_config("bge-large-zh-v1.5").smoke()
params = embedder.init_embedder(jax.random.PRNGKey(0), cfg)
be = ShardedEmbedderBackend(cfg, params, max_tokens=32, min_seq_bucket=8)
assert be.device_count == 8

CS = (32, 64, 128, 256)        # single pow2 chunks: 4..32 rows per device

def measure(c, repeats=5):
    batch = [Query(qid=j, length=24) for j in range(c)]
    best = float("inf")
    for _ in range(repeats):
        t0 = time.monotonic()
        be.embed_batch(batch)
        best = min(best, time.monotonic() - t0)
    return best

for c in (24, 48) + CS:        # compile every shape before timing
    measure(c, repeats=2)

# run A: measured sharded service times -> per-DEVICE Eq. 12 fit -> the
# fan-out ModeledBackend the DES/calibrator would use for this tier
tA = [measure(c) for c in CS]
per_dev = fit_latency([c // 8 for c in CS], tA)
# ref_length must match the measured query length, or DeviceModel's
# length scaling silently rescales the fitted compute term by 24/75
base = DeviceModel("measured-1dev", beta=per_dev.beta, b=per_dev.alpha,
                   a=0.0, ref_length=24)
backend = ModeledBackend(base, embed_dim=4, devices=8)
slo = per_dev.beta + 12.5 * per_dev.alpha / 8          # target depth ~12

d_model, fitm = estimate_depth(
    profile_fn_for(backend.model, length=24), slo,
    probe_points=fanout_probe_points(8, (4, 8, 16, 32)))

# the direct fit of the SAME measured service curve against concurrency
fit_meas = fit_latency(list(CS), tA)
d_meas = fit_meas.max_concurrency(slo)
print(f"DEPTHS {d_model} {d_meas}")

# run B: independent measurements (incl. non-pow2 batches that exercise
# the multi-chunk plan).  Per-point timings on a 2-core box oversubscribed
# by 8 fake devices jitter by ~2x, so the guard is a factor-4 per-point cap
# plus a factor-2 geometric-mean cap: random jitter averages out, while a
# structurally wrong model (per-device rows == C, i.e. fan-out forgotten)
# is ~8x off at the large batches and fails both.
import math
ratios = []
for c in (24, 48) + CS:
    want = backend.model.latency(c, 24)
    got = measure(c, repeats=7)
    ratio = max(want, got) / max(min(want, got), 1e-9)
    ratios.append(ratio)
    print(f"ADEQ {c} model={want*1e3:.2f}ms measured={got*1e3:.2f}ms "
          f"ratio={ratio:.2f}")
    assert ratio <= 4.0, (c, want, got)
gmean = math.exp(sum(math.log(r) for r in ratios) / len(ratios))
print(f"ADEQ-GMEAN {gmean:.2f}")
assert gmean <= 2.0, ratios
print("FANOUT-8DEV-OK")
"""


def test_eight_device_fanout_depth_matches_measured():
    """Forced 8-device host mesh in a subprocess (the suite's own jax must
    keep its single device, see conftest)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src")] +
        env.get("PYTHONPATH", "").split(os.pathsep))
    proc = subprocess.run([sys.executable, "-c", _SUBPROCESS_PROBE],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "FANOUT-8DEV-OK" in proc.stdout
    depths = [ln for ln in proc.stdout.splitlines()
              if ln.startswith("DEPTHS")][0].split()
    d_model, d_meas = int(depths[1]), int(depths[2])
    assert abs(d_model - d_meas) <= 1, (d_model, d_meas)
