"""Device-sharded serving path: serve-mode spec rules, mesh degrade
behaviour, bf16-vs-fp32 parity, donation/async correctness, staging reuse
and the engine's double-buffered worker.

The spec-rule tests use the FakeMesh idiom from ``test_sharding`` (axis
names/sizes only, no real devices); the real multi-device mesh runs in a
subprocess with a forced 8-device host platform, because the device count is
fixed at jax backend init and the suite must keep seeing one device (see
``conftest``)."""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core.bucketing import BucketedEmbedderBackend, length_bucket_fn
from repro.core.routing import NPU, Query, TierSpec
from repro.core.sharded_backend import ShardedEmbedderBackend, _serve_devices
from repro.core.telemetry import Telemetry
from repro.core.windve import WindVE
from repro.models import embedder
from repro.parallel import sharding
from tests.test_sharding import FakeMesh

MAX_TOKENS = 64


@pytest.fixture(scope="module")
def bge_smoke():
    cfg = get_config("bge-large-zh-v1.5").smoke()
    params = embedder.init_embedder(jax.random.PRNGKey(0), cfg)
    return cfg, params


def queries(lengths, base_qid=0, payloads=False, vocab=1000):
    rng = np.random.default_rng(3)
    return [Query(qid=base_qid + i, length=ln,
                  payload=(rng.integers(1, vocab, ln) if payloads else None))
            for i, ln in enumerate(lengths)]


def cosine_distance(a, b):
    return float((1.0 - (a * b).sum(-1) /
                  (np.linalg.norm(a, axis=-1) *
                   np.linalg.norm(b, axis=-1))).max())


# ------------------------------------------------- serve-mode spec rules --
class TestServeModeSpecs:
    """Satellite: serve-mode sharding rules for the embedder param tree over
    a multi-device data-parallel host mesh (8 x 1)."""

    MESH = FakeMesh({"data": 8, "model": 1})

    def _specs(self, bge_smoke):
        cfg, params = bge_smoke
        shape = jax.eval_shape(lambda: params)
        return sharding.param_pspecs(self.MESH, shape, mode="serve")

    def test_weights_resident_no_data_axis_specs(self, bge_smoke):
        specs = self._specs(bge_smoke)
        flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert flat, "no specs produced for the embedder tree"
        for spec in flat:
            for entry in tuple(spec):
                axes = entry if isinstance(entry, tuple) else (entry,)
                assert "data" not in axes, \
                    f"serve-mode spec {spec} FSDP-shards a weight over data"

    def test_train_mode_does_shard_weights_over_data(self, bge_smoke):
        cfg, params = bge_smoke
        shape = jax.eval_shape(lambda: params)
        train = sharding.param_pspecs(self.MESH, shape, mode="train")
        flat = jax.tree.leaves(train, is_leaf=lambda x: isinstance(x, P))
        assert any("data" in (e if isinstance(e, tuple) else (e,))
                   for s in flat for e in tuple(s)), \
            "train mode lost its FSDP specs — serve test would be vacuous"

    def test_batch_shards_over_data(self):
        assert sharding.dp_axes(self.MESH) == ("data",)
        # the (B, S) token/mask batch (and the (B, D) output) shard over the
        # mesh's data axes and replicate the trailing dim
        dp = sharding.dp_axes(self.MESH)
        b = dp if len(dp) > 1 else dp[0]
        assert P(b, None) == P("data", None)


# ---------------------------------------------- single-device mesh (real) --
class TestShardedBackendSingleDevice:
    def test_degrades_to_bucketed_backend(self, bge_smoke):
        """bf16-resident weights == the bucketed path's cast-at-use weights
        (fp32->bf16 rounding commutes with the gather), so a single-device
        mesh serves bitwise-identical vectors to PR 2's backend."""
        cfg, params = bge_smoke
        buck = BucketedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                       min_seq_bucket=8)
        shard = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                       min_seq_bucket=8, dtype="bf16")
        assert shard.device_count == 1
        for lens in ([10, 40, 25], [5], [33, 7, 60, 12, 50]):
            a = np.stack(buck.embed_batch(queries(lens)))
            b = np.stack(shard.embed_batch(queries(lens)))
            np.testing.assert_allclose(a, b, atol=1e-5)

    def test_bf16_parity_with_fp32_oracle(self, bge_smoke):
        """Acceptance guard: bf16 serving stays within 1e-2 cosine of the
        fp32 oracle (fp32-resident weights + fp32 trunk); both emit fp32
        unit vectors because the pool_norm epilogue accumulates fp32."""
        cfg, params = bge_smoke
        oracle = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                        dtype="fp32")
        bf16 = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                      dtype="bf16")
        qs = queries([12, 30, 55, 20, 44, 9], payloads=True,
                     vocab=cfg.vocab_size)
        a = np.stack(oracle.embed_batch(qs))
        b = np.stack(bf16.embed_batch(qs))
        assert a.dtype == b.dtype == np.float32
        np.testing.assert_allclose(np.linalg.norm(b, axis=-1), 1.0,
                                   atol=1e-3)
        assert cosine_distance(a, b) <= 1e-2

    def test_donate_and_async_serve_identical_vectors(self, bge_smoke):
        cfg, params = bge_smoke
        base = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS)
        opt = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                     donate=True, async_dispatch=True)
        assert opt.async_dispatch and opt.donate
        qs = queries([18, 33, 7, 61])
        a = np.stack(base.embed_batch(qs))
        fetch = opt.embed_batch_async(qs)
        b = np.stack(fetch())
        np.testing.assert_allclose(a, b, atol=1e-5)

    def test_flags_pick_backend_defaults(self, bge_smoke):
        from repro import perf_flags

        cfg, params = bge_smoke
        try:
            perf_flags.set_flags(embed_dtype="bf16", embed_donate=True,
                                 embed_async=True)
            be = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS)
            assert be.serve_dtype == jnp.bfloat16
            assert be.donate and be.async_dispatch
        finally:
            perf_flags.reset_flags()
        base = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS)
        assert base.serve_dtype == jnp.float32
        assert not base.donate and not base.async_dispatch

    def test_staging_ring_bounded_and_reused_per_bucket(self, bge_smoke):
        cfg, params = bge_smoke
        be = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                    min_seq_bucket=8)
        for _ in range(be._staging_slots + 2):         # bucket (4, 16)
            be.embed_batch(queries([10, 12, 9, 15]))
        assert set(be._staging) == {(4, 16)}
        ring = be._staging[(4, 16)]
        assert len(ring) == be._staging_slots          # bounded...
        ids = [(id(t), id(m)) for t, m in ring]
        be.embed_batch(queries([16, 11, 13, 14]))      # same bucket
        assert [(id(t), id(m))
                for t, m in be._staging[(4, 16)]] == ids   # ...then reused
        be.embed_batch(queries([40, 50]))              # new bucket (2, 64)
        assert set(be._staging) == {(4, 16), (2, 64)}

    def test_prewarm_then_zero_serving_retraces(self, bge_smoke):
        cfg, params = bge_smoke
        be = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                    min_seq_bucket=8,
                                    dtype="bf16", donate=True,
                                    async_dispatch=True)
        grid = be.warm_grid(max_batch=4)
        n = be.prewarm(grid)
        assert n == len(grid) == be.traces
        for lens in ([5], [9, 9], [40, 33, 20], [7, 7, 7, 60]):
            be.embed_batch(queries(lens))
        assert be.traces == n, "sharded serving retraced despite prewarm"

    def test_truncation_counts_into_telemetry(self, bge_smoke):
        cfg, params = bge_smoke
        tel = Telemetry()
        be = ShardedEmbedderBackend(cfg, params, max_tokens=16,
                                    telemetry=tel)
        be.embed_batch([Query(qid=1, payload=np.arange(1, 40), length=39)])
        assert be.truncated == 1 and tel.truncated == 1

    def test_rejects_unknown_dtype(self, bge_smoke):
        cfg, params = bge_smoke
        with pytest.raises(ValueError, match="fp32|bf16"):
            ShardedEmbedderBackend(cfg, params, dtype="fp16")


# ----------------------------------------------- staging overrun guard --
class TestStagingOverrun:
    """The ROADMAP's 'fetch at most 2 batches late' discipline, enforced:
    more concurrent staged-but-unfetched batches than the ring has slots
    must raise a clear error (never serve rotated embeddings)."""

    def _batches(self, cfg, n, base=0):
        rng = np.random.default_rng(100 + base)
        return [[Query(qid=base * 100 + i * 10 + j,
                       payload=rng.integers(1, cfg.vocab_size, 10),
                       length=10) for j in range(4)] for i in range(n)]

    def test_three_workers_default_slots_raise_clearly_or_serve_correct(
            self, bge_smoke):
        import threading

        cfg, params = bge_smoke
        be = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS)
        oracle = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS)
        barrier = threading.Barrier(3, timeout=30)
        errors, served = [], []
        lock = threading.Lock()

        def worker(tid):
            b0, b1 = self._batches(cfg, 2, base=tid)
            f0 = be.embed_batch_async(b0)       # 3 staged, none fetched
            barrier.wait()
            err = f1 = None
            try:
                f1 = be.embed_batch_async(b1)   # 4th-6th staging: overrun
            except RuntimeError as e:
                err = e
            barrier.wait()  # every thread attempts round 2 BEFORE any fetch
            if err is not None:
                with lock:
                    errors.append(err)
                f0()                            # release what we hold
                return
            with lock:
                served.append((b0, f0()))
                served.append((b1, f1()))

        threads = [threading.Thread(target=worker, args=(t,))
                   for t in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        # default staging_slots (4) covers 2 double-buffered workers; three
        # must either trip the guard loudly or still serve correct vectors
        assert errors, "3 workers on default staging_slots went unguarded"
        for e in errors:
            assert "staging_slots" in str(e) and "overrun" in str(e)
        for batch, embs in served:              # survivors stay correct
            want = oracle.embed_batch(batch)
            np.testing.assert_allclose(np.stack(embs), np.stack(want),
                                       atol=1e-5)

    def _drive(self, be, cfg, n_workers, n_batches):
        import threading

        errors, served = [], []
        lock = threading.Lock()

        def worker(tid):
            pending = None
            try:
                for batch in self._batches(cfg, n_batches, base=tid):
                    fetch = be.embed_batch_async(batch)
                    if pending is not None:
                        pb, pf = pending
                        with lock:
                            served.append((pb, pf()))
                    pending = (batch, fetch)
                if pending is not None:
                    pb, pf = pending
                    with lock:
                        served.append((pb, pf()))
            except Exception as e:              # pragma: no cover - fail path
                with lock:
                    errors.append(e)

        threads = [threading.Thread(target=worker, args=(t,))
                   for t in range(n_workers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        return errors, served

    def test_two_workers_double_buffering_never_trips_the_guard(
            self, bge_smoke):
        cfg, params = bge_smoke
        be = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS)
        oracle = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS)
        errors, served = self._drive(be, cfg, n_workers=2, n_batches=6)
        assert not errors, errors
        assert len(served) == 12
        for batch, embs in served:
            np.testing.assert_allclose(
                np.stack(embs), np.stack(oracle.embed_batch(batch)),
                atol=1e-5)
        assert not be._staging_pending          # accounting drained

    def test_raised_staging_slots_covers_three_workers(self, bge_smoke):
        cfg, params = bge_smoke
        be = ShardedEmbedderBackend(cfg, params, max_tokens=MAX_TOKENS,
                                    staging_slots=6)     # 2 x 3 workers
        errors, served = self._drive(be, cfg, n_workers=3, n_batches=5)
        assert not errors, errors
        assert len(served) == 15
        assert not be._staging_pending


# ------------------------------------------------ engine double buffering --
class TestEngineAsyncWorker:
    def test_async_backend_serves_correct_futures(self, bge_smoke):
        """The double-buffered worker must hand every future ITS OWN batch's
        embedding (a lag bug would rotate results between batches)."""
        cfg, params = bge_smoke
        be = ShardedEmbedderBackend(cfg, params, max_tokens=32,
                                    dtype="bf16", async_dispatch=True)
        oracle = ShardedEmbedderBackend(cfg, params, max_tokens=32,
                                        dtype="bf16")
        rng = np.random.default_rng(11)
        payloads = [rng.integers(1, cfg.vocab_size, 20) for _ in range(12)]
        ve = WindVE(tiers=[TierSpec(NPU, 64, backend=be, max_batch=3,
                                    bucket_fn=length_bucket_fn(8, 32))])
        try:
            futs = [ve.submit(payload=p, length=len(p)) for p in payloads]
            got = [f.result(timeout=60) for f in futs]
        finally:
            ve.shutdown()
        want = oracle.embed_batch(
            [Query(qid=100 + i, payload=p, length=len(p))
             for i, p in enumerate(payloads)])
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, atol=1e-5)
        assert ve.stats.batch_latencies, "worker did not record batch tails"

    def test_sync_backend_records_batch_latency(self, bge_smoke):
        cfg, params = bge_smoke
        be = ShardedEmbedderBackend(cfg, params, max_tokens=32)
        ve = WindVE(tiers=[TierSpec(NPU, 16, backend=be)])
        try:
            fut = ve.submit(length=12)
            fut.result(timeout=60)
        finally:
            ve.shutdown()
        s = ve.stats.summary()
        assert len(ve.stats.batch_latencies) >= 1
        assert s["batch_p95_s"] >= s["batch_p50_s"] >= 0.0


# -------------------------------------------------- telemetry percentiles --
class TestBatchTailTelemetry:
    def test_summary_surfaces_batch_percentiles(self):
        t = Telemetry()
        for ms in (1, 2, 3, 4, 100):
            t.record_batch(NPU, ms / 1e3)
        s = t.summary()
        assert s["batch_p50_s"] == pytest.approx(3e-3)
        assert s["batch_p99_s"] > s["batch_p95_s"] > s["batch_p50_s"]
        assert t.batch_p(50) == s["batch_p50_s"]

    def test_empty_batch_percentiles_are_zero(self):
        s = Telemetry().summary()
        assert s["batch_p50_s"] == s["batch_p95_s"] == s["batch_p99_s"] == 0.0

    def test_des_records_batch_latencies(self):
        from repro.core.simulator import PAPER_DEVICES, ServingSimulator

        npu = PAPER_DEVICES["tesla-v100/bge"]
        res = ServingSimulator(npu, None, 16, 0, slo_s=2.0).run_burst(32)
        assert res.batch_latencies
        assert res.batch_p(95) >= res.batch_p(50) > 0.0


# ----------------------------------------------- real 8-device host mesh --
_SUBPROCESS_PROBE = r"""
import os
os.environ["JAX_PLATFORMS"] = "cpu"
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")
import numpy as np
import jax
from repro.configs import get_config
from repro.core.routing import Query
from repro.core.sharded_backend import ShardedEmbedderBackend
from repro.parallel.sharding import serve_embed_shardings

assert len(jax.devices()) == 8
cfg = get_config("bge-large-zh-v1.5").smoke()
from repro.models import embedder
params = embedder.init_embedder(jax.random.PRNGKey(0), cfg)

be = ShardedEmbedderBackend(cfg, params, max_tokens=32, dtype="bf16",
                            donate=True, async_dispatch=True,
                            min_seq_bucket=8)
assert be.device_count == 8
assert be.min_batch_bucket == 8      # batch buckets divide the mesh
# weights RESIDENT: every param leaf is fully replicated on all 8 devices
for leaf in jax.tree.leaves(be.params):
    assert len(leaf.sharding.device_set) == 8
    assert leaf.sharding.is_fully_replicated, leaf.sharding
# the batch shards over data: 8 distinct shards, one row-block each
_, bsh = serve_embed_shardings(be.mesh, jax.eval_shape(lambda: be.params))
tok = jax.device_put(np.zeros((16, 32), np.int32), bsh)
assert len({s.device for s in tok.addressable_shards}) == 8
assert tok.addressable_shards[0].data.shape == (2, 32)

qs = [Query(qid=i, length=ln) for i, ln in enumerate(
    [9, 30, 22, 15, 27, 12, 18, 31, 8, 25])]
out = np.stack(be.embed_batch(qs))
ref = ShardedEmbedderBackend(cfg, params, max_tokens=32, dtype="bf16",
                             devices=jax.devices()[:1], min_seq_bucket=8)
np.testing.assert_allclose(out, np.stack(ref.embed_batch(qs)), atol=1e-5)
print("SHARDED-8DEV-OK")

# int8 weight-only serving composes with the 8-device mesh + donation +
# async dispatch: int8 leaves resident/replicated, vectors match the
# 1-device int8 mesh exactly
import jax.numpy as jnp
q8 = ShardedEmbedderBackend(cfg, params, max_tokens=32, dtype="int8",
                            donate=True, async_dispatch=True,
                            min_seq_bucket=8)
leaves = jax.tree.leaves(q8.params)
assert any(l.dtype == jnp.int8 for l in leaves)
for leaf in leaves:
    assert len(leaf.sharding.device_set) == 8
fetch = q8.embed_batch_async(qs)
out8 = np.stack(fetch())
ref8 = ShardedEmbedderBackend(cfg, params, max_tokens=32, dtype="int8",
                              devices=jax.devices()[:1], min_seq_bucket=8)
np.testing.assert_allclose(out8, np.stack(ref8.embed_batch(qs)), atol=1e-5)
print("SHARDED-8DEV-INT8-OK")

# W8A8 (int8 weights AND dynamically quantized activations) composes with
# the full mesh stack too: same int8 resident tree, act_quant switched on,
# vectors match the 1-device W8A8 mesh exactly
qaa = ShardedEmbedderBackend(cfg, params, max_tokens=32, dtype="int8_w8a8",
                             donate=True, async_dispatch=True,
                             min_seq_bucket=8)
assert qaa.act_quant and not q8.act_quant
leaves = jax.tree.leaves(qaa.params)
assert any(l.dtype == jnp.int8 for l in leaves)
for leaf in leaves:
    assert len(leaf.sharding.device_set) == 8
fetch = qaa.embed_batch_async(qs)
outaa = np.stack(fetch())
refaa = ShardedEmbedderBackend(cfg, params, max_tokens=32,
                               dtype="int8_w8a8",
                               devices=jax.devices()[:1], min_seq_bucket=8)
np.testing.assert_allclose(outaa, np.stack(refaa.embed_batch(qs)),
                           atol=1e-5)
# activation quantization actually changed the computation vs weight-only
assert float(np.abs(outaa - out8).max()) > 0.0
print("SHARDED-8DEV-W8A8-OK")
"""


def test_eight_device_mesh_end_to_end(bge_smoke):
    """Real forced 8-device host mesh (subprocess: the suite's own backend
    must keep its single device, see conftest): resident replicated weights,
    data-sharded batches, embeddings identical to the 1-device mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    proc = subprocess.run([sys.executable, "-c", _SUBPROCESS_PROBE],
                          capture_output=True, text=True, timeout=600,
                          env=env)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "SHARDED-8DEV-OK" in proc.stdout
    assert "SHARDED-8DEV-INT8-OK" in proc.stdout
    assert "SHARDED-8DEV-W8A8-OK" in proc.stdout


def test_serve_devices_clamps_to_pow2():
    devs = list(range(6))           # stand-in objects are fine
    assert len(_serve_devices(devs)) == 4
    assert len(_serve_devices(list(range(8)))) == 8
    with pytest.raises(ValueError):
        _serve_devices([])
