"""End-to-end system behaviour: the paper's headline claims reproduced.

These are the EXPERIMENTS.md §Paper-repro acceptance tests — if they pass,
the benchmarks' numbers match the published tables within tolerance."""
import jax
import numpy as np
import pytest

from repro.core.cost_model import peak_saving, throughput_uplift
from repro.core.estimator import (estimate_depth, fine_tune_depth,
                                  stress_test_depth)
from repro.core.simulator import (PAPER_DEVICES, ServingSimulator,
                                  profile_fn_for)


def depths_for(npu_key: str, cpu_key: str, slo: float):
    pn = profile_fn_for(PAPER_DEVICES[npu_key])
    pc = profile_fn_for(PAPER_DEVICES[cpu_key])
    dn = fine_tune_depth(pn, slo, start=stress_test_depth(pn, slo) or 8,
                         radius=16)
    dc = fine_tune_depth(pc, slo, start=max(stress_test_depth(pc, slo), 4),
                         radius=16)
    return dn, dc


class TestTable1Bge:
    """Table 1: WindVE vs FlagEmbedding concurrency on bge."""

    def test_v100_xeon_1s(self):
        dn, dc = depths_for("tesla-v100/bge", "xeon-e5-2690/bge", 1.0)
        assert dn == 44 and dc == 8                      # 44 + 8
        assert throughput_uplift(dn, dc) == pytest.approx(0.182, abs=0.01)

    def test_v100_xeon_2s(self):
        dn, dc = depths_for("tesla-v100/bge", "xeon-e5-2690/bge", 2.0)
        assert dn == 96 and dc == 22                     # 96 + 22
        assert peak_saving(dn, dc) == pytest.approx(0.186, abs=0.01)

    def test_atlas_kunpeng_rows_close(self):
        # noisy devices: within a small tolerance of the published 84+1/172+8
        dn1, dc1 = depths_for("atlas-300i-duo/bge", "kunpeng-920/bge", 1.0)
        dn2, dc2 = depths_for("atlas-300i-duo/bge", "kunpeng-920/bge", 2.0)
        assert abs(dn1 - 84) <= 4 and dc1 <= 4
        assert abs(dn2 - 172) <= 6 and abs(dc2 - 8) <= 4
        # qualitative claim: smaller CPU-NPU gap -> larger uplift
        up_v100 = throughput_uplift(*depths_for(
            "tesla-v100/bge", "xeon-e5-2690/bge", 2.0))
        assert up_v100 > throughput_uplift(dn2, dc2)


class TestTable2Jina:
    def test_v100_xeon_2s(self):
        dn, dc = depths_for("tesla-v100/jina", "xeon-e5-2690/jina", 2.0)
        assert dn == 112 and dc == 30                    # 112 + 30 -> 26.7%
        assert throughput_uplift(dn, dc) == pytest.approx(0.268, abs=0.01)

    def test_faster_model_gives_bigger_uplift(self):
        """§5.2 phenomenon 3: jina (faster) uplifts more than bge."""
        for slo in (1.0, 2.0):
            ub = throughput_uplift(*depths_for(
                "tesla-v100/bge", "xeon-e5-2690/bge", slo))
            uj = throughput_uplift(*depths_for(
                "tesla-v100/jina", "xeon-e5-2690/jina", slo))
            assert uj > ub


class TestSloRelaxation:
    def test_looser_slo_bigger_improvement(self):
        """§5.2 phenomenon 1 (Ineq. 23): 2s uplift > 1s uplift, both combos."""
        for npu, cpu in [("tesla-v100/bge", "xeon-e5-2690/bge"),
                         ("atlas-300i-duo/bge", "kunpeng-920/bge")]:
            u1 = throughput_uplift(*depths_for(npu, cpu, 1.0))
            u2 = throughput_uplift(*depths_for(npu, cpu, 2.0))
            assert u2 >= u1


class TestDESEndToEnd:
    def test_windve_vs_baseline_under_burst(self):
        npu = PAPER_DEVICES["tesla-v100/bge"]
        cpu = PAPER_DEVICES["xeon-e5-2690/bge"]
        base = ServingSimulator(npu, None, 96, 0, slo_s=2.0).run_burst(130)
        wind = ServingSimulator(npu, cpu, 96, 22, slo_s=2.0).run_burst(130)
        assert wind.accepted > base.accepted
        assert wind.violations == 0 and base.violations == 0
        assert wind.rejected < base.rejected

    def test_diurnal_day_more_throughput_with_offload(self):
        from repro.core.simulator import diurnal_trace
        npu = PAPER_DEVICES["tesla-v100/bge"]
        cpu = PAPER_DEVICES["xeon-e5-2690/bge"]
        trace = diurnal_trace(120, base_rate=10, peak_rate=90, seed=5)
        base = ServingSimulator(npu, None, 96, 0, slo_s=2.0).run(list(trace))
        wind = ServingSimulator(npu, cpu, 96, 22, slo_s=2.0).run(list(trace))
        assert wind.accepted >= base.accepted
        assert wind.rejected <= base.rejected


class TestEstimatorSystem:
    def test_estimator_close_to_finetuned_everywhere(self):
        """Table 3 claim: regression predictions are comparable to (or better
        than) stress tests with step 8."""
        for key in ("tesla-v100/bge", "xeon-e5-2690/bge"):
            p = profile_fn_for(PAPER_DEVICES[key])
            for slo in (1.0, 2.0):
                est, _ = estimate_depth(p, slo)
                ft = fine_tune_depth(p, slo, start=max(est, 1), radius=16)
                stress = stress_test_depth(p, slo, step=8)
                assert abs(est - ft) <= max(8, 0.15 * ft)
                assert abs(est - ft) <= abs(stress - ft) + 8
