"""PartitionSpec rules for params, optimizer state, activations and caches.

Scheme (DESIGN.md §5):
* ``model`` axis — tensor/expert parallelism: d_ff-like dims, vocab of the
  embedding table, expert dim of MoE weights, d_inner of mamba.
* ``data`` axis — FSDP: the d_model-like dim of every weight is sharded over
  ``data`` and all-gathered per layer; the batch dim of activations also runs
  over ``data`` (plus ``pod`` when present).
* ``pod`` axis — data parallelism across pods (batch only; params replicated
  across pods — they already fit at 256-chip FSDPxTP).
* decode KV caches shard their *sequence* dim over ``model`` (flash-decode
  style partial-softmax via GSPMD reductions); ``long_500k`` (batch=1) shards
  sequence over ``('data','model')`` jointly.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, tree_map_with_path

from repro.configs.base import ModelConfig, ShapeConfig

STACK_KEYS = ("blocks", "enc_blocks", "dec_blocks")


def dp_axes(mesh) -> Tuple[str, ...]:
    """Axes the batch dim is sharded over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


# ---------------------------------------------------------------------------
# param rules
# ---------------------------------------------------------------------------

_RULES: Dict[str, Tuple] = {
    # name -> spec for the *unstacked* shape
    "embed": ("model", "data"),
    "lm_head": ("data", "model"),
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wo": ("model", "data"),
    "bq": ("model",),
    "bk": ("model",),
    "bv": ("model",),
    "router": ("data", None),
    "in_proj": ("data", "model"),
    "x_proj": ("model", None),
    "dt_proj": (None, "model"),
    "dt_bias": ("model",),
    "A_log": ("model", None),
    "D": ("model",),
    "conv_w": (None, "model"),
    "conv_b": ("model",),
    "scale": (None,),
    "bias": (None,),
}

_MOE_RULES: Dict[str, Tuple] = {
    # 3-D expert-stacked weights: experts over `model` (expert parallelism)
    "w_gate": ("model", "data", None),
    "w_up": ("model", "data", None),
    "w_down": ("model", None, "data"),
}

_MLP_RULES: Dict[str, Tuple] = {
    "w_gate": ("data", "model"),
    "w_up": ("data", "model"),
    "w_down": ("model", "data"),
    "w_in": ("data", "model"),
    "w_out": ("model", "data"),
}


# fallback when the expert count does not divide the model axis (e.g.
# granite's 40 experts on a 16-way axis): shard the FFN dims instead.
_MOE_FALLBACK: Dict[str, Tuple] = {
    "w_gate": (None, "data", "model"),
    "w_up": (None, "data", "model"),
    "w_down": (None, "model", "data"),
}


def _path_names(path) -> Tuple[str, ...]:
    return tuple(p.key for p in path if isinstance(p, DictKey))


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in axes:
        n *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    return n


def _fit(mesh, shape, rule) -> Tuple:
    """Drop spec entries whose mesh-axis size does not divide the dim.
    jit input shardings (unlike intermediates) require exact divisibility."""
    return tuple(
        (a if d % _axis_size(mesh, a) == 0 else None)
        for d, a in zip(shape, rule))


def param_spec(path, leaf, mesh, mode: str = "train") -> P:
    names = _path_names(path)
    name = names[-1]
    stacked = any(n in STACK_KEYS for n in names)
    eff_ndim = leaf.ndim - (1 if stacked else 0)
    moe = name in _MOE_RULES and eff_ndim == 3
    if moe:
        rule = _MOE_RULES[name]
    elif name in _MLP_RULES:
        rule = _MLP_RULES[name]
    elif name in _RULES:
        rule = _RULES[name]
    else:
        rule = (None,) * eff_ndim
    rule = tuple(rule)[:eff_ndim]
    rule = rule + (None,) * (eff_ndim - len(rule))
    if mode == "serve":
        # §Perf: serving keeps weights RESIDENT — tensor/expert parallelism
        # only.  FSDP's per-layer weight all-gathers amortize over large
        # training batches but dominate the decode collective term
        # (measured 17.9 GB/step = 359 ms on qwen2-72b decode_32k).
        rule = tuple(None if a == "data" else a for a in rule)
    if stacked:
        rule = (None,) + rule
    rule = _fit(mesh, leaf.shape, rule)
    if moe and rule[1 if stacked else 0] is None:
        # expert axis didn't divide: shard the FFN dims instead
        alt = _MOE_FALLBACK[name]
        if mode == "serve":
            alt = tuple(None if a == "data" else a for a in alt)
        alt = ((None,) + alt) if stacked else alt
        rule = _fit(mesh, leaf.shape, alt)
    return P(*rule)


def param_pspecs(mesh, params_shape, mode: str = "train") -> Any:
    """Pytree of PartitionSpec matching a param (or opt-state) pytree."""
    return tree_map_with_path(
        lambda p, l: param_spec(p, l, mesh, mode), params_shape)


def param_shardings(mesh, params_shape, mode: str = "train") -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(mesh, params_shape, mode))


def serve_embed_shardings(mesh, params_shape) -> Tuple[Any, NamedSharding]:
    """(param shardings, batch sharding) for the data-parallel embed path.

    Serve-mode param rules (weights RESIDENT: no ``data``-axis FSDP specs, so
    per-batch weight all-gathers never enter the service-time term the
    paper's Eq. 12 prices) + the (B, S) token/mask batch sharded over the
    data axes.  The same pair shards the (B, D) output, whose trailing dim
    is always replicated.
    """
    dp = dp_axes(mesh)
    b = dp if len(dp) > 1 else (dp[0] if dp else None)
    batch = NamedSharding(mesh, P(b, None))
    return param_shardings(mesh, params_shape, mode="serve"), batch


# ---------------------------------------------------------------------------
# activation / batch / cache rules
# ---------------------------------------------------------------------------

def batch_pspecs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> Dict[str, P]:
    """Specs for the input batch dict of a step (see steps.inputs)."""
    dp = dp_axes(mesh)
    dps = dp if len(dp) > 1 else (dp[0] if dp else None)
    big_batch = shape.global_batch >= _dp_size(mesh)
    b = dps if big_batch else None
    specs: Dict[str, P] = {}
    if shape.kind == "train":
        specs["tokens"] = P(b, None)
        specs["labels"] = P(b, None)
    elif shape.kind == "prefill":
        specs["tokens"] = P(b, None)
    else:  # decode
        specs["token"] = P(b)
    if shape.kind != "decode":
        if cfg.frontend == "vision":
            specs["patches"] = P(b, None, None)
        if cfg.frontend == "audio":
            specs["frames"] = P(b, None, None)
    return specs


def _dp_size(mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def cache_pspecs(cfg: ModelConfig, shape: ShapeConfig, mesh, cache_shape) -> Any:
    """Specs for the decode cache pytree (built via jax.eval_shape)."""
    dp = dp_axes(mesh)
    dps = dp if len(dp) > 1 else (dp[0] if dp else None)
    big_batch = shape.global_batch >= _dp_size(mesh)
    b = dps if big_batch else None
    # batch=1 long-context: shard the cache sequence over every axis we have
    seq_axes = ("model",) if big_batch else tuple(dp) + ("model",)
    seq = seq_axes if len(seq_axes) > 1 else seq_axes[0]

    def spec(path, leaf):
        name = _path_names(path)[-1]
        if name in ("k", "v"):            # (L, B, S, KV, hd)
            rule = (None, b, seq, None, None)
        elif name in ("cross_k", "cross_v"):  # (L, B, F, KV, hd)
            rule = (None, b, None, None, None)
        elif name == "kpos":              # (S,)
            rule = (seq,)
        elif name == "ssm":               # (L, B, DI, N)
            rule = (None, b, "model", None)
        elif name == "conv":              # (L, B, CK-1, DI)
            rule = (None, b, None, "model")
        else:
            return P()                    # pos scalar
        return P(*_fit(mesh, leaf.shape, rule))

    return tree_map_with_path(spec, cache_shape)


def hidden_constraint(mesh, batch_sharded: bool):
    """with_sharding_constraint for the residual stream inside layer scans.

    Keeps the hidden (B, S, D) sharded batch-over-dp, D replicated — GSPMD's
    natural layout between FSDP all-gathers."""
    dp = dp_axes(mesh)
    dps = dp if len(dp) > 1 else (dp[0] if dp else None)
    b = dps if batch_sharded else None
    sh = NamedSharding(mesh, P(b, None, None))

    def constrain(h):
        if h.ndim == 3:
            return jax.lax.with_sharding_constraint(h, sh)
        return h

    return constrain


def logits_pspec(mesh, batch_sharded: bool) -> P:
    dp = dp_axes(mesh)
    dps = dp if len(dp) > 1 else (dp[0] if dp else None)
    b = dps if batch_sharded else None
    return P(b, None, "model")
