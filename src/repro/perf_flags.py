"""Optimization toggles for the §Perf hillclimb.

The PAPER-FAITHFUL baseline is all-defaults; `launch/dryrun.py --opt k=v`
flips individual flags so every EXPERIMENTS.md §Perf row is reproducible as
baseline-vs-change.  Flags default OFF so tests exercise the baseline unless
they opt in.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass
class PerfFlags:
    # mamba selective scan: 0 = per-timestep lax.scan (baseline);
    # N = outer scan over S/N chunks with an N-step unrolled inner body so
    # XLA fuses the chunk and the ssm state stops round-tripping HBM per step
    # (the pure-XLA analogue of the Pallas ssm_scan kernel).
    mamba_chunk: int = 0
    # flash attention: skip kv chunks that are fully masked (outside the
    # causal/sliding-window band) instead of masking them — fewer chunk
    # iterations, less score traffic, fewer flops.
    attn_band_skip: bool = False
    # attn_forward backend: "jnp" (baseline: chunked pure-JAX flash), "auto"
    # (the Pallas kernel when running on TPU, pure-JAX elsewhere), "pallas" /
    # "interpret" (force the kernel, compiled / interpreter).  The kernel
    # route assumes contiguous [0, S) positions (what train / prefill /
    # encoder / embedder all pass) and prefix-style kv masks.
    attn_kernel: str = "jnp"
    # decode: pick label/argmax paths that avoid gathers over the
    # vocab-sharded logits (one-hot dot instead of take_along_axis).
    ce_onehot: bool = False
    # train: all-reduce gradients in bf16 instead of fp32 (halves the
    # gradient-sync collective bytes; optimizer math stays fp32).
    grad_bf16: bool = False
    # decode: carry the stacked KV cache through a fori_loop with per-layer
    # in-place dynamic-update-slice instead of scan-ys stacking.  The scan
    # path makes XLA rewrite the FULL cache with a bf16->f32->bf16 roundtrip
    # every layer iteration (measured 870 GB/step on qwen2-72b decode_32k).
    decode_fori: bool = False
    # decode: flash-decode attention via shard_map — the seq-sharded cache
    # is attended locally per shard (partial softmax, pmax/psum combine) and
    # only the owner shard writes the new token.  Avoids GSPMD's
    # full-cache select/copy lowering of DUS on a sharded dim entirely.
    decode_shard_map: bool = False
    # MoE: dispatch tokens to expert buckets PER BATCH ROW (indices local to
    # each data shard) instead of one global scatter — the global scatter
    # from token-sharded to expert-sharded layouts makes GSPMD all-gather
    # every token to every device.
    moe_row_dispatch: bool = False
    # serving: shard weights tensor/expert-parallel ONLY (resident weights,
    # no FSDP all-gathers).  FSDP amortizes over training batches; at decode
    # it all-gathers every layer's weights per token step.
    serve_tp_only: bool = False
    # dry-run artifact control: the CPU backend legalizes bf16 arithmetic to
    # f32, wrapping the cache DUS in FULL-BUFFER converts that would not
    # exist on the TPU target.  f32 caches sidestep the legalization so the
    # dry-run traffic matches what TPU bf16 caches would do (modulo 2x raw
    # cache bytes, which we report).
    cache_f32: bool = False
    # train remat policy: "full" (baseline: save only layer inputs) or
    # "dots" (save no-batch-dim dot outputs, i.e. the weight-matmul
    # activations; recompute only the cheap elementwise/attention math).
    remat_policy: str = "full"
    # embedding serving precision: "fp32" (baseline oracle: fp32-resident
    # weights, fp32 trunk), "bf16" (weights cast ONCE at load, all matmuls
    # bf16), "int8" (weight-only per-output-channel symmetric int8
    # quantization of every dense/attention projection at load, fp32 scales,
    # fp32 activations, the fused quant-matmul kernel in the trunk — 4x
    # smaller resident weights), or "int8_w8a8" (the int8 tree plus dynamic
    # per-row symmetric int8 activation quantization: every projection
    # contracts int8 x int8 with int32 accumulation, dequantized once in the
    # kernel epilogue — the MXU int8-rate path).  The pool_norm epilogue
    # always accumulates fp32 so served vectors stay fp32 unit vectors
    # within 1e-2 cosine (>= 0.99) of the oracle for the weight-only
    # policies and 2e-2 (>= 0.98) for int8_w8a8.
    embed_dtype: str = "fp32"
    # embedding serving: donate the token/mask device buffers to the jit'd
    # embed (jit donate_argnums) so XLA reuses them instead of allocating
    # fresh HBM per batch.  No-op (with the warning suppressed) on backends
    # that cannot alias, e.g. this CPU container.
    embed_donate: bool = False
    # embedding serving: enqueue the embed and return a fetch handle so the
    # engine worker overlaps batch N's compute with batch N-1's
    # device->host fetch (double buffering) instead of blocking per batch.
    embed_async: bool = False
    # serving: N > 0 puts an exact-match embedding cache of N entries at
    # the head of the dispatch topology (token-hash keyed LRU, zero-latency
    # TierSpec — repro.core.cache).  Hits serve the stored embedding
    # bitwise at ~zero latency / zero FLOPs; misses fall through to the
    # policy cascade and are admitted on batch completion.  0 = no cache
    # (baseline).
    cache: int = 0
    # serving: optional byte budget for the cache tier (summed embedding
    # nbytes) on top of the entry count; 0 = entries-only bound.
    cache_bytes: int = 0
    # serving fault tolerance: N > 0 arms every submitted query with a
    # relative deadline of N milliseconds — queries still QUEUED past it
    # are swept out (their futures fail with DeadlineExceeded, counted as
    # deadline_misses) instead of serving uselessly late.  0 = no deadline
    # (baseline).
    deadline_ms: int = 0
    # serving fault tolerance: re-dispatch each query of a failed batch up
    # to N times through the normal policy path (survivors fail over to
    # whatever healthy tier the policy ranks first); exhausted attempts
    # fail the future with a structured ServeError.  0 = one attempt,
    # failures terminal (baseline).
    retries: int = 0
    # serving fault tolerance: base exponential backoff (milliseconds)
    # before retry attempt k: backoff * 2^(k-1), slept by the FAILED
    # tier's worker (healthy tiers keep draining).  0 = immediate retry.
    retry_backoff_ms: int = 0
    # serving fault tolerance: trip a tier's circuit breaker after N
    # consecutive batch failures — dispatch routes around the open tier
    # until a half-open probe succeeds.  0 = no breakers (baseline).
    breaker: int = 0
    # serving fault tolerance: how long (milliseconds) a tripped breaker
    # stays open before the half-open recovery probe.  Only meaningful
    # with breaker > 0.
    breaker_cooldown_ms: int = 1000
    # serving overload control: SLO-aware admission at dispatch — arrivals
    # a calibrated fit predicts past their budget, or over every tier's
    # backpressure watermark, are rejected with ServeError(kind="admission")
    # instead of queueing into a guaranteed deadline miss (off = baseline:
    # queue until BUSY).
    admission: bool = False
    # serving overload control: the admission price of turning a query
    # away, against an expected SLO-violation cost of 1.0 — reject when
    # rejecting is cheaper (reject_cost < 1.0); >= 1.0 disables pricing
    # rejections outside brownout shedding, leaving watermarks only.
    reject_cost: float = 0.5
    # serving overload control: fraction of each tier's depth open to NEW
    # arrivals (1.0 = full depth); the band above the watermark stays
    # reserved for retry/failover re-dispatch.  Halved under brownout
    # shedding.
    watermark: float = 1.0
    # serving overload control: three-stage brownout (normal -> degraded ->
    # shedding) on a dispatch-time utilization EWMA — degraded prefers the
    # quantized tier at equal backlog and tightens effective deadlines,
    # shedding also tightens the admission watermark.  Off = baseline.
    brownout: bool = False


FLAGS = PerfFlags()


def set_flags(**kw) -> PerfFlags:
    global FLAGS
    FLAGS = dataclasses.replace(FLAGS, **kw)
    return FLAGS


def reset_flags() -> None:
    global FLAGS
    FLAGS = PerfFlags()


def parse_opt(spec: str) -> dict:
    """'mamba_chunk=16,attn_band_skip=1' -> kwargs dict."""
    out = {}
    for part in filter(None, spec.split(",")):
        k, _, v = part.partition("=")
        k = k.strip()
        if k not in PerfFlags.__dataclass_fields__:
            valid = ", ".join(sorted(PerfFlags.__dataclass_fields__))
            raise ValueError(f"unknown perf flag {k!r}; valid flags: {valid}")
        field = PerfFlags.__dataclass_fields__[k]
        if field.type in ("int", int):
            out[k] = int(v)
        elif field.type in ("float", float):
            out[k] = float(v)
        elif field.type in ("str", str):
            out[k] = v.strip()
        else:
            out[k] = v.strip() in ("1", "true", "True", "yes", "on")
        if k == "embed_dtype":
            # validate the VALUE here too: a typo'd policy must fail at the
            # CLI, not at first backend construction minutes into a run
            from repro.models.quantize import EMBED_DTYPES
            if out[k] not in EMBED_DTYPES:
                raise ValueError(
                    f"unknown embed_dtype {out[k]!r}; valid values: "
                    f"{'|'.join(EMBED_DTYPES)}")
    return out
