from repro.steps import inputs, optim, serve, train

__all__ = ["inputs", "optim", "serve", "train"]
