"""Train-step builder: remat'd forward + chunked cross-entropy + AdamW.

The CE is computed in sequence chunks (logits per chunk, recomputed in the
backward via jax.checkpoint) so (B, S, V) is never materialised — with
V=152k vocabs that matters more than anything else in the step.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, lm
from repro.parallel import sharding
from repro.steps import optim
from repro.steps.inputs import input_specs


def _chunk_size(S: int, target: int = 512) -> int:
    for c in range(min(target, S), 0, -1):
        if S % c == 0:
            return c
    return S


def chunked_ce(h: jax.Array, head: jax.Array, labels: jax.Array,
               constrain_logits=lambda x: x, target_chunk: int = 512) -> jax.Array:
    """Mean next-token CE from final hidden states, chunked over sequence."""
    B, S, D = h.shape
    c = _chunk_size(S, target_chunk)
    nc = S // c
    hr = h.reshape(B, nc, c, D)
    lr = labels.reshape(B, nc, c)

    @jax.checkpoint
    def body(tot, idx):
        hc = jnp.moveaxis(hr, 1, 0)[idx]
        lc = jnp.moveaxis(lr, 1, 0)[idx]
        logits = (hc @ head.astype(hc.dtype)).astype(jnp.float32)
        logits = constrain_logits(logits)
        lp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(lp, lc[..., None], axis=-1)[..., 0]
        return tot - ll.sum(), None

    tot, _ = lax.scan(body, jnp.zeros((), jnp.float32), jnp.arange(nc))
    return tot / (B * S)


def build_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh,
                     opt_cfg: optim.AdamWConfig = optim.AdamWConfig(),
                     aux_weight: float = 0.01):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""
    big = shape.global_batch >= sharding._dp_size(mesh)
    constrain = sharding.hidden_constraint(mesh, big)
    lspec = sharding.logits_pspec(mesh, big)
    lsh = NamedSharding(mesh, lspec)
    constrain_logits = lambda x: lax.with_sharding_constraint(x, lsh)

    def loss_fn(params, batch):
        if cfg.cross_attention:
            h, aux = encdec.forward(params, cfg, batch["tokens"],
                                    batch["frames"], remat=True,
                                    return_hidden=True, constrain=constrain)
            head = params["lm_head"]
        else:
            h, aux = lm.forward(params, cfg, batch["tokens"],
                                extra_embed=batch.get("patches"), remat=True,
                                return_hidden=True, constrain=constrain)
            head = lm.head_weights(params, cfg)
            if cfg.frontend == "vision":
                h = h[:, cfg.num_patches:]   # loss only over text positions
        ce = chunked_ce(h, head, batch["labels"], constrain_logits)
        return ce + aux_weight * aux, (ce, aux)

    def train_step(params, opt_state, batch):
        (loss, (ce, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        new_params, new_opt, om = optim.update(grads, opt_state, params, opt_cfg)
        metrics = {"loss": loss, "ce": ce, "moe_aux": aux, **om}
        return new_params, new_opt, metrics

    return train_step


def train_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh, params_shape):
    """(in_shardings, out_shardings) for jax.jit(train_step)."""
    psh = sharding.param_shardings(mesh, params_shape)
    osh = {"m": psh, "v": psh, "step": NamedSharding(mesh, P())}
    bsp = sharding.batch_pspecs(cfg, shape, mesh)
    bsh = {k: NamedSharding(mesh, v) for k, v in bsp.items()}
    scalar = NamedSharding(mesh, P())
    metrics_sh = {k: scalar for k in ("loss", "ce", "moe_aux", "grad_norm")}
    return (psh, osh, bsh), (psh, osh, metrics_sh)
