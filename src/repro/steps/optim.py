"""Hand-rolled AdamW (no external optimizer dependency).

State layout mirrors the param pytree so the sharding rules apply verbatim:
``{"m": tree, "v": tree, "step": scalar}``.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def update(grads, state, params, cfg: AdamWConfig = AdamWConfig()
           ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    m = jax.tree.map(lambda m_, g: cfg.b1 * m_ + (1 - cfg.b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: cfg.b2 * v_ + (1 - cfg.b2) * jnp.square(g),
                     state["v"], grads)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, {"grad_norm": gnorm}
