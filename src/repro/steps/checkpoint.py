"""Checkpointing: param/opt pytrees <-> .npz files (no external deps).

Keys encode the tree path (``blocks/attn/wq``); restore rebuilds into the
reference structure (from init or eval_shape) and validates shapes/dtypes.
Training state (data-stream step included) round-trips exactly.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict

import jax
import numpy as np
from jax.tree_util import DictKey, SequenceKey, tree_flatten_with_path


def _path_key(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, DictKey):
            parts.append(str(p.key))
        elif isinstance(p, SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(path: str, tree: Any, metadata: Dict[str, Any] | None = None) -> None:
    flat, _ = tree_flatten_with_path(tree)
    arrays = {}
    for p, leaf in flat:
        arrays[_path_key(p)] = np.asarray(leaf)
    arrays["__metadata__"] = np.frombuffer(
        json.dumps(metadata or {}).encode(), dtype=np.uint8)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    # atomic write: tmp + rename
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, path)
    except BaseException:
        os.unlink(tmp)
        raise


def load(path: str, like: Any) -> tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``like`` (init output or eval_shape)."""
    with np.load(path) as data:
        meta = json.loads(bytes(data["__metadata__"].tobytes()).decode())
        flat, treedef = tree_flatten_with_path(like)
        leaves = []
        for p, ref in flat:
            key = _path_key(p)
            if key not in data:
                raise KeyError(f"checkpoint missing {key!r}")
            arr = data[key]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"{key}: shape {arr.shape} != expected {ref.shape}")
            leaves.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves]), meta
