"""ShapeDtypeStruct stand-ins for every model input — the dry-run contract.

``input_specs(cfg, shape)`` returns the batch dict a step consumes, with no
device allocation.  For the stubbed modality frontends (per spec), the specs
ARE the stub: precomputed patch/frame embeddings of the right shape.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, lm


def text_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """VLM shapes budget `seq_len` across patches + text."""
    if cfg.frontend == "vision" and shape.kind != "decode":
        return shape.seq_len - cfg.num_patches
    return shape.seq_len


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                cache_dtype=jnp.bfloat16) -> Dict[str, Any]:
    B = shape.global_batch
    S = text_len(cfg, shape)
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch: Dict[str, Any] = {
            "tokens": sds((B, S), i32),
            "labels": sds((B, S), i32),
        }
    elif shape.kind == "prefill":
        batch = {"tokens": sds((B, S), i32)}
    else:  # decode: ONE new token against a seq_len-deep cache
        batch = {"token": sds((B,), i32)}
    if cfg.frontend == "vision" and shape.kind != "decode":
        batch["patches"] = sds((B, cfg.num_patches, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "audio" and shape.kind != "decode":
        batch["frames"] = sds((B, cfg.num_frames, cfg.d_model), jnp.bfloat16)
    return batch


def cache_specs(cfg: ModelConfig, shape: ShapeConfig,
                cache_dtype=jnp.bfloat16) -> Any:
    """Decode-cache ShapeDtypeStruct pytree via eval_shape (no allocation)."""
    assert shape.kind == "decode"
    init = encdec.init_cache if cfg.cross_attention else lm.init_cache
    return jax.eval_shape(
        lambda: init(cfg, shape.global_batch, shape.seq_len, dtype=cache_dtype))


def make_batch(cfg: ModelConfig, shape: ShapeConfig, key) -> Dict[str, Any]:
    """Materialise a random batch matching input_specs (smoke tests/examples)."""
    specs = input_specs(cfg, shape)
    out = {}
    for name, s in specs.items():
        k, key = jax.random.split(key) if hasattr(key, "shape") else (key, key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab_size, s.dtype)
        else:
            out[name] = jax.random.normal(k, s.shape, jnp.float32).astype(s.dtype)
    return out
