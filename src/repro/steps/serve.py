"""Serving-step builders: prefill (prompt -> cache) and decode (one token).

`decode` is what the decode_32k / long_500k dry-run shapes lower: ONE new
token against a seq_len-deep KV cache (ring-buffer for sliding-window archs,
recurrent state for SSM/hybrid).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, lm
from repro.parallel import sharding


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh,
                       cache_dtype=jnp.bfloat16, max_len: int | None = None):
    big = shape.global_batch >= sharding._dp_size(mesh)
    constrain = sharding.hidden_constraint(mesh, big)

    def prefill_step(params, batch):
        if cfg.cross_attention:
            return encdec.prefill(params, cfg, batch["tokens"], batch["frames"],
                                  cache_dtype=cache_dtype, max_len=max_len,
                                  constrain=constrain)
        return lm.prefill(params, cfg, batch["tokens"],
                          extra_embed=batch.get("patches"),
                          cache_dtype=cache_dtype, max_len=max_len,
                          constrain=constrain)

    return prefill_step


def build_decode_step(cfg: ModelConfig, shape: ShapeConfig, mesh,
                      greedy: bool = True):
    from repro.perf_flags import FLAGS

    big = shape.global_batch >= sharding._dp_size(mesh)
    constrain = sharding.hidden_constraint(mesh, big)
    shard_ctx = None
    if FLAGS.decode_shard_map and not cfg.cross_attention and cfg.has_attention:
        dp = sharding.dp_axes(mesh)
        dps = dp if len(dp) > 1 else (dp[0] if dp else None)
        b = dps if big else None
        seq_axes = ("model",) if big else tuple(dp) + ("model",)
        shard_ctx = (mesh, b, seq_axes)

    def serve_step(params, cache, batch):
        if cfg.cross_attention:
            logits, cache = encdec.decode_step(params, cfg, batch["token"],
                                               cache, constrain=constrain)
        else:
            logits, cache = lm.decode_step(params, cfg, batch["token"], cache,
                                           constrain=constrain,
                                           shard_ctx=shard_ctx)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_step


def serve_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh,
                    params_shape, cache_shape=None):
    from repro.perf_flags import FLAGS

    mode = "serve" if FLAGS.serve_tp_only else "train"
    psh = sharding.param_shardings(mesh, params_shape, mode)
    bsp = sharding.batch_pspecs(cfg, shape, mesh)
    bsh = {k: NamedSharding(mesh, v) for k, v in bsp.items()}
    if cache_shape is None:
        return psh, bsh
    csp = sharding.cache_pspecs(cfg, shape, mesh, cache_shape)
    csh = jax.tree.map(lambda s: NamedSharding(mesh, s), csp)
    return psh, csh, bsh
