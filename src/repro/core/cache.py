"""Exact-match embedding cache — the zero-cost tier at the head of the topology.

Real query streams are heavily skewed (EdgeRAG builds its whole system
around online embedding caches; the RAG systems-trade-offs literature shows
retrieval recomputing the same hot queries over and over).  A cache hit is
a query served at ~zero latency and zero FLOPs, which raises effective
concurrency past anything a faster backend can buy: with hit fraction p,
only (1 - p) of the arrival stream ever reaches a device, so the paper's
deployment-cost lever (concurrency capacity, Eqs. 5-6) scales by 1/(1-p)
(see ``repro.core.cost_model.cache_uplift`` and
``repro.core.estimator.cached_fit`` for the Eq. 12 side).

The cache is surfaced as a first-class :class:`~repro.core.routing.TierSpec`
with ``cache=`` set (see :func:`cache_tier`), placed at the head of the
topology list.  ``QueueManager.dispatch`` consults cache tiers BEFORE policy
dispatch: a hit fills ``Query.emb`` and returns the cache tier's name — the
threaded engine then resolves the future immediately and the DES completes
the query at +0 service time.  Misses fall through to the normal policy
cascade, and the drivers admit each computed embedding back through
``QueueManager.admit`` on batch completion (insert happens BEFORE the future
resolves, so a caller that has seen a result can rely on the key being
cached).

Keys are token-content hashes (:func:`cache_key`): two queries embed
identically iff their token payloads are identical, so exact-match hits are
bitwise-faithful by construction.  Payload-less queries hash to their
length — ``JaxEmbedderBackend._tokenize`` derives the same deterministic
synthetic stream for every payload-less query of one length, so this is the
exact-match key for them too (and what makes the DES, whose queries carry
no tokens, cache deterministically).

Thread-safe (one lock around the LRU) for the engine; fully deterministic
(ordered dict, no wall-clock reads — callers pass ``now``) for the DES.
"""
from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Hashable, Optional, Tuple

import numpy as np

from repro.core.routing import TierSpec

CACHE = "CACHE"


def cache_key(query) -> Hashable:
    """Exact-match key for a query: a digest of its token payload.

    * payload arrays/lists hash by canonical int64 token bytes (two payloads
      collide iff their token sequences are identical — dtype/container
      differences do not split the key);
    * payload-less queries key on their length alone, matching the
      deterministic synthetic stream ``_tokenize`` expands them into.
    """
    p = getattr(query, "payload", None)
    if p is None:
        return ("synthetic", int(getattr(query, "length", 0)))
    toks = np.asarray(p, dtype=np.int64).ravel()
    return ("tokens", toks.size,
            hashlib.blake2b(toks.tobytes(), digest_size=16).digest())


@dataclass
class CacheEntry:
    value: Any          # the served embedding (engine) or None (DES)
    nbytes: int
    t: float            # insert time (driver clock: monotonic or sim time)


def _value_nbytes(value: Any) -> int:
    if value is None:
        return 0
    nb = getattr(value, "nbytes", None)
    return int(nb) if nb is not None else 0


class EmbeddingCache:
    """Token-hash-keyed LRU over served embeddings.

    ``capacity`` bounds entries; ``capacity_bytes`` (optional) additionally
    bounds the summed ``value.nbytes``.  Values are stored as read-only
    copies so a caller mutating a served array cannot corrupt later hits —
    the bitwise-identical-serving contract holds for the cache's lifetime.

    ``get``/``put`` take ``now`` explicitly instead of reading a clock, so
    the DES drives the cache on simulated time and two seeded runs replay
    identical hit/miss/evict sequences.
    """

    def __init__(self, capacity: int = 1024,
                 capacity_bytes: Optional[int] = None):
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got {capacity}")
        if capacity_bytes is not None and capacity_bytes < 1:
            raise ValueError("capacity_bytes must be >= 1 when set")
        self.capacity = int(capacity)
        self.capacity_bytes = capacity_bytes
        self._lru: "OrderedDict[Hashable, CacheEntry]" = OrderedDict()
        self._nbytes = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.inserts = 0
        self.evictions = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._lru)

    @property
    def nbytes(self) -> int:
        with self._lock:
            return self._nbytes

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def get(self, query, now: float = 0.0) -> Optional[CacheEntry]:
        """Exact-match lookup; a hit refreshes recency.  Returns the live
        entry (value + insert time, so the caller can derive staleness)."""
        k = cache_key(query)
        with self._lock:
            entry = self._lru.get(k)
            if entry is None:
                self.misses += 1
                return None
            self._lru.move_to_end(k)
            self.hits += 1
            return entry

    def put(self, query, value: Any, now: float = 0.0) -> int:
        """Admit one computed embedding; returns how many entries were
        evicted to make room (0 for a plain insert/refresh).  A value that
        alone exceeds ``capacity_bytes`` is not admitted (it would evict
        the whole cache and then itself)."""
        if isinstance(value, np.ndarray):
            value = value.copy()
            value.setflags(write=False)
        nb = _value_nbytes(value)
        if self.capacity_bytes is not None and nb > self.capacity_bytes:
            return 0
        k = cache_key(query)
        with self._lock:
            old = self._lru.pop(k, None)
            if old is not None:
                self._nbytes -= old.nbytes
            self._lru[k] = CacheEntry(value, nb, float(now))
            self._nbytes += nb
            self.inserts += 1
            evicted = 0
            while len(self._lru) > self.capacity or (
                    self.capacity_bytes is not None
                    and self._nbytes > self.capacity_bytes):
                _, victim = self._lru.popitem(last=False)
                self._nbytes -= victim.nbytes
                evicted += 1
            self.evictions += evicted
            return evicted

    def clear(self) -> None:
        """Drop every entry AND the counters — one DES run's cache state."""
        with self._lock:
            self._lru.clear()
            self._nbytes = 0
            self.hits = self.misses = self.inserts = self.evictions = 0


def cache_tier(entries: int, capacity_bytes: Optional[int] = None,
               name: str = CACHE) -> TierSpec:
    """A zero-latency cache TierSpec for the head of a topology list.

    ``depth=0``: the cache holds no queue and no in-flight work — a hit
    completes at dispatch, so it contributes no backlog for policies to
    price and no C^max to ``max_concurrency`` (its capacity contribution is
    the hit-rate uplift, see ``cost_model.cache_uplift``).  Both drivers
    accept the spec as-is: the engine needs no backend and the DES no
    latency model for it.
    """
    return TierSpec(name, 0,
                    cache=EmbeddingCache(entries, capacity_bytes))
