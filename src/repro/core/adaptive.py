"""Online queue-depth re-calibration — beyond-paper extension of §4.2.2.

The paper fits Eq. 12 once, offline, from dedicated profiling runs.  In
production the (alpha, beta) drift (thermal throttling, co-located load,
query-length mix — their §5.4 shows both knobs move the curve), so WindVE
here keeps a rolling window of REAL (batch_size, service_latency)
observations per device and periodically refits the line, shrinking or
growing the queue depths while the SLO contract holds.

The estimator stays the paper's exact linear model; only the data source
changes (live traffic instead of offline probes).
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, Optional, Tuple

from repro.core.estimator import LatencyFit, fit_latency


@dataclass
class Observation:
    concurrency: int
    latency_s: float


class OnlineCalibrator:
    """Rolling-window Eq. 12 refit per device."""

    def __init__(self, slo_s: float, window: int = 256,
                 min_points: int = 8, headroom: float = 0.95):
        self.slo = slo_s
        self.window = window
        self.min_points = min_points
        self.headroom = headroom          # aim below the SLO by this factor
        self._obs: Dict[str, Deque[Observation]] = {}
        self._lock = threading.Lock()

    def observe(self, device: str, concurrency: int, latency_s: float) -> None:
        with self._lock:
            q = self._obs.setdefault(device, deque(maxlen=self.window))
            q.append(Observation(concurrency, latency_s))

    def n_observations(self, device: str) -> int:
        with self._lock:
            return len(self._obs.get(device, ()))

    def fit(self, device: str) -> Optional[LatencyFit]:
        with self._lock:
            obs = list(self._obs.get(device, ()))
        # need at least two distinct concurrency levels for a line
        if len(obs) < self.min_points or \
                len({o.concurrency for o in obs}) < 2:
            return None
        return fit_latency([o.concurrency for o in obs],
                           [o.latency_s for o in obs])

    def suggest_depth(self, device: str,
                      current: int) -> Tuple[int, Optional[LatencyFit]]:
        """New depth for ``device`` (falls back to ``current`` if the window
        is not informative yet)."""
        f = self.fit(device)
        if f is None:
            return current, None
        return max(f.max_concurrency(self.slo * self.headroom), 0), f


def attach(engine, calibrator: OnlineCalibrator, refit_every: int = 64):
    """Wire a calibrator into a running WindVE engine: every completed batch
    feeds an observation; every ``refit_every`` completions the depths are
    re-estimated and applied atomically.

    Uses the engine's first-class batch-completion hook (the seed
    monkey-patched every backend's ``embed_batch``, which broke per-worker
    model ownership and was invisible to other instrumentation).  Returns
    the hook so callers can ``engine.remove_batch_hook(hook)`` to detach.
    """
    done = {"n": 0}

    def on_batch(tier: str, batch, service_latency_s: float) -> None:
        calibrator.observe(tier, len(batch), service_latency_s)
        done["n"] += len(batch)
        if done["n"] >= refit_every:
            done["n"] = 0
            for dev, q in engine.qm.queues.items():
                new, _ = calibrator.suggest_depth(dev, q.depth)
                if new > 0 and new != q.depth:
                    engine.qm.set_depth(dev, new)

    return engine.add_batch_hook(on_batch)
