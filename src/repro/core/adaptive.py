"""Online queue-depth re-calibration — beyond-paper extension of §4.2.2.

The paper fits Eq. 12 once, offline, from dedicated profiling runs.  In
production the (alpha, beta) drift (thermal throttling, co-located load,
query-length mix — their §5.4 shows both knobs move the curve), so WindVE
here keeps a rolling window of REAL (batch_size, service_latency)
observations per device and periodically refits the line, shrinking or
growing the queue depths while the SLO contract holds.

The estimator stays the paper's exact linear model; only the data source
changes (live traffic instead of offline probes).  Observations can also be
kept per seq-length *bucket* (``observe(..., bucket=...)``), yielding one
fit per (device, bucket) — the granularity ``PredictivePolicy`` prices
candidate tiers at, and ``attach(..., policy=...)`` streams refreshed fits
into a live policy through the engine's batch-completion hook.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from repro.core.estimator import LatencyFit, fit_latency


@dataclass
class Observation:
    concurrency: int
    latency_s: float


class OnlineCalibrator:
    """Rolling-window Eq. 12 refit per device (and per length bucket)."""

    def __init__(self, slo_s: float, window: int = 256,
                 min_points: int = 8, headroom: float = 0.95):
        self.slo = slo_s
        self.window = window
        self.min_points = min_points
        self.headroom = headroom          # aim below the SLO by this factor
        # keys: device name (tier-level window) or (device, bucket)
        self._obs: Dict[Any, Deque[Observation]] = {}
        self._lock = threading.Lock()

    def observe(self, device: str, concurrency: int, latency_s: float,
                bucket: Any = None) -> None:
        with self._lock:
            q = self._obs.setdefault(device, deque(maxlen=self.window))
            q.append(Observation(concurrency, latency_s))
            if bucket is not None:
                qb = self._obs.setdefault((device, bucket),
                                          deque(maxlen=self.window))
                qb.append(Observation(concurrency, latency_s))

    def n_observations(self, device: str, bucket: Any = None) -> int:
        key = device if bucket is None else (device, bucket)
        with self._lock:
            return len(self._obs.get(key, ()))

    def buckets_for(self, device: str) -> List[Any]:
        """Buckets this device has per-bucket observations for."""
        with self._lock:
            return [k[1] for k in self._obs
                    if isinstance(k, tuple) and k[0] == device]

    def fit(self, device: str, bucket: Any = None) -> Optional[LatencyFit]:
        key = device if bucket is None else (device, bucket)
        with self._lock:
            obs = list(self._obs.get(key, ()))
        # need at least two distinct concurrency levels for a line
        if len(obs) < self.min_points or \
                len({o.concurrency for o in obs}) < 2:
            return None
        return fit_latency([o.concurrency for o in obs],
                           [o.latency_s for o in obs])

    def suggest_depth(self, device: str,
                      current: int) -> Tuple[int, Optional[LatencyFit]]:
        """New depth for ``device`` (falls back to ``current`` if the window
        is not informative yet)."""
        f = self.fit(device)
        if f is None:
            return current, None
        return max(f.max_concurrency(self.slo * self.headroom), 0), f


def attach(engine, calibrator: OnlineCalibrator, refit_every: int = 64,
           policy: Any = None,
           bucket_fn: Optional[Callable[[Any], Any]] = None):
    """Wire a calibrator into a running WindVE engine: every completed batch
    feeds an observation; every ``refit_every`` completions the depths are
    re-estimated and applied atomically.

    Uses the engine's first-class batch-completion hook (the seed
    monkey-patched every backend's ``embed_batch``, which broke per-worker
    model ownership and was invisible to other instrumentation).  Returns
    the hook so callers can ``engine.remove_batch_hook(hook)`` to detach.

    ``policy`` (optional): a :class:`~repro.core.routing.PredictivePolicy`
    (anything with ``update(tier, fit, bucket=None)``) to stream refreshed
    fits into on every refit — the latency-predictive dispatch then follows
    the LIVE service curve, not the offline calibration it was seeded with.
    ``bucket_fn`` (``Query -> bucket``) keys the per-bucket windows by the
    batch's LONGEST member — service latency follows the max length (one
    padded execution), so that is the length the observation belongs to.
    Under bucketed dispatch every popped batch is single-bucket and this is
    simply the batch's bucket; on tiers draining mixed-length batches it
    avoids filing a long batch's latency under a short query's bucket.
    """
    done = {"n": 0}

    def on_batch(tier: str, batch, service_latency_s: float) -> None:
        bucket = bucket_fn(max(batch, key=lambda q: q.length)) \
            if (bucket_fn and batch) else None
        calibrator.observe(tier, len(batch), service_latency_s, bucket=bucket)
        done["n"] += len(batch)
        if done["n"] >= refit_every:
            done["n"] = 0
            for dev, q in engine.qm.queues.items():
                new, fit = calibrator.suggest_depth(dev, q.depth)
                if new > 0 and new != q.depth:
                    engine.qm.set_depth(dev, new)
                if policy is not None:
                    if fit is not None:
                        policy.update(dev, fit)
                    for b in calibrator.buckets_for(dev):
                        fb = calibrator.fit(dev, bucket=b)
                        if fb is not None:
                            policy.update(dev, fb, bucket=b)

    return engine.add_batch_hook(on_batch)
