"""The scheduling core: N device tiers x pluggable dispatch policies.

The paper's Algorithm 1 is a cascade over an *ordered list of device tiers*
(main NPU queue, then the auxiliary CPU queue).  The seed hardcoded exactly
two string-keyed queues in three divergent places (threaded engine, DES,
calibrator monkey-patch); this module is the single implementation they all
drive now:

* ``TierSpec``       — one device pool: name, queue depth (C^max), optional
                       engine backend / DES latency model, batch and worker
                       limits.  A topology is just a list of these.
* ``DispatchPolicy`` — orders the tiers a query may enter.  ``CascadePolicy``
                       is paper-exact Algorithm 1 generalized to N tiers;
                       ``LengthAwarePolicy`` pins long queries to the fast
                       tier(s) (§5.4: CPU concurrency collapses with query
                       length); ``LeastLoadedPolicy`` balances by free share.
* ``QueueManager``   — bounded per-tier FIFOs + atomic policy dispatch +
                       shared :class:`~repro.core.telemetry.Telemetry`.

Queue depths are the SLO contract: depth == the largest concurrency whose
processing latency still meets the SLO (estimated by
``repro.core.estimator``).  Thread-safe; the real engine (windve.py) drives
it from a request thread while worker threads drain it, and the DES
(simulator.py) drives it single-threaded.

The legacy two-queue constructor ``QueueManager(npu_depth, cpu_depth,
heter_enable=...)`` still works and builds the equivalent 2-tier cascade.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, replace as _dc_replace
from typing import (Any, Callable, Deque, Dict, Iterable, List, Optional,
                    Sequence, Tuple, Union)

from repro.core.health import CLOSED as BREAKER_CLOSED
from repro.core.health import NORMAL as BROWNOUT_NORMAL
from repro.core.health import OPEN as BREAKER_OPEN
from repro.core.telemetry import Telemetry

NPU = "NPU"
CPU = "CPU"
BUSY = "BUSY"
# dispatch verdict for a query already past its deadline on arrival (or on a
# retry re-dispatch): it never enters a queue and never reaches a device
EXPIRED = "EXPIRED"
# dispatch verdict for a query the admission controller turned away (priced
# as a predictable SLO miss, or over every tier's backpressure watermark):
# rejected at arrival, it never occupies a queue slot
ADMISSION = "ADMISSION"
# pseudo-tier key for deadline misses detected at dispatch time (the query
# was never queued on any tier, so no tier owns the miss)
ARRIVAL = "arrival"


class ServeError(RuntimeError):
    """Structured terminal serving failure — what a client future carries
    instead of a raw backend traceback.

    ``kind``: ``"backend_error"`` (every retry attempt failed),
    ``"deadline"`` (see :class:`DeadlineExceeded`), ``"worker_death"`` (the
    tier's last worker thread died with this query stranded in its queue),
    ``"no_capacity"`` (re-dispatch after a failure found every surviving
    tier full), ``"admission"`` (the admission controller shed the query —
    at arrival it is a rejection, not a terminal serving failure; on a
    retry re-dispatch it is terminal).  ``attempts`` is how many
    re-dispatches were burned and ``cause`` the last underlying exception
    (None for deadline misses).
    """

    def __init__(self, kind: str, tier: Optional[str] = None,
                 qid: Optional[int] = None, attempts: int = 0,
                 cause: Optional[BaseException] = None):
        self.kind = kind
        self.tier = tier
        self.qid = qid
        self.attempts = attempts
        self.cause = cause
        msg = f"{kind} (tier={tier}, qid={qid}, attempts={attempts})"
        if cause is not None:
            msg += f": {cause!r}"
        super().__init__(msg)


class DeadlineExceeded(ServeError):
    """The query's absolute deadline passed before it could be served —
    while queued (the sweep expired it), at dispatch (it arrived dead), or
    between retry attempts."""

    def __init__(self, tier: Optional[str] = None, qid: Optional[int] = None,
                 attempts: int = 0):
        super().__init__("deadline", tier=tier, qid=qid, attempts=attempts)


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded re-dispatch of queries from a failed batch.

    A failed batch's queries go back through ``QueueManager.dispatch`` (the
    normal policy path — so survivors route to whatever healthy tier the
    policy picks), each re-dispatch burning one of ``max_retries`` attempts
    carried on ``Query.attempts``.  ``backoff(attempt)`` is the exponential
    pause before attempt N (1-based): ``backoff_s * backoff_factor**(N-1)``
    — the DES prices it as simulated delay, the engine sleeps it in the
    failed tier's worker (the tier that just failed is the one that waits).
    """

    max_retries: int = 2
    backoff_s: float = 0.0
    backoff_factor: float = 2.0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff_s < 0:
            raise ValueError("backoff_s must be >= 0")
        if self.backoff_factor < 1.0:
            raise ValueError("backoff_factor must be >= 1")

    def backoff(self, attempt: int) -> float:
        if attempt < 1:
            raise ValueError("attempt is 1-based")
        return self.backoff_s * self.backoff_factor ** (attempt - 1)


@dataclass
class Query:
    qid: int
    payload: Any = None          # token ids / text
    length: int = 75             # paper default query length (tokens)
    arrival_t: float = 0.0
    # filled by the system:
    device: Optional[str] = None
    start_t: float = 0.0
    done_t: float = 0.0
    emb: Any = None              # filled by a cache-tier hit at dispatch
    # fault tolerance: absolute deadline on the driver's clock (monotonic /
    # sim time; None = no deadline) and the retry attempts burned so far
    deadline: Optional[float] = None
    attempts: int = 0

    @property
    def e2e_latency(self) -> float:
        return self.done_t - self.arrival_t

    def expired(self, now: float) -> bool:
        """Dead at ``now``?  The deadline is the first dead instant
        (``now >= deadline``), so an expiry swept exactly at the deadline
        behaves identically whichever same-instant event runs first."""
        return self.deadline is not None and now >= self.deadline


class BoundedQueue:
    """FIFO with a hard depth bound == the device's C^max."""

    def __init__(self, depth: int):
        if depth < 0:
            raise ValueError("queue depth must be >= 0")
        self.depth = depth
        self._q: Deque[Query] = deque()
        self._lock = threading.Lock()
        # paper semantics: queue length counts queued AND in-flight queries —
        # C^max bounds *concurrency*, not just waiting items.
        self._in_flight = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._q) + self._in_flight

    @property
    def is_full(self) -> bool:
        return len(self) >= self.depth

    def push(self, q: Query) -> bool:
        with self._lock:
            if len(self._q) + self._in_flight >= self.depth:
                return False
            self._q.append(q)
            return True

    def pop_batch(self, max_batch: int,
                  bucket_fn: Optional[Callable[[Query], Any]] = None
                  ) -> List[Query]:
        """Dequeue up to max_batch queries and mark them in-flight.

        With a ``bucket_fn`` the batch is *length-aware*: the oldest queued
        query picks the bucket (strict FIFO decides who is served next), then
        only queries in that same bucket join the batch — so one execution
        pads to the bucket's shape, not to the longest straggler.  Queries in
        other buckets keep their arrival order and wait for a later pop.
        """
        out: List[Query] = []
        with self._lock:
            if bucket_fn is None:
                while self._q and len(out) < max_batch:
                    out.append(self._q.popleft())
            elif self._q:
                key = bucket_fn(self._q[0])
                rest: Deque[Query] = deque()
                while self._q:
                    q = self._q.popleft()
                    if len(out) < max_batch and bucket_fn(q) == key:
                        out.append(q)
                    else:
                        rest.append(q)
                self._q = rest
            self._in_flight += len(out)
        return out

    def expire(self, now: float) -> List[Query]:
        """Remove and return every *queued* query whose deadline has passed
        at ``now`` (in-flight work cannot be recalled).  The returned
        queries never count as in-flight — their slots free immediately."""
        dead: List[Query] = []
        with self._lock:
            if not self._q:
                return dead
            live: Deque[Query] = deque()
            for q in self._q:
                (dead if q.expired(now) else live).append(q)
            self._q = live
        return dead

    def finish(self, n: int) -> None:
        with self._lock:
            self._in_flight -= n
            assert self._in_flight >= 0


@dataclass
class TierSpec:
    """One device pool in the topology, in cascade-priority order.

    ``backend`` is what the threaded engine runs (``embed_batch``-capable);
    ``model`` is what the DES samples latencies from (a ``DeviceModel``).
    Either may be None when the spec is used by the other driver.
    ``max_batch`` defaults to the live queue depth; ``workers`` is the number
    of engine threads draining this tier (Algorithm 2's N instances).

    ``bucket_fn`` (optional, ``Query -> hashable``) makes this tier drain its
    queue in length buckets: each popped batch contains only queries whose
    bucket matches the oldest waiting query's (see
    ``BoundedQueue.pop_batch``).  Pair it with a shape-bucketed backend
    (``repro.core.bucketing``) so intra-batch padding collapses to the
    bucket boundary.

    ``cache`` (optional, an ``repro.core.cache.EmbeddingCache``) makes this
    a *zero-latency cache tier*: it holds no queue and no device —
    ``QueueManager.dispatch`` consults it before policy dispatch, a hit
    completes the query immediately, and the drivers admit computed
    embeddings back via ``QueueManager.admit``.  Cache tiers are invisible
    to ``DispatchPolicy.candidates`` (see :func:`dispatchable`): they have
    no queue depth to fill and no service curve to price.

    ``breaker`` (optional, a ``repro.core.health.CircuitBreaker``) gives
    the tier health state: the drivers feed batch outcomes through
    ``QueueManager.tier_success`` / ``tier_failure`` and a tripped (open)
    breaker removes the tier from :func:`dispatchable`, so every policy
    transparently routes around it until its half-open probe recovers.

    ``quantized`` marks a reduced-precision (W8A8/int8) tier: under
    brownout degradation the candidate re-rank prefers quantized tiers at
    equal backlog — quality is shed before queries are (see
    ``repro.core.health.BrownoutController.reorder``).  Inert otherwise.

    ``replica_of`` / ``host`` are replica identity, set by
    :func:`replicate` when this spec is one replica of a logical tier:
    ``replica_of`` names the logical tier and ``host`` the host index the
    replica's device group lives on.  The scheduler itself treats replicas
    as ordinary tiers (that is the point — each replica is an
    independently-failing capacity unit with its own queue, breaker,
    admission watermark, and service-curve fit); the identity fields exist
    so summaries and telemetry can roll per-replica counters back up to
    the logical tier (``replica_base``).
    """

    name: str
    depth: int
    backend: Any = None
    model: Any = None
    max_batch: Optional[int] = None
    workers: int = 1
    bucket_fn: Optional[Callable[[Query], Any]] = None
    cache: Any = None
    breaker: Any = None
    quantized: bool = False
    replica_of: Optional[str] = None
    host: int = 0


def device_tiers(tiers: Sequence[TierSpec]) -> List[TierSpec]:
    """The tiers that hold a bounded queue and a device: everything but the
    zero-latency cache tiers.  This is the *structural* set — queues and
    workers exist for these regardless of live health state."""
    return [t for t in tiers if t.cache is None]


def dispatchable(tiers: Sequence[TierSpec]) -> List[TierSpec]:
    """The tiers a policy may route a query into RIGHT NOW: device tiers
    (cache tiers are consulted by ``QueueManager.dispatch`` BEFORE the
    policy runs — a hit never reaches a device) whose circuit breaker, if
    any, is not open.  A tripped tier keeps its queue and workers — queued
    work still drains, cache hits still serve — but receives no new
    queries until its half-open probe succeeds, so every policy ranks over
    this filtered list and degrades around failures without knowing they
    exist.
    """
    return [t for t in tiers if t.cache is None and
            (t.breaker is None or t.breaker.dispatchable)]


# ---------------------------------------------------------------------------
# replicas: one logical tier expanded into hosts x replicas capacity units
# ---------------------------------------------------------------------------

def replica_name(base: str, host: int, replica: int) -> str:
    """Canonical replica tier name: ``NPU`` on host 1, replica 0 ->
    ``NPU@h1r0``.  Telemetry, fits, breakers, and watermarks all key by
    this name, so every per-tier mechanism is per-replica automatically."""
    return f"{base}@h{host}r{replica}"


def replica_base(name: str) -> str:
    """Logical tier a replica name belongs to (``NPU@h1r0`` -> ``NPU``);
    identity for non-replica names, so roll-ups are safe on any tier."""
    i = name.rfind("@h")
    return name[:i] if i > 0 else name


def replicate(spec: TierSpec, hosts: int = 1, replicas: int = 1, *,
              backend: Optional[Callable[[int, int], Any]] = None,
              model: Optional[Callable[[int, int], Any]] = None,
              breaker: Optional[Callable[[int, int], Any]] = None,
              ) -> List[TierSpec]:
    """Expand one logical tier into ``hosts * replicas`` first-class
    ``TierSpec``s (cascade order: host-major, replica-minor).

    Each replica must be an *independently-failing* capacity unit, so the
    stateful parts are built per replica through the optional factories
    (``(host, replica) -> instance``): a shared backend would serialize
    replicas on one device group, a shared breaker would quarantine all
    replicas when one host dies.  Fields with no factory are copied from
    ``spec`` (depth, max_batch, bucket_fn, quantized — per-replica policy
    knobs are a ``dataclasses.replace`` away).

    The degrade rule mirrors ``sharded_model``: ``replicate(spec, 1, 1)``
    returns ``[spec]`` UNCHANGED — same object, same name — so a 1x1
    topology is bitwise today's single-replica path (the factories are not
    consulted; the spec's own backend/model ARE the single replica).
    """
    if hosts < 1 or replicas < 1:
        raise ValueError(f"hosts and replicas must be >= 1, "
                         f"got {hosts}x{replicas}")
    if spec.cache is not None:
        raise ValueError("cache tiers hold no device group to replicate")
    if hosts == 1 and replicas == 1:
        return [spec]
    out: List[TierSpec] = []
    for h in range(hosts):
        for r in range(replicas):
            out.append(_dc_replace(
                spec,
                name=replica_name(spec.name, h, r),
                backend=backend(h, r) if backend is not None else spec.backend,
                model=model(h, r) if model is not None else spec.model,
                breaker=breaker(h, r) if breaker is not None else spec.breaker,
                replica_of=spec.name,
                host=h))
    return out


@dataclass(frozen=True)
class ReplicaSet:
    """The replica view of one logical tier: the expanded specs plus the
    grouping lens (per-host, per-name) that serve summaries and telemetry
    roll-ups look through.  ``build`` is :func:`replicate` + bookkeeping;
    at 1x1 the set holds the original spec under its original name."""

    base: str
    hosts: int
    replicas: int
    specs: Tuple[TierSpec, ...]

    @classmethod
    def build(cls, spec: TierSpec, hosts: int = 1, replicas: int = 1,
              **factories: Any) -> "ReplicaSet":
        return cls(spec.name, hosts, replicas,
                   tuple(replicate(spec, hosts, replicas, **factories)))

    @property
    def names(self) -> List[str]:
        return [t.name for t in self.specs]

    def on_host(self, host: int) -> List[TierSpec]:
        return [t for t in self.specs if t.host == host]

    def __iter__(self):
        return iter(self.specs)

    def __len__(self) -> int:
        return len(self.specs)


class DispatchPolicy:
    """Orders the tiers a query may enter; first with free capacity wins.

    ``QueueManager.dispatch`` holds its lock while trying the candidates in
    order, so a policy only decides *ordering* — admission stays atomic.
    """

    name = "policy"

    def candidates(self, query: Query, tiers: Sequence[TierSpec],
                   qm: "QueueManager") -> Iterable[str]:
        raise NotImplementedError


class CascadePolicy(DispatchPolicy):
    """Paper-exact Algorithm 1, generalized: overflow down the tier list."""

    name = "cascade"

    def candidates(self, query, tiers, qm):
        return [t.name for t in dispatchable(tiers)]


class LengthAwarePolicy(DispatchPolicy):
    """§5.4-informed: long queries only fit the fast tier(s).

    Fig. 5 shows the CPU pool's additional concurrency collapsing to 0 by
    query length 500 at the 1 s SLO — a long query offloaded to a slow tier
    is a guaranteed SLO violation, so spend slow-tier slots on short queries
    only and cascade long ones over the first ``fast_tiers`` entries.
    """

    name = "length-aware"

    def __init__(self, long_threshold: int = 300, fast_tiers: int = 1):
        if long_threshold <= 0:
            raise ValueError("long_threshold must be positive")
        if fast_tiers < 1:
            raise ValueError("need at least one fast tier")
        self.long_threshold = long_threshold
        self.fast_tiers = fast_tiers

    @classmethod
    def from_bucket_depths(cls, bucket_depths: Dict[int, int],
                           fast_tiers: int = 1) -> "LengthAwarePolicy":
        """Derive the long-query threshold from measured per-bucket depths.

        ``bucket_depths`` maps a seq-length bucket to its SLO-safe slow-tier
        depth (one Eq. 12 fit per bucket — see
        ``repro.core.estimator.estimate_depth_per_bucket``).  Queries round
        UP into their bucket (``bucketing.bucket_length``), so the first
        bucket whose depth collapsed to 0 (the paper's Eq. 11 "CPU cannot
        be used" case, observed per bucket instead of assumed at a fixed
        length) poisons every length ABOVE the previous live bucket — the
        threshold is that lower boundary, not the dead bucket's own padded
        length.  If every profiled bucket still has capacity, anything
        beyond the profiled range counts as long — unprofiled lengths must
        not be routed onto the slow tier on faith.
        """
        if not bucket_depths:
            raise ValueError("need at least one bucket depth")
        buckets = sorted(bucket_depths)
        dead = [b for b in buckets if bucket_depths[b] <= 0]
        if not dead:
            threshold = buckets[-1] + 1
        else:
            prev = [b for b in buckets if b < dead[0]]
            # smallest profiled bucket dead -> every length pads into a
            # dead bucket, so every query is long (threshold must stay > 0)
            threshold = prev[-1] + 1 if prev else 1
        return cls(long_threshold=threshold, fast_tiers=fast_tiers)

    def candidates(self, query, tiers, qm):
        # fast_tiers counts REAL device tiers: a cache tier at the head of
        # the topology must not eat the fast slot(s)
        real = dispatchable(tiers)
        if query.length >= self.long_threshold:
            return [t.name for t in real[:self.fast_tiers]]
        return [t.name for t in real]


class LeastLoadedPolicy(DispatchPolicy):
    """Route to the tier with the largest free share (ties: cascade order).

    Unlike the cascade this spreads sub-peak load across tiers, trading the
    paper's strict fast-tier priority for drain-queue headroom everywhere.
    """

    name = "least-loaded"

    def candidates(self, query, tiers, qm):
        real = dispatchable(tiers)

        def free_share(t: TierSpec) -> float:
            d = qm.depth(t.name)
            return (d - len(qm.queues[t.name])) / d if d > 0 else -1.0

        order = sorted(range(len(real)),
                       key=lambda i: (-free_share(real[i]), i))
        return [real[i].name for i in order]


class PredictivePolicy(DispatchPolicy):
    """Route to the tier with the minimal *predicted completion time*.

    The paper's Eq. 12 says tier service latency is (near-)linear in
    concurrency; the cascade ignores that and fills the fast tier to its
    depth before spilling, so at peak every fast-tier query pays the
    full-depth latency while slow-tier slots idle at t(1).  This policy
    prices each candidate tier with its calibrated service curve at the
    backlog the query would join:

        predicted(tier) = fit_tier.latency(backlog(tier) + 1)

    where backlog counts queued + in-flight queries (the paper's C
    semantics) and ``fit`` is anything with a ``latency(concurrency)``
    method — an ``estimator.LatencyFit`` (offline calibration), a
    ``simulator.DeviceModel``/``FanOutModel`` (the DES), or whatever the
    online calibrator refits from live traffic
    (``adaptive.attach(..., policy=...)`` keeps the fits fresh through the
    engine's batch-completion hook).

    ``bucket_fn`` (optional, ``Query -> bucket``) selects per-bucket fits
    registered via ``update(tier, fit, bucket=...)`` — a bucketed CPU tier
    serves a 16-token bucket several times faster than a 96-token one, so
    one global line misprices long queries (§5.4).  Lookup falls back from
    ``(tier, bucket)`` to the tier-level fit; tiers with no fit at all keep
    their cascade order BEHIND every fitted tier, so an uncalibrated
    topology degrades to Algorithm 1 instead of routing blind.
    """

    name = "predictive"

    def __init__(self, fits: Optional[Dict[str, Any]] = None,
                 bucket_fn: Optional[Callable[[Query], Any]] = None):
        self.bucket_fn = bucket_fn
        self._fits: Dict[Any, Any] = dict(fits or {})
        self._fit_lock = threading.Lock()

    def update(self, tier: str, fit: Any, bucket: Any = None) -> None:
        """Install/replace the service-curve estimate for a tier (or one of
        its length buckets).  Called by the online calibrator on refit."""
        with self._fit_lock:
            self._fits[tier if bucket is None else (tier, bucket)] = fit

    def fit_for(self, tier: str, query: Optional[Query] = None) -> Any:
        with self._fit_lock:
            if query is not None and self.bucket_fn is not None:
                f = self._fits.get((tier, self.bucket_fn(query)))
                if f is not None:
                    return f
            return self._fits.get(tier)

    def predicted_completion_s(self, tier: str, query: Query,
                               qm: "QueueManager") -> Optional[float]:
        """Service latency this query would see joining ``tier`` now, per
        the tier's calibrated curve; None when the tier has no fit yet."""
        fit = self.fit_for(tier, query)
        if fit is None:
            return None
        return float(fit.latency(len(qm.queues[tier]) + 1))

    def candidates(self, query, tiers, qm):
        # cache tiers never appear as candidates: a hit completed at
        # dispatch (predicted completion ~0 needs no pricing) and a MISS by
        # definition cannot be served there — only device tiers hold a
        # backlog for the fits to price
        real = dispatchable(tiers)

        def key(i: int):
            p = self.predicted_completion_s(real[i].name, query, qm)
            # fitted tiers first, cheapest predicted completion wins;
            # unfitted tiers trail in cascade order (graceful degrade)
            return (0, p, i) if p is not None else (1, 0.0, i)

        return [real[i].name for i in sorted(range(len(real)), key=key)]


class RoundRobinPolicy(DispatchPolicy):
    """Replica-oblivious baseline: rotate the dispatchable tier list one
    position per dispatch, blind to backlog, service curves, or replica
    identity.  This is the strawman front-end router the multi-replica A/B
    (``benchmarks/multihost_microbench.py``) measures ``PredictivePolicy``
    against — same hardware, no per-replica pricing.  Deterministic: the
    rotation counter advances exactly once per ``candidates`` call, so
    both drivers see the same sequence for the same arrival order."""

    name = "round-robin"

    def __init__(self):
        self._n = 0
        self._rr_lock = threading.Lock()

    def candidates(self, query, tiers, qm):
        real = dispatchable(tiers)
        if not real:
            return []
        with self._rr_lock:
            k = self._n % len(real)
            self._n += 1
        return [t.name for t in real[k:] + real[:k]]


class QueueManager:
    """Policy dispatch over N bounded tier queues (Algorithm 1 core).

    New-style: ``QueueManager([TierSpec(...), ...], policy=CascadePolicy())``.
    Legacy:    ``QueueManager(npu_depth, cpu_depth, heter_enable=...)`` —
    builds the paper's 2-tier NPU/CPU cascade.
    """

    def __init__(self, tiers: Union[int, Sequence[TierSpec], None] = None,
                 cpu_depth: int = 0, heter_enable: bool = True, *,
                 npu_depth: Optional[int] = None,
                 policy: Optional[DispatchPolicy] = None,
                 stats: Optional[Telemetry] = None,
                 admission: Any = None,
                 brownout: Any = None):
        if npu_depth is not None:           # legacy keyword form
            tiers = npu_depth
        if isinstance(tiers, int):          # legacy positional form
            specs = [TierSpec(NPU, tiers)]
            if heter_enable and cpu_depth > 0:
                specs.append(TierSpec(CPU, cpu_depth))
            tiers = specs
        if not tiers:
            raise ValueError("need at least one tier")
        names = [t.name for t in tiers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names: {names}")
        self.tiers: List[TierSpec] = list(tiers)
        # zero-latency cache tiers are consulted before policy dispatch and
        # hold no bounded queue (a hit never occupies a concurrency slot)
        self.cache_tiers: List[TierSpec] = [t for t in self.tiers
                                            if t.cache is not None]
        if not device_tiers(self.tiers):
            raise ValueError("need at least one non-cache tier")
        self.policy: DispatchPolicy = policy or CascadePolicy()
        # queues exist per DEVICE tier, tripped or not: a breaker gates
        # admission, never the existence of the tier's queue/workers
        self.queues: Dict[str, BoundedQueue] = {
            t.name: BoundedQueue(t.depth) for t in device_tiers(self.tiers)}
        self.stats: Telemetry = stats if stats is not None else Telemetry()
        # overload control (both optional): an
        # ``repro.core.admission.AdmissionController`` consulted after the
        # cache tiers and before policy dispatch, and a
        # ``repro.core.health.BrownoutController`` whose utilization EWMA
        # is fed every arrival and whose stage reorders candidates /
        # tightens deadlines under overload
        self.admission = admission
        self.brownout = brownout
        self._brownout_stage = BROWNOUT_NORMAL
        # driver hook: called (outside the queue lock) for every queued
        # query the deadline sweep expires — the engine fails its future
        # with DeadlineExceeded; the DES needs no action beyond telemetry
        self.on_expire: Optional[Callable[[Query], None]] = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    @property
    def heter_enable(self) -> bool:
        """Legacy flag: True iff an auxiliary tier exists."""
        return len(self.tiers) > 1

    def is_cache_tier(self, name: str) -> bool:
        return any(t.name == name for t in self.cache_tiers)

    def dispatch(self, query: Query, now: Optional[float] = None) -> str:
        """Route one query.  Returns the admitting tier's name, BUSY,
        EXPIRED (already past its deadline — it never enters a queue), or
        ADMISSION (shed by the admission controller at arrival).

        Cache tiers are consulted first, in topology order: an exact-match
        hit fills ``query.emb``, counts as a dispatch to (and completion
        responsibility of) the cache tier, and never touches a device queue
        — the driver must complete the query immediately (zero service
        time).  Misses record per-tier miss telemetry and fall through to
        overload control, then normal policy dispatch.  Cache hits are
        served at EVERY brownout stage and are never subject to admission:
        they cost nothing, which is exactly what an overloaded system
        wants to serve.  ``now`` defaults to ``query.arrival_t``
        (the lookup clock for cache staleness and the breaker clock under
        both drivers: monotonic / sim time); retry re-dispatch passes the
        current clock explicitly since ``arrival_t`` is then stale.
        """
        if now is None:
            now = query.arrival_t
        with self._lock:
            if query.expired(now):
                self.stats.record_deadline_miss(ARRIVAL)
                self.stats.record_rejection("expired")
                return EXPIRED
            # advance every breaker's clock: open tiers whose cooldown has
            # elapsed become half-open (dispatchable again) on THIS
            # driver's clock, so the recovery probe is deterministic
            for t in self.tiers:
                if t.breaker is not None:
                    t.breaker.tick(now)
            for ct in self.cache_tiers:
                entry = ct.cache.get(query, now=now)
                if entry is not None:
                    query.device = ct.name
                    query.emb = entry.value
                    self.stats.record_dispatch(ct.name)
                    self.stats.record_cache_hit(
                        ct.name, max(0.0, now - entry.t))
                    return ct.name
                self.stats.record_cache_miss(ct.name)
            stage = BROWNOUT_NORMAL
            if self.brownout is not None:
                stage = self.brownout.observe(self.utilization(), now)
                if stage != self._brownout_stage:
                    self.stats.record_brownout(stage)
                    self._brownout_stage = stage
                # degraded/shedding: tighten the remaining deadline budget
                # so queued work that cannot finish in time expires early
                query.deadline = self.brownout.tighten(query.deadline, now)
            allowed = None
            if self.admission is not None:
                allowed = self.admission.decide(
                    query, self.tiers, self, now, stage)
                if allowed is None:
                    self.stats.record_rejection("admission")
                    return ADMISSION
            names = self.policy.candidates(query, self.tiers, self)
            if self.brownout is not None:
                names = self.brownout.reorder(list(names), self)
            for name in names:
                if name not in self.queues:     # custom policies may emit
                    continue                    # cache-tier names: skip
                if allowed is not None and name not in allowed:
                    continue                    # over its watermark
                if self.queues[name].push(query):
                    query.device = name
                    self.stats.record_dispatch(name)
                    return name
            self.stats.record_busy()
            return BUSY

    def utilization(self) -> float:
        """Live load fraction: queued + in-flight over the dispatchable
        capacity (the paper's C summed over reachable tiers), clamped to
        [0, 1].  1.0 when no capacity is reachable — a fully-tripped
        topology IS overloaded.  The clamp matters: retry/failover
        re-dispatch onto a shrunken dispatchable set (a tripped tier keeps
        its in-flight work while leaving the denominator), or an online
        ``set_depth`` below the live backlog, can push the raw ratio past
        1.0 — a *fraction* above 1 would over-drive the brownout EWMA
        through its shedding threshold in a single sample."""
        cap = self.degraded_max_concurrency
        if cap <= 0:
            return 1.0
        load = sum(len(self.queues[t.name]) for t in dispatchable(self.tiers)
                   if t.name in self.queues)
        return max(0.0, min(1.0, load / cap))

    # -- fault-tolerance bridges (drivers -> breaker + telemetry) ----------
    def tier_success(self, device: str, service_s: float, now: float) -> None:
        """One completed batch on ``device``: feed the tier's breaker (if
        any) and record a half-open probe success as a recovery."""
        t = self.tier(device)
        if t.breaker is None:
            return
        before = t.breaker.state
        t.breaker.record_success(service_s, now)
        after = t.breaker.state
        if before != after:
            if after == BREAKER_CLOSED:
                self.stats.record_breaker_recovery(device)
            elif after == BREAKER_OPEN:    # latency-EWMA stall trip
                self.stats.record_breaker_trip(device)

    def tier_failure(self, device: str, now: float) -> None:
        """One failed batch on ``device``: count the backend error and feed
        the tier's breaker; a threshold crossing records the trip."""
        self.stats.record_backend_error(device)
        t = self.tier(device)
        if t.breaker is None:
            return
        before = t.breaker.state
        t.breaker.record_failure(now)
        if before != BREAKER_OPEN and t.breaker.state == BREAKER_OPEN:
            self.stats.record_breaker_trip(device)

    def sweep(self, device: str, now: float) -> List[Query]:
        """Expire overdue *queued* queries on one tier: each is removed
        from the queue (its slot frees immediately), counted as a
        ``deadline_miss`` against the tier, and handed to ``on_expire`` so
        the driver can fail its future.  The engine sweeps on every worker
        poll; the DES sweeps at exact per-query deadline events and before
        every batch formation — either way ``pop_batch`` never forms a
        batch from dead work."""
        if device not in self.queues:
            return []
        dead = self.queues[device].expire(now)
        for q in dead:
            self.stats.record_deadline_miss(device)
            if self.on_expire is not None:
                self.on_expire(q)
        return dead

    def tripped(self) -> List[str]:
        """Names of tiers currently removed from dispatch by their breaker."""
        return [t.name for t in device_tiers(self.tiers)
                if t.breaker is not None and not t.breaker.dispatchable]

    @property
    def degraded_max_concurrency(self) -> int:
        """sum of C^max over the tiers dispatch can reach *right now* —
        the live capacity the SLO contract actually has while breakers are
        open (``cost_model.degraded_capacity`` gives the closed form)."""
        return sum(self.queues[t.name].depth for t in dispatchable(self.tiers)
                   if t.name in self.queues)

    def admit(self, query: Query, value: Any = None) -> Optional[str]:
        """Admission hook: insert one computed embedding into the head
        cache tier (if any).  Drivers call this per completed query, BEFORE
        resolving its future — so any caller that observed a result can
        rely on the key being cached.  ``query.done_t`` timestamps the
        entry (the staleness clock under either driver).  Returns the
        admitting cache tier's name, or None when the topology has none."""
        for ct in self.cache_tiers:
            evicted = ct.cache.put(query, value, now=query.done_t)
            self.stats.record_cache_insert(ct.name, evicted)
            return ct.name
        return None

    def tier(self, name: str) -> TierSpec:
        for t in self.tiers:
            if t.name == name:
                return t
        raise KeyError(name)

    def depth(self, device: str) -> int:
        return self.queues[device].depth if device in self.queues else 0

    def set_depth(self, device: str, depth: int) -> None:
        """Resize a tier's SLO contract (online re-calibration)."""
        if depth < 0:
            raise ValueError("queue depth must be >= 0")
        self.queues[device].depth = depth
        self.tier(device).depth = depth

    def max_batch(self, device: str) -> int:
        """Effective batch bound: the spec's max_batch or the live depth."""
        spec = self.tier(device)
        return spec.max_batch if spec.max_batch else \
            max(1, self.queues[device].depth)

    def pop_batch(self, device: str, now: Optional[float] = None
                  ) -> List[Query]:
        """Drain one batch from a tier, honouring its ``bucket_fn``.

        Both drivers (threaded engine, DES) form batches through this single
        entry point so batch composition cannot diverge between them.  With
        ``now`` set, overdue queued queries are swept out first (see
        :meth:`sweep`) — a batch never contains dead work.
        """
        if now is not None:
            self.sweep(device, now)
        return self.queues[device].pop_batch(self.max_batch(device),
                                             self.tier(device).bucket_fn)

    def reset(self, stats: Optional[Telemetry] = None) -> Telemetry:
        """Fresh queues (at current depths), empty caches, closed breakers
        + fresh telemetry — one DES run starts cold and deterministic."""
        with self._lock:
            self.queues = {t.name: BoundedQueue(self.depth(t.name) if
                                                t.name in self.queues else
                                                t.depth)
                           for t in device_tiers(self.tiers)}
            for ct in self.cache_tiers:
                ct.cache.clear()
            for t in self.tiers:
                if t.breaker is not None:
                    t.breaker.reset()
            if self.brownout is not None:
                self.brownout.reset()
            self._brownout_stage = BROWNOUT_NORMAL
            self.stats = stats if stats is not None else Telemetry()
        return self.stats

    @property
    def max_concurrency(self) -> int:
        """sum of C^max over tiers — the paper's headline metric."""
        return sum(q.depth for q in self.queues.values())
