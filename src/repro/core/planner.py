"""Failure-aware capacity planner: the calibrated DES as a sizing tool.

The paper's deployment-cost analysis (Eqs. 5-6) prices a topology from
closed forms; this module closes the remaining gap to *operations*: it
evaluates candidate deployments — tier/device counts, admission and
brownout settings, fault exposure — by actually running them in the
discrete-event simulator against realistic arrival traces (diurnal,
flash-crowd, MTTF outage schedules) and reduces each run to the numbers a
sizing decision needs:

* **SLO attainment** — fraction of OFFERED queries served within the SLO
  (rejections and deadline misses both count against it: a shed query is
  a query the deployment did not serve);
* **cost per million accepted queries** —
  :func:`repro.core.cost_model.cost_per_million_queries` over the trace
  horizon, the unit-economics curve ``BENCH_capacity_plan.json`` plots.

The controllers under test are the REAL ones: a ``PlanArm`` carries the
same :class:`~repro.core.admission.AdmissionController` /
:class:`~repro.core.health.BrownoutController` objects the threaded engine
serves with, wired into the same ``QueueManager`` — the planner never
simulates a simplification of the system, it runs the system.

Typical use (see ``benchmarks/capacity_plan_microbench.py`` for the full
sweep)::

    tiers, fits = calibrated_tiers({"NPU": npu_model, "CPU": cpu_model},
                                   slo_s=1.0, quantized={"CPU"})
    arm = PlanArm("npu+cpu", tiers=tiers, price_per_s=10.5,
                  admission=AdmissionController(fits=fits, slo_s=1.0),
                  brownout=BrownoutController(), deadline_s=2.0)
    trace = flash_crowd_trace(40, base_rate=60, burst_mult=6,
                              burst_start=10, burst_len=10)
    point = evaluate(arm, trace, slo_s=1.0)
    point.slo_attainment, point.cost_per_m_accepted
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.cost_model import cost_per_million_queries
from repro.core.estimator import LatencyFit, fit_from_model
from repro.core.routing import DispatchPolicy, RetryPolicy, TierSpec
from repro.core.simulator import ServingSimulator

__all__ = ["PlanArm", "PlanPoint", "calibrated_tiers", "evaluate", "sweep",
           "best"]


@dataclass(frozen=True)
class PlanArm:
    """One candidate deployment the planner prices.

    ``tiers`` is a live TierSpec list (models set — this runs in the DES);
    ``price_per_s`` the topology's all-in price rate (devices x unit
    price, the Eq. 5/6 numerator); the optional controllers/policies are
    the exact serving objects, reset per evaluation by ``qm.reset`` /
    ``FaultModel.reset`` so one arm can be evaluated against many traces.
    Evaluate one arm sequentially — the TierSpecs hold live queue state
    during a run.
    """

    name: str
    tiers: Sequence[TierSpec]
    price_per_s: float
    admission: Optional[object] = None
    brownout: Optional[object] = None
    policy: Optional[DispatchPolicy] = None
    retry: Optional[RetryPolicy] = None
    deadline_s: Optional[float] = None
    faults: Mapping[str, object] = field(default_factory=dict)

    def __post_init__(self):
        if self.price_per_s < 0:
            raise ValueError("price_per_s must be >= 0")
        if not self.tiers:
            raise ValueError("need at least one tier")


@dataclass(frozen=True)
class PlanPoint:
    """One (arm, trace) evaluation, reduced to sizing numbers."""

    arm: str
    trace: str
    horizon_s: float
    arrivals: int
    accepted: int            # delivered: arrivals - rejections - failures
    completed: int
    in_slo: int              # completions within the SLO
    slo_attainment: float    # in_slo / arrivals — offered-load attainment
    deadline_misses: int
    failed: int
    rejections: Mapping[str, int]
    brownout_transitions: Mapping[str, int]
    cost: float              # price_per_s * horizon_s
    cost_per_m_accepted: float

    def row(self) -> Dict[str, float]:
        """Flat record for ``BENCH_capacity_plan.json``."""
        out = {
            "arm": self.arm,
            "trace": self.trace,
            "horizon_s": self.horizon_s,
            "arrivals": self.arrivals,
            "accepted": self.accepted,
            "completed": self.completed,
            "in_slo": self.in_slo,
            "slo_attainment": self.slo_attainment,
            "deadline_misses": self.deadline_misses,
            "failed": self.failed,
            "cost": self.cost,
            "cost_per_m_accepted": self.cost_per_m_accepted,
        }
        out.update({f"rejections_{k}": v
                    for k, v in sorted(self.rejections.items()) if v})
        out.update({f"brownout_to_{k}": v for k, v in
                    sorted(self.brownout_transitions.items())})
        return out


def calibrated_tiers(models: Mapping[str, object], slo_s: float,
                     quantized: Sequence[str] = (),
                     probe_points: Sequence[int] = (1, 4, 16, 64),
                     ) -> Tuple[List[TierSpec], Dict[str, LatencyFit]]:
    """SLO-calibrated topology from DES device models: each tier's depth is
    its Eq. 12 ``max_concurrency(slo)`` (the paper's C^max), and the
    returned fits are the matching service curves for an
    ``AdmissionController``/``PredictivePolicy`` — one calibration feeding
    dispatch, admission, and the simulator consistently.

    ``models`` iterates in cascade-priority order (dicts preserve
    insertion order); ``quantized`` names the tiers brownout may prefer at
    equal backlog.
    """
    tiers: List[TierSpec] = []
    fits: Dict[str, LatencyFit] = {}
    for name, model in models.items():
        fit = fit_from_model(model, probe_points)
        depth = fit.max_concurrency(slo_s)
        tiers.append(TierSpec(name, depth, model=model,
                              quantized=name in quantized))
        fits[name] = fit
    if all(t.depth <= 0 for t in tiers):
        raise ValueError(f"no tier meets the {slo_s}s SLO even at C=1")
    return tiers, fits


def evaluate(arm: PlanArm, trace: Sequence[Tuple[float, int]], *,
             slo_s: float = 1.0, trace_name: str = "trace",
             seed: int = 0) -> PlanPoint:
    """Run one arm against one arrival trace in the DES and reduce it."""
    if not trace:
        raise ValueError("need a non-empty arrival trace")
    sim = ServingSimulator(
        tiers=list(arm.tiers), slo_s=slo_s, seed=seed,
        policy=arm.policy, retry=arm.retry, deadline_s=arm.deadline_s,
        faults=dict(arm.faults), admission=arm.admission,
        brownout=arm.brownout)
    res = sim.run(list(trace))
    arrivals = len(trace)
    # at-arrival turn-aways: classic BUSY, admission sheds, dead on arrival
    shed = (res.rejected + res.rejections.get("admission", 0)
            + res.rejections.get("expired", 0))
    # accepted = delivered capacity: arrivals minus turn-aways minus
    # terminal failures (queued expiry, retry exhaustion).  A query the
    # deployment admitted and then failed is not a unit of capacity — an
    # outage arm must not look CHEAPER per query because it admitted work
    # it went on to burn.
    accepted = max(0, arrivals - shed - res.failed)
    horizon = max(float(trace[-1][0]), 1e-9)
    cost = arm.price_per_s * horizon
    return PlanPoint(
        arm=arm.name, trace=trace_name, horizon_s=horizon,
        arrivals=arrivals, accepted=accepted, completed=res.n_completed,
        in_slo=res.max_ok_concurrency,
        slo_attainment=res.max_ok_concurrency / arrivals,
        deadline_misses=sum(res.deadline_misses.values()),
        failed=res.failed,
        rejections=dict(res.rejections),
        brownout_transitions=dict(res.brownout_transitions),
        cost=cost,
        cost_per_m_accepted=cost_per_million_queries(
            arm.price_per_s, horizon, accepted))


def sweep(arms: Sequence[PlanArm],
          traces: Mapping[str, Sequence[Tuple[float, int]]], *,
          slo_s: float = 1.0, seed: int = 0) -> List[PlanPoint]:
    """Every arm against every named trace — the planner's full grid."""
    return [evaluate(arm, trace, slo_s=slo_s, trace_name=name, seed=seed)
            for arm in arms for name, trace in traces.items()]


def best(points: Sequence[PlanPoint],
         min_attainment: float = 0.0) -> PlanPoint:
    """Cheapest point (cost per million accepted) meeting the attainment
    bar — the sizing decision the curve exists to answer."""
    ok = [p for p in points if p.slo_attainment >= min_attainment]
    if not ok:
        raise ValueError(
            f"no plan point reaches SLO attainment {min_attainment}; "
            f"best seen {max(p.slo_attainment for p in points):.3f}")
    return min(ok, key=lambda p: (p.cost_per_m_accepted, p.arm))
