# The paper's primary contribution: CPU-NPU collaborative vector-embedding
# serving (WindVE).  Queue manager (Alg. 1), device detector (Alg. 2),
# linear-regression queue-depth estimator (Eq. 12), cost model (Eqs. 1-6),
# affinity planner (§4.4), calibrated discrete-event simulator and the real
# threaded serving engine.
from repro.core import (affinity, cost_model, device_detector, estimator,
                        routing, simulator, telemetry, windve)

__all__ = ["affinity", "cost_model", "device_detector", "estimator",
           "queue_manager", "routing", "simulator", "telemetry", "windve"]


def __getattr__(name):
    # the deprecated queue_manager alias warns on import; load it lazily so
    # only call sites that actually reach for it pay (and see) the warning
    if name == "queue_manager":
        from repro.core import queue_manager
        return queue_manager
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
