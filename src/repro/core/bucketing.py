"""Shape-bucketed execution for the embedding hot path.

The paper's deployment-cost argument makes per-batch service time the lever
behind concurrency-per-device, and its Fig. 5 shows the query-length
distribution is structured — yet the fixed-shape backend pads every batch to
the global ``max_tokens`` window and retraces jit for every distinct batch
size.  This module exploits the structure:

* ``next_pow2`` / ``bucket_length`` — round batch size and sequence length
  up to power-of-two buckets, so the set of compiled shapes is SMALL and
  ENUMERABLE (O(log max_batch x log max_tokens) instead of one shape per
  raw batch size) and padding stops at the bucket boundary.
* ``length_bucket_fn`` — a ``TierSpec.bucket_fn``: the queue drains queries
  grouped by length bucket (FIFO within the bucket, see
  ``repro.core.routing.BoundedQueue.pop_batch``), so one batch never pads
  its short queries to a long straggler's length.
* ``BucketedEmbedderBackend`` — a drop-in ``JaxEmbedderBackend`` that pads
  each batch only to its (B_bucket, S_bucket) bucket, keeps the jit compile
  cache warm per bucket, and supports eager pre-warming
  (``prewarm(default_buckets(...))``) so a serving process takes ZERO
  compile stalls after startup.

Correctness relies on the embedder being padding-invariant: padded key
positions are masked out of every attention softmax (``kv_mask`` in
``repro.models.embedder.embed``), so the same query embeds to the same
vector whether the batch is padded to 32 or 128 tokens.
"""
from __future__ import annotations

import threading
from typing import Callable, Iterable, List, Sequence, Tuple

import numpy as np

from repro.core.routing import Query
from repro.core.telemetry import Telemetry
from repro.core.windve import JaxEmbedderBackend


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (1 for n <= 1)."""
    if n <= 1:
        return 1
    return 1 << (int(n) - 1).bit_length()


def bucket_length(length: int, min_bucket: int = 16,
                  max_bucket: int = 128) -> int:
    """Round a token count up to its power-of-two bucket in
    [min_bucket, max_bucket] (max_bucket also caps: longer payloads are
    truncated by the backend and counted in telemetry)."""
    return min(max(next_pow2(length), min_bucket), max_bucket)


def length_bucket_fn(min_bucket: int = 16, max_bucket: int = 128
                     ) -> Callable[[Query], int]:
    """A ``TierSpec.bucket_fn``: group queries by padded-length bucket."""

    def fn(q: Query) -> int:
        return bucket_length(q.length, min_bucket, max_bucket)

    return fn


def default_buckets(max_batch: int, max_tokens: int = 128,
                    min_seq_bucket: int = 16, min_batch_bucket: int = 1
                    ) -> List[Tuple[int, int]]:
    """The full (B_bucket, S_bucket) grid — the enumerable compile-cache
    key space, suitable for ``BucketedEmbedderBackend.prewarm``."""
    bs: List[int] = []
    b = max(1, min_batch_bucket)
    while b < max_batch:
        bs.append(b)
        b *= 2
    bs.append(next_pow2(max_batch))
    ss: List[int] = []
    s = max(1, min_seq_bucket)
    while s < max_tokens:
        ss.append(s)
        s *= 2
    ss.append(max_tokens)
    return [(b, s) for b in bs for s in ss]


class BucketedEmbedderBackend(JaxEmbedderBackend):
    """Length-aware JAX embedder: pad to the (B, S) bucket, not the max.

    The sequence dim rounds up to its power-of-two bucket (short batches
    stop paying full-window FLOPs).  The batch dim uses a *binary
    decomposition plan* (``_batch_plan``): a batch of 9 runs as pow2 chunks
    8 + 1 rather than padding up to 16, so batch-dim padding rows all but
    vanish while the compiled-shape space stays the pow2 grid.  Each chunk
    buckets its OWN sequence length, and any padding rows carry an all-zero
    mask and are dropped from the output.

    Counters (shared with the fixed backend, which tracks the same):
    ``traces`` (jit retraces), ``bucket_hits`` (chunk launches served from
    an already-warm bucket), ``real_tokens`` / ``padded_tokens`` (padding
    waste; see ``padded_waste``), ``truncated``.
    """

    def __init__(self, cfg, params, max_tokens: int = 128, *,
                 min_seq_bucket: int = 16, min_batch_bucket: int = 1,
                 telemetry: Telemetry | None = None,
                 dtype: str | None = None,
                 prewarm_buckets: Sequence[Tuple[int, int]] = ()):
        super().__init__(cfg, params, max_tokens, telemetry=telemetry,
                         dtype=dtype)
        self.name = (f"jax-cpu-bucketed/{cfg.name}"
                     + (f"/{dtype}" if dtype else ""))
        self.min_seq_bucket = min_seq_bucket
        self.min_batch_bucket = min_batch_bucket
        self.bucket_hits = 0
        self._buckets: set = set()
        self._bucket_lock = threading.Lock()
        if prewarm_buckets:
            self.prewarm(prewarm_buckets)

    # ------------------------------------------------------------------
    def bucket_shape(self, batch: int, seq_len: int) -> Tuple[int, int]:
        """(B, S) -> the (B_bucket, S_bucket) a single-launch batch would
        execute at (the largest chunk of ``_batch_plan``)."""
        return (self._batch_plan(batch)[0],
                bucket_length(seq_len, self.min_seq_bucket, self.max_tokens))

    def _batch_plan(self, batch: int) -> List[int]:
        """Pow2 chunk sizes covering ``batch`` with minimal padding rows.

        Greedy binary decomposition (13 -> 8 + 4 + 1), with chunks below
        ``min_batch_bucket`` rounded up to it; when a single rounded-up
        launch pads no more rows than the decomposition, prefer the single
        launch (fewer per-batch fixed costs — the paper's Eq. 12 beta is
        per execution).
        """
        g = max(1, self.min_batch_bucket)
        greedy: List[int] = []
        rem = batch
        while rem > 0:
            c = max(1 << (rem.bit_length() - 1), g)   # largest pow2 <= rem
            greedy.append(c)
            rem -= min(c, rem)
        single = max(next_pow2(batch), g)
        return [single] if single <= sum(greedy) else greedy

    @property
    def warm_buckets(self) -> frozenset:
        """Buckets with a compiled executable (cache keys)."""
        return frozenset(self._buckets)

    def prewarm(self, buckets: Iterable[Tuple[int, int]]) -> int:
        """Eagerly compile the given (B_bucket, S_bucket) shapes so serving
        takes no compile stalls.  Returns how many were newly compiled."""
        jnp = self._jnp
        new = 0
        for bb, sb in buckets:
            key = (int(bb), int(sb))
            with self._bucket_lock:
                if key in self._buckets:
                    continue
            toks = jnp.zeros(key, jnp.int32)
            mask = jnp.ones(key, jnp.float32)
            self._embed(self.params, toks, mask).block_until_ready()
            # mark warm only AFTER the compile succeeds, so an interrupted
            # prewarm can be retried instead of silently no-op'ing
            with self._bucket_lock:
                self._buckets.add(key)
            new += 1
        return new

    @staticmethod
    def _qlen(q: Query) -> int:
        return len(q.payload) if q.payload is not None else q.length

    def _stage_chunk(self, chunk: Sequence[Query], bb: int, sb: int):
        """Tokenize one chunk into (bb, sb) device-ready inputs.

        Returns (tokens, mask, real_tokens, truncated).  The sharded backend
        overrides this with its staging-ring + mesh-sharded transfer; here
        fresh host arrays are handed straight to jit.  Padding rows beyond
        the chunk stay all-zero (dropped by pooling).
        """
        toks, mask, real, truncated = self._tokenize(
            chunk, sb, out=(np.zeros((bb, sb), np.int32),
                            np.zeros((bb, sb), np.float32)))
        return (self._jnp.asarray(toks), self._jnp.asarray(mask), real,
                truncated)

    def _enqueue_chunks(self, queries: Sequence[Query]
                        ) -> List[Tuple[int, object]]:
        """The single chunking/accounting path for every bucketed backend:
        decompose the batch (``_batch_plan``), bucket each chunk's own
        sequence length, stage (``_stage_chunk``), count, and enqueue the
        jit execution.  Returns [(chunk_len, device_result), ...] in query
        order; results are fetched by the caller (sync or deferred)."""
        handles: List[Tuple[int, object]] = []
        start = 0
        for bb in self._batch_plan(len(queries)):
            chunk = queries[start:start + bb]
            start += len(chunk)
            # pad only to this chunk's own bucket; truncation still happens
            # at the global max_tokens cap, exactly like the fixed backend
            longest = max(min(self._qlen(q), self.max_tokens) for q in chunk)
            sb = bucket_length(longest, self.min_seq_bucket, self.max_tokens)
            toks, mask, real, truncated = self._stage_chunk(chunk, bb, sb)
            self._record_truncations(truncated)
            with self._bucket_lock:
                if (bb, sb) in self._buckets:
                    self.bucket_hits += 1
                else:
                    self._buckets.add((bb, sb))
                self.real_tokens += real
                self.padded_tokens += bb * sb - real
            handles.append((len(chunk), self._embed(self.params, toks, mask)))
        return handles

    def embed_batch(self, queries: Sequence[Query]) -> List[np.ndarray]:
        out: List[np.ndarray] = []
        for n, dev in self._enqueue_chunks(queries):
            emb = np.asarray(dev)
            out.extend(emb[i] for i in range(n))
        return out
