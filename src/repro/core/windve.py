"""WindVE engine — the paper's full system (Fig. 3B), runnable for real.

Pipeline: device detector -> queue depth calibration (linear-regression
estimator) -> policy-driven N-tier queue manager (Algorithm 1 core in
``repro.core.routing``) -> per-tier worker threads draining their queue in
batches, each worker owning its own model instance (the paper: "each
instance employs its own model copy").

The engine is one of two *drivers* of the shared scheduling core (the other
is the DES in ``repro.core.simulator``): every query goes through the same
``QueueManager.dispatch`` + ``DispatchPolicy``, so thread and simulation
semantics cannot diverge.

Backends:
* ``JaxEmbedderBackend`` — actually runs the bge/jina-style JAX embedder on
  this host's CPU (the paper's CPU pool).
* ``ModeledBackend``     — wall-clock sleeps per the calibrated DeviceModel
  (stands in for the NPU/GPU pool on this accelerator-less container; on a
  real TPU deployment this is replaced by the pjit'd embedder).

Observability: ``add_batch_hook(fn)`` registers a first-class batch
completion hook ``fn(tier_name, batch, service_latency_s)`` — the online
calibrator (``repro.core.adaptive``) attaches through this instead of
monkey-patching ``embed_batch``.
"""
from __future__ import annotations

import threading
import time
import warnings
from concurrent.futures import Future
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import estimator
from repro.core.routing import (ADMISSION, BUSY, CPU, EXPIRED, NPU,
                                DeadlineExceeded, DispatchPolicy, Query,
                                QueueManager, RetryPolicy, ServeError,
                                TierSpec)
from repro.core.simulator import DeviceModel, sharded_model
from repro.core.telemetry import EngineStats, Telemetry

BatchHook = Callable[[str, Sequence[Query], float], None]


class Backend:
    """A device pool able to embed a batch of queries.

    ``telemetry`` (optional): a :class:`~repro.core.telemetry.Telemetry` the
    backend reports quality events (payload truncations) into.  ``WindVE``
    wires its shared stats object into any backend that left it None.
    """

    name = "backend"
    telemetry: Optional[Telemetry] = None
    # backends that can enqueue a batch and hand back a deferred fetch set
    # this True and implement ``embed_batch_async`` (see
    # ``repro.core.sharded_backend``); the engine worker then double-buffers.
    async_dispatch = False

    def embed_batch(self, queries: Sequence[Query]) -> List[np.ndarray]:
        raise NotImplementedError

    def embed_batch_async(self, queries: Sequence[Query]
                          ) -> Callable[[], List[np.ndarray]]:
        """Enqueue the batch; the returned thunk blocks for the results."""
        out = self.embed_batch(queries)
        return lambda: out


class ModeledBackend(Backend):
    """Wall-clock stand-in for the accelerator pool.

    ``devices=N`` models the tier as an N-device mesh: the same fan-out
    service curve the DES uses (``repro.core.simulator.FanOutModel`` —
    pow2 per-device chunks mirroring ``ShardedEmbedderBackend``'s
    mesh-floored buckets, chunk latency = the straggler device's, plus a
    ``fanout_beta_s * log2(N)`` scatter/gather term per execution).
    ``devices=1`` keeps the wrapped model untouched, exactly like a
    1-device mesh degrading to the single-device path.

    ``hosts=H`` (with ``interhost_beta_s``) marks the device group as
    spanning H machines: the fan-out curve gains the cross-host gather
    term (``interhost_beta_s * log2(H)``), so an engine replica carved
    across hosts prices its network fabric exactly like the DES does —
    depth calibration against this backend stays honest at cluster scale.
    """

    def __init__(self, model: DeviceModel, embed_dim: int = 1024, *,
                 devices: int = 1, fanout_beta_s: float = 0.0,
                 hosts: int = 1, interhost_beta_s: float = 0.0):
        self.model = sharded_model(model, devices, fanout_beta_s,
                                   hosts, interhost_beta_s)
        self.devices = max(1, devices)
        self.hosts = max(1, hosts)
        self.embed_dim = embed_dim
        self.name = self.model.name

    def embed_batch(self, queries: Sequence[Query]) -> List[np.ndarray]:
        # the batch is served as ONE padded execution, so its latency follows
        # the longest member — using queries[0] made the modeled tier blind
        # to length-aware batch formation
        dur = self.model.latency(len(queries),
                                 max(q.length for q in queries))
        time.sleep(dur)
        return [np.zeros(self.embed_dim, np.float32) for _ in queries]


class JaxEmbedderBackend(Backend):
    """Real JAX embedder running on the host CPU.

    Every batch is padded to the fixed ``max_tokens`` window, and every new
    *batch size* triggers a fresh jit trace (``traces`` counts them) — the
    baseline the shape-bucketed backend (``repro.core.bucketing``) beats.
    Payloads longer than ``max_tokens`` are truncated; truncations are
    counted locally and into ``telemetry`` when attached.

    ``dtype`` (optional) selects a serving precision policy realised ONCE
    at load by ``repro.models.quantize.serve_params``: ``"fp32"`` (fp32
    weights + fp32 trunk — the precision oracle), ``"bf16"`` (bf16-resident
    weights, bf16 trunk), ``"int8"`` (int8 weight-only quantized
    projections + fp32 scales, fp32 activations, routed through the fused
    quant matmul by ``models.layers.dense_apply``), or ``"int8_w8a8"``
    (same quantized tree plus dynamic per-row int8 activation quantization:
    every projection contracts int8 x int8 with int32 accumulation).  None
    keeps the legacy behaviour: raw params with the model's default compute
    dtype.
    """

    def __init__(self, cfg, params, max_tokens: int = 128,
                 telemetry: Optional[Telemetry] = None, *,
                 dtype: Optional[str] = None):
        import jax
        import jax.numpy as jnp

        from repro.models import embedder

        self.cfg = cfg
        self.dtype = dtype
        self.max_tokens = max_tokens
        self.telemetry = telemetry
        self.name = f"jax-cpu/{cfg.name}" + (f"/{dtype}" if dtype else "")
        self.traces = 0          # jit retraces (one per new padded shape)
        self.truncated = 0
        self.real_tokens = 0     # tokens the queries actually carried
        self.padded_tokens = 0   # tokens added by padding (wasted FLOPs)

        if dtype is None:
            self.params = params
            cdt = None           # model default (layers.COMPUTE_DTYPE)
            aq = False
        else:
            from repro.models.quantize import serve_params, wants_act_quant
            self.params, cdt = serve_params(params, dtype)
            aq = wants_act_quant(dtype)
        self.act_quant = aq

        def _fn(p, toks, mask):
            self.traces += 1          # python side effect: runs once per trace
            return embedder.embed(p, cfg, toks, mask, compute_dtype=cdt,
                                  act_quant=aq)

        self._embed = jax.jit(_fn)
        self._jnp = jnp

    @property
    def params_nbytes(self) -> int:
        """Resident serving-weight footprint (int8 serving: ~4x under fp32)."""
        import jax

        return sum(leaf.nbytes for leaf in jax.tree.leaves(self.params))

    def _tokenize(self, queries: Sequence[Query], seq_len: int, out=None):
        """Pad/truncate a batch into (tokens, mask) of width ``seq_len``.

        Returns (toks, mask, real_tokens, truncated).  Queries without a
        payload get the deterministic synthetic token stream, so modeled and
        real runs embed identical inputs.

        ``out``: optional reusable ``(toks, mask)`` staging arrays with at
        least ``len(queries)`` rows and exactly ``seq_len`` columns — the
        sharded backend keeps one pair per (B, S) bucket so steady-state
        serving stops allocating fresh host arrays per batch.  Padding rows
        beyond the batch are zeroed (all-zero mask == dropped by pooling).

        Vectorized: this runs inside the worker thread on EVERY batch, so
        the fill is two bulk numpy writes — the mask broadcast from a
        length vector, the token grid from one stacked payload flat-assign
        (synthetic rows share a single base pattern) — instead of a
        per-query row loop.
        """
        B = len(queries)
        if out is None:
            toks = np.zeros((B, seq_len), np.int32)
            mask = np.zeros((B, seq_len), np.float32)
        else:
            toks, mask = out
            toks[:] = 0
            mask[:] = 0.0
        if B == 0:
            return toks, mask, 0, 0
        lens = np.fromiter(
            (q.length if q.payload is None else len(q.payload)
             for q in queries), np.int64, count=B)
        n = np.minimum(lens, seq_len)
        truncated = int((lens > seq_len).sum())
        real = int(n.sum())
        valid = np.arange(seq_len)[None, :] < n[:, None]      # (B, seq_len)
        mask[:B] = valid
        synth = np.fromiter((q.payload is None for q in queries), bool,
                            count=B)
        tv = toks[:B]                   # basic-slice view: writes land in out
        if synth.any():
            # every synthetic stream is the same deterministic prefix
            base = ((np.arange(seq_len, dtype=np.int64)
                     % (self.cfg.vocab_size - 1)) + 1).astype(np.int32)
            sel = synth[:, None] & valid
            tv[sel] = np.broadcast_to(base, (B, seq_len))[sel]
        if not synth.all():
            # row-major boolean assignment consumes the concatenated
            # payloads in exactly batch order
            flat = np.concatenate(
                [np.asarray(q.payload[:seq_len]).ravel()
                 for q in queries if q.payload is not None])
            tv[~synth[:, None] & valid] = flat.astype(np.int32)
        return toks, mask, real, truncated

    def _record_truncations(self, n: int) -> None:
        if n:
            self.truncated += n
            if self.telemetry is not None:
                self.telemetry.record_truncations(n)

    @property
    def padded_waste(self) -> float:
        """Fraction of embedded tokens that were padding."""
        total = self.real_tokens + self.padded_tokens
        return self.padded_tokens / total if total else 0.0

    def embed_batch(self, queries: Sequence[Query]) -> List[np.ndarray]:
        jnp = self._jnp
        toks, mask, real, truncated = self._tokenize(queries, self.max_tokens)
        self._record_truncations(truncated)
        self.real_tokens += real
        self.padded_tokens += len(queries) * self.max_tokens - real
        out = np.asarray(self._embed(self.params, jnp.asarray(toks),
                                     jnp.asarray(mask)))
        return [out[i] for i in range(len(queries))]


class WindVE:
    """The serving engine: threaded driver of the shared scheduling core.

    New-style: ``WindVE(tiers=[TierSpec(name, depth, backend=...), ...],
    policy=...)`` for arbitrary topologies.  Legacy two-tier form
    ``WindVE(npu_backend, cpu_backend, npu_depth, cpu_depth, ...)`` still
    works and builds the paper's NPU/CPU cascade (including Algorithm 2's
    single-device fallback when only one backend exists).

    Fault tolerance: ``retry`` (a :class:`~repro.core.routing.RetryPolicy`)
    re-dispatches failed batches through the policy path with bounded
    attempts and exponential backoff; ``default_deadline_s`` arms every
    submit with a relative deadline (per-call ``submit(deadline_s=...)``
    overrides); a ``TierSpec.breaker`` makes dispatch route around a tier
    that keeps failing or stalling.  Terminal failures surface on client
    futures as structured :class:`~repro.core.routing.ServeError`.

    Overload control: ``admission`` (an
    :class:`~repro.core.admission.AdmissionController`) sheds predictably
    late arrivals with ``ServeError(kind="admission")`` futures before they
    occupy a queue slot; ``brownout`` (a
    :class:`~repro.core.health.BrownoutController`) degrades quality —
    quantized-tier preference, tightened deadlines — before anything is
    shed.  Both live in the shared ``QueueManager``, so the DES replays
    the identical decisions.
    """

    def __init__(self, npu_backend: Optional[Backend] = None,
                 cpu_backend: Optional[Backend] = None,
                 npu_depth: int = 0, cpu_depth: int = 0,
                 heter_enable: bool = True,
                 max_batch: Optional[Dict[str, int]] = None,
                 workers: Optional[Dict[str, int]] = None, *,
                 tiers: Optional[Sequence[TierSpec]] = None,
                 policy: Optional[DispatchPolicy] = None,
                 retry: Optional[RetryPolicy] = None,
                 default_deadline_s: Optional[float] = None,
                 admission: Any = None,
                 brownout: Any = None):
        if tiers is None:
            tiers = self._legacy_tiers(npu_backend, cpu_backend, npu_depth,
                                       cpu_depth, heter_enable,
                                       max_batch or {}, workers or {})
        tiers = list(tiers)
        if not tiers:
            raise ValueError("need at least one tier")
        # cache tiers (TierSpec.cache set) are zero-latency: no backend, no
        # queue, no worker thread — hits complete inside submit()
        device_tiers = [t for t in tiers if t.cache is None]
        for t in device_tiers:
            if t.backend is None:
                raise ValueError(f"tier {t.name!r} has no backend")
        # keep_queries=False: a long-running engine must not pin every
        # Query (and its payload) forever; all metrics read `latencies`
        self.qm = QueueManager(tiers, policy=policy,
                               stats=Telemetry(keep_queries=False),
                               admission=admission, brownout=brownout)
        self.stats: EngineStats = self.qm.stats   # one shared Telemetry
        self.backends: Dict[str, Backend] = {t.name: t.backend
                                             for t in device_tiers}
        for be in self.backends.values():
            # backends report quality events (truncations) into the engine's
            # shared telemetry unless the caller wired their own
            if getattr(be, "telemetry", False) is None:
                be.telemetry = self.stats
        self._batch_hooks: List[BatchHook] = []
        self._futures: Dict[int, Future] = {}
        self._qid = 0
        self._lock = threading.Lock()
        self._stop = threading.Event()
        # fault tolerance: 0 retries keeps the legacy single-attempt
        # semantics (one backend failure is terminal for its batch), but
        # failures now surface as structured ServeError, never raw
        # backend tracebacks
        self.retry = retry if retry is not None else RetryPolicy(max_retries=0)
        self.default_deadline_s = default_deadline_s
        # queued queries the deadline sweep expires get their future failed
        self.qm.on_expire = self._expire_query
        self._wake: Dict[str, threading.Event] = {
            t.name: threading.Event() for t in device_tiers}
        # Algorithm 2's worker counts: N instances may drain one tier's
        # queue (each instance owns its own model copy on real hardware).
        # Live counts detect tier death: when a tier's LAST worker dies of
        # a crash, its queued queries must be drained and failed over, not
        # stranded behind a queue nobody will ever pop again.
        self._live_workers: Dict[str, int] = {
            t.name: max(1, t.workers) for t in device_tiers}
        self._thread_tiers: List[str] = [
            t.name for t in device_tiers for _ in range(max(1, t.workers))]
        self._threads = [
            threading.Thread(target=self._worker, args=(name,), daemon=True)
            for name in self._thread_tiers]
        for t in self._threads:
            t.start()

    @staticmethod
    def _legacy_tiers(npu_backend, cpu_backend, npu_depth, cpu_depth,
                      heter_enable, max_batch, workers) -> List[TierSpec]:
        if npu_backend is None and cpu_backend is None:
            raise ValueError("need at least one backend")
        # single-device fallback: Algorithm 2 forces heter off and the sole
        # device becomes the main queue
        if npu_backend is None:
            npu_backend, cpu_backend = cpu_backend, None
            npu_depth, cpu_depth = cpu_depth or npu_depth, 0
            heter_enable = False
        tiers = [TierSpec(NPU, npu_depth, backend=npu_backend,
                          max_batch=max_batch.get(NPU),
                          workers=max(1, workers.get(NPU, 1)))]
        if cpu_backend is not None and heter_enable and cpu_depth > 0:
            tiers.append(TierSpec(CPU, cpu_depth, backend=cpu_backend,
                                  max_batch=max_batch.get(CPU),
                                  workers=max(1, workers.get(CPU, 1))))
        return tiers

    # ------------------------------------------------------------------
    def submit(self, payload=None, length: int = 75,
               deadline_s: Optional[float] = None) -> Optional[Future]:
        """Dispatch one query via the policy core.  None == BUSY (rejected).

        ``deadline_s`` (relative; falls back to the engine's
        ``default_deadline_s``) arms an absolute deadline on the monotonic
        clock: if the query is still *queued* when it passes, the sweep
        expires it and its future fails with :class:`DeadlineExceeded`
        (in-flight work completes late as an SLO violation instead — a
        batch on a device cannot be recalled).  A query already dead at
        dispatch never enters a queue: its future comes back with the
        exception pre-set.

        The future is registered BEFORE dispatch: a worker may complete the
        query before this thread returns from ``dispatch``, and must find
        the future to resolve.  On BUSY the registration is rolled back.
        """
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        with self._lock:
            self._qid += 1
            now = time.monotonic()
            q = Query(qid=self._qid, payload=payload, length=length,
                      arrival_t=now,
                      deadline=None if deadline_s is None
                      else now + deadline_s)
        fut: Future = Future()
        self._futures[q.qid] = fut
        verdict = self.qm.dispatch(q)
        if verdict == BUSY:
            self._futures.pop(q.qid, None)
            return None
        if verdict == EXPIRED:
            self._fail(q, DeadlineExceeded(qid=q.qid, attempts=q.attempts))
            return fut
        if verdict == ADMISSION:
            # admission shed at arrival is a REJECTION (rejections_admission
            # counts it), not a terminal serving failure — the future
            # carries the structured error but `failed` stays untouched,
            # mirroring how BUSY rejections never count as failed
            self._futures.pop(q.qid, None)
            fut.set_exception(ServeError("admission", qid=q.qid))
            return fut
        if self.qm.is_cache_tier(verdict):
            # zero-latency tier: the hit already filled q.emb at dispatch —
            # complete here, no queue slot, no worker, no batch
            q.done_t = time.monotonic()
            self.stats.record_completion(q, verdict)
            self._futures.pop(q.qid, None)
            fut.set_result(q.emb)
            return fut
        self._wake[verdict].set()
        return fut

    def add_batch_hook(self, hook: BatchHook) -> BatchHook:
        """Register ``hook(tier_name, batch, service_latency_s)``, called by
        the worker after every completed batch (calibration, metrics, ...)."""
        self._batch_hooks.append(hook)
        return hook

    def remove_batch_hook(self, hook: BatchHook) -> None:
        if hook in self._batch_hooks:
            self._batch_hooks.remove(hook)

    # -- fault tolerance ------------------------------------------------
    def _fail(self, q: Query, exc: ServeError) -> None:
        """Terminally fail one query: its future carries a structured
        ``ServeError`` (never a raw backend traceback) and the failure is
        counted.  No-op if the future already resolved."""
        fut = self._futures.pop(q.qid, None)
        if fut is None:
            return
        self.stats.record_failed()
        fut.set_exception(exc)

    def _expire_query(self, q: Query) -> None:
        """``QueueManager.on_expire`` hook: a queued query the deadline
        sweep removed — fail its future with the tier it was waiting on."""
        self._fail(q, DeadlineExceeded(tier=q.device, qid=q.qid,
                                       attempts=q.attempts))

    def _retry_or_fail(self, batch: Sequence[Query], tier_name: str,
                       cause: BaseException, now: float,
                       kind: str = "backend_error") -> None:
        """A batch failed on ``tier_name``: re-dispatch every query through
        the normal policy path (so survivors land on whatever healthy tier
        the policy picks — including this one, once its slots freed) with
        bounded attempts, or fail its future with a structured ServeError.

        The exponential backoff is slept HERE, in the failed tier's worker
        — the tier that just failed is the one that waits, healthy tiers
        keep draining — and is computed per batch from its first retryable
        query's attempt count (batch members share a history in the common
        case; the DES prices the identical delay).
        """
        retryable: List[Query] = []
        for q in batch:
            q.attempts += 1
            if q.attempts > self.retry.max_retries:
                self._fail(q, ServeError(kind, tier=tier_name, qid=q.qid,
                                         attempts=q.attempts, cause=cause))
            else:
                retryable.append(q)
        if not retryable:
            return
        pause = self.retry.backoff(retryable[0].attempts)
        if pause > 0:
            time.sleep(pause)
        for q in retryable:
            now = time.monotonic()
            if q.expired(now):
                # dispatch would refuse it anyway; fail with the tier it
                # burned its last attempt on rather than the ARRIVAL pseudo
                # tier so the miss is attributable
                self.qm.stats.record_deadline_miss(tier_name)
                self._fail(q, DeadlineExceeded(tier=tier_name, qid=q.qid,
                                               attempts=q.attempts))
                continue
            self.stats.record_retry(tier_name)
            verdict = self.qm.dispatch(q, now=now)
            if verdict == BUSY:
                self._fail(q, ServeError("no_capacity", tier=tier_name,
                                         qid=q.qid, attempts=q.attempts,
                                         cause=cause))
            elif verdict == ADMISSION:
                # on a retry re-dispatch the shed IS terminal: the query
                # already burned device time, so it ends as failed
                self._fail(q, ServeError("admission", tier=tier_name,
                                         qid=q.qid, attempts=q.attempts,
                                         cause=cause))
            elif verdict == EXPIRED:
                self._fail(q, DeadlineExceeded(qid=q.qid,
                                               attempts=q.attempts))
            elif self.qm.is_cache_tier(verdict):
                q.done_t = time.monotonic()
                self.stats.record_completion(q, verdict)
                fut = self._futures.pop(q.qid, None)
                if fut is not None:
                    fut.set_result(q.emb)
            else:
                self._wake[verdict].set()

    def _worker_died(self, tier_name: str, crash: BaseException) -> None:
        """The tier's LAST worker crashed: quarantine the tier (depth 0 —
        dispatch and retry can no longer land work on it) and drain its
        queue, failing over every stranded query so no client future hangs
        on a queue nobody will ever pop again."""
        warnings.warn(f"windve: tier {tier_name!r} lost its last worker "
                      f"({crash!r}); draining its queue", RuntimeWarning)
        self.qm.set_depth(tier_name, 0)
        queue = self.qm.queues[tier_name]
        while True:
            # raw queue drain (no bucket_fn: buckets don't matter to a
            # dead tier) — pop_batch marks in-flight, finish releases
            stranded = queue.pop_batch(1 << 30)
            if not stranded:
                return
            queue.finish(len(stranded))
            self._retry_or_fail(stranded, tier_name, crash,
                                time.monotonic(), kind="worker_death")

    def _worker(self, tier_name: str) -> None:
        backend = self.backends[tier_name]
        queue = self.qm.queues[tier_name]
        use_async = bool(getattr(backend, "async_dispatch", False)) and \
            callable(getattr(backend, "embed_batch_async", None))
        # double buffering (async backends): the previous batch's fetch is
        # deferred until the NEXT batch is enqueued, so device->host copy of
        # batch N-1 overlaps batch N's compute and the worker never idles on
        # ``device_get``.
        pending = None   # (batch, fetch_thunk, t0)

        def resolve(entry) -> None:
            batch, fetch, t0 = entry
            try:
                embs = fetch()
                err: Optional[BaseException] = None
            except BaseException as e:
                # BaseException on purpose: even a worker-killing crash
                # (SystemExit and friends) must not strand this batch's
                # futures — account for it, THEN let it propagate
                embs, err = None, e
            service = time.monotonic() - t0
            now = time.monotonic()
            queue.finish(len(batch))   # slots free before any re-dispatch
            if err is not None:
                self.qm.tier_failure(tier_name, now)
                self._retry_or_fail(batch, tier_name, err, now)
                if not isinstance(err, Exception):
                    raise err           # genuine worker death (accounted)
                return
            self.qm.tier_success(tier_name, service, now)
            self.stats.record_batch(tier_name, service)
            admit = bool(self.qm.cache_tiers)
            for q, emb in zip(batch, embs):
                q.done_t = now
                self.stats.record_completion(q, tier_name)
                if admit:
                    # admission hook: insert BEFORE the future resolves, so
                    # a client that saw this result re-submitting the same
                    # tokens is guaranteed the cache hit
                    self.qm.admit(q, emb)
                fut = self._futures.pop(q.qid, None)
                if fut is not None:
                    fut.set_result(emb)
            for hook in list(self._batch_hooks):
                try:
                    hook(tier_name, batch, service)
                except Exception:      # hooks must not kill the worker
                    self.stats.record_hook_error()

        crash: Optional[BaseException] = None
        try:
            while not self._stop.is_set():
                # live values: online re-calibration may resize the depth;
                # qm.pop_batch honours the tier's bucket_fn (length-aware
                # batches) and sweeps deadline-dead work out first
                batch = self.qm.pop_batch(tier_name, now=time.monotonic())
                if not batch:
                    if pending is not None:  # drain: nothing left to overlap
                        entry, pending = pending, None
                        resolve(entry)
                        continue
                    self._wake[tier_name].wait(timeout=0.01)
                    self._wake[tier_name].clear()
                    continue
                t0 = time.monotonic()
                if use_async:
                    try:
                        fetch = backend.embed_batch_async(batch)
                    except Exception as e:
                        def fetch(err=e):
                            raise err
                    prev, pending = pending, (batch, fetch, t0)
                    if prev is not None:
                        resolve(prev)
                else:
                    resolve((batch,
                             (lambda b=batch: backend.embed_batch(b)), t0))
            if pending is not None:  # pragma: no cover - shutdown mid-flight
                entry, pending = pending, None
                resolve(entry)
        except BaseException as e:   # worker death, not a batch failure
            crash = e
            if pending is not None:
                # a double-buffered batch this worker still owned: account
                # it (resolve never saw it, so no double-finish risk)
                b, pending = pending[0], None
                queue.finish(len(b))
                self._retry_or_fail(b, tier_name, e, time.monotonic(),
                                    kind="worker_death")
        finally:
            with self._lock:
                self._live_workers[tier_name] -= 1
                last = self._live_workers[tier_name] == 0
            if crash is not None and last and not self._stop.is_set():
                self._worker_died(tier_name, crash)

    def shutdown(self) -> None:
        """Stop the workers.  Threads that fail to join within the timeout
        are *leaked* (a worker wedged in a backend call): each is warned
        about with its tier name and ``Telemetry.summary()`` reports
        ``clean_shutdown`` 0.0 instead of silently returning."""
        self._stop.set()
        for e in self._wake.values():
            e.set()
        leaked: List[str] = []
        for t, tier in zip(self._threads, self._thread_tiers):
            t.join(timeout=2.0)
            if t.is_alive():
                leaked.append(tier)
        self.stats.clean_shutdown = not leaked
        for tier in sorted(set(leaked)):
            warnings.warn(f"windve: shutdown leaked a worker thread on tier "
                          f"{tier!r} (join timed out)", RuntimeWarning)

    @property
    def max_concurrency(self) -> int:
        return self.qm.max_concurrency


def calibrate_depths(profile_npu: Callable[[int], float],
                     profile_cpu: Optional[Callable[[int], float]],
                     slo_s: float,
                     probe_points: Sequence[int] = (1, 2, 4, 8, 16),
                     ) -> Dict[str, int]:
    """Paper §4.2.2 end-to-end: estimate both queue depths from a few
    profiling points via the linear-regression estimator."""
    d_npu, _ = estimator.estimate_depth(profile_npu, slo_s, probe_points)
    d_cpu = 0
    if profile_cpu is not None:
        d_cpu, _ = estimator.estimate_depth(profile_cpu, slo_s, probe_points)
    return {NPU: max(d_npu, 0), CPU: max(d_cpu, 0)}
