"""WindVE engine — the paper's full system (Fig. 3B), runnable for real.

Pipeline: device detector -> queue depth calibration (linear-regression
estimator) -> bounded two-tier queue manager (Algorithm 1) -> per-device
worker threads draining their queue in batches, each worker owning its own
model instance (the paper: "each instance employs its own model copy").

Backends:
* ``JaxEmbedderBackend`` — actually runs the bge/jina-style JAX embedder on
  this host's CPU (the paper's CPU pool).
* ``ModeledBackend``     — wall-clock sleeps per the calibrated DeviceModel
  (stands in for the NPU/GPU pool on this accelerator-less container; on a
  real TPU deployment this is replaced by the pjit'd embedder).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import estimator
from repro.core.device_detector import DetectionResult
from repro.core.queue_manager import BUSY, CPU, NPU, Query, QueueManager
from repro.core.simulator import DeviceModel


class Backend:
    """A device pool able to embed a batch of queries."""

    name = "backend"

    def embed_batch(self, queries: Sequence[Query]) -> List[np.ndarray]:
        raise NotImplementedError


class ModeledBackend(Backend):
    def __init__(self, model: DeviceModel, embed_dim: int = 1024):
        self.model = model
        self.embed_dim = embed_dim
        self.name = model.name

    def embed_batch(self, queries: Sequence[Query]) -> List[np.ndarray]:
        dur = self.model.latency(len(queries), queries[0].length)
        time.sleep(dur)
        return [np.zeros(self.embed_dim, np.float32) for _ in queries]


class JaxEmbedderBackend(Backend):
    """Real JAX embedder running on the host CPU."""

    def __init__(self, cfg, params, max_tokens: int = 128):
        import jax
        import jax.numpy as jnp

        from repro.models import embedder

        self.cfg = cfg
        self.params = params
        self.max_tokens = max_tokens
        self.name = f"jax-cpu/{cfg.name}"
        self._embed = jax.jit(
            lambda p, toks, mask: embedder.embed(p, cfg, toks, mask))
        self._jnp = jnp

    def embed_batch(self, queries: Sequence[Query]) -> List[np.ndarray]:
        jnp = self._jnp
        B = len(queries)
        toks = np.zeros((B, self.max_tokens), np.int32)
        mask = np.zeros((B, self.max_tokens), np.float32)
        for i, q in enumerate(queries):
            ids = q.payload
            if ids is None:
                ids = (np.arange(q.length) % (self.cfg.vocab_size - 1)) + 1
            n = min(len(ids), self.max_tokens)
            toks[i, :n] = np.asarray(ids[:n], np.int32)
            mask[i, :n] = 1.0
        out = np.asarray(self._embed(self.params, jnp.asarray(toks),
                                     jnp.asarray(mask)))
        return [out[i] for i in range(B)]


@dataclass
class EngineStats:
    accepted: int = 0
    rejected: int = 0
    completed: int = 0
    latencies: List[float] = field(default_factory=list)
    per_device: Dict[str, int] = field(default_factory=dict)

    def p(self, q: float) -> float:
        return float(np.percentile(self.latencies, q)) if self.latencies else 0.0


class WindVE:
    """The serving engine.  ``depths`` maps device -> C^max."""

    def __init__(self, npu_backend: Optional[Backend],
                 cpu_backend: Optional[Backend],
                 npu_depth: int, cpu_depth: int,
                 heter_enable: bool = True,
                 max_batch: Optional[Dict[str, int]] = None,
                 workers: Optional[Dict[str, int]] = None):
        if npu_backend is None and cpu_backend is None:
            raise ValueError("need at least one backend")
        # single-device fallback: Algorithm 2 forces heter off and the sole
        # device becomes the main queue
        if npu_backend is None:
            npu_backend, cpu_backend = cpu_backend, None
            npu_depth, cpu_depth = cpu_depth or npu_depth, 0
            heter_enable = False
        self.backends: Dict[str, Backend] = {NPU: npu_backend}
        if cpu_backend is not None and heter_enable:
            self.backends[CPU] = cpu_backend
        self.qm = QueueManager(npu_depth, cpu_depth if CPU in self.backends else 0,
                               heter_enable=CPU in self.backends)
        self.max_batch = max_batch or {}
        self.stats = EngineStats()
        self._futures: Dict[int, Future] = {}
        self._qid = 0
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._wake: Dict[str, threading.Event] = {
            d: threading.Event() for d in self.backends}
        # Algorithm 2's worker counts: N instances may drain one device
        # queue (each instance owns its own model copy on real hardware)
        workers = workers or {}
        self._threads = [
            threading.Thread(target=self._worker, args=(d,), daemon=True)
            for d in self.backends
            for _ in range(max(1, workers.get(d, 1)))]
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------------
    def submit(self, payload=None, length: int = 75) -> Optional[Future]:
        """Dispatch one query per Algorithm 1.  None == BUSY (rejected)."""
        with self._lock:
            self._qid += 1
            q = Query(qid=self._qid, payload=payload, length=length,
                      arrival_t=time.monotonic())
        verdict = self.qm.dispatch(q)
        if verdict == BUSY:
            self.stats.rejected += 1
            return None
        self.stats.accepted += 1
        fut: Future = Future()
        self._futures[q.qid] = fut
        self._wake[verdict].set()
        return fut

    def _worker(self, device: str) -> None:
        backend = self.backends[device]
        queue = self.qm.queues[device]
        max_b = self.max_batch.get(device, queue.depth)
        while not self._stop.is_set():
            batch = queue.pop_batch(max_b)
            if not batch:
                self._wake[device].wait(timeout=0.01)
                self._wake[device].clear()
                continue
            try:
                embs = backend.embed_batch(batch)
            except Exception as e:  # pragma: no cover
                embs = [e] * len(batch)
            now = time.monotonic()
            for q, emb in zip(batch, embs):
                q.done_t = now
                self.stats.completed += 1
                self.stats.latencies.append(now - q.arrival_t)
                self.stats.per_device[device] = \
                    self.stats.per_device.get(device, 0) + 1
                fut = self._futures.pop(q.qid, None)
                if fut is not None:
                    if isinstance(emb, Exception):
                        fut.set_exception(emb)
                    else:
                        fut.set_result(emb)
            queue.finish(len(batch))

    def shutdown(self) -> None:
        self._stop.set()
        for e in self._wake.values():
            e.set()
        for t in self._threads:
            t.join(timeout=2.0)

    @property
    def max_concurrency(self) -> int:
        return self.qm.max_concurrency


def calibrate_depths(profile_npu: Callable[[int], float],
                     profile_cpu: Optional[Callable[[int], float]],
                     slo_s: float,
                     probe_points: Sequence[int] = (1, 2, 4, 8, 16),
                     ) -> Dict[str, int]:
    """Paper §4.2.2 end-to-end: estimate both queue depths from a few
    profiling points via the linear-regression estimator."""
    d_npu, _ = estimator.estimate_depth(profile_npu, slo_s, probe_points)
    d_cpu = 0
    if profile_cpu is not None:
        d_cpu, _ = estimator.estimate_depth(profile_cpu, slo_s, probe_points)
    return {NPU: max(d_npu, 0), CPU: max(d_cpu, 0)}
