"""CPU affinity / NUMA planner — paper §4.4.

Empirical rules from the paper (ARM Kunpeng 920 observations):
1. bind worker processes to explicit cores (avoid core-switch cost);
2. prefer cores with LARGE indices (the service framework and OS occupy the
   small-index cores by default);
3. never cross NUMA boundaries within one worker (remote-NUMA memory access
   is slower);
4. in a 128-core 4-NUMA box, at most the last 3 NUMAs (96 cores) are usable
   because the main program owns the first NUMA (paper §5.4).

``plan_affinity`` is a pure function (testable on this 1-core container);
``apply_affinity`` optionally calls sched_setaffinity.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence


@dataclass(frozen=True)
class NumaTopology:
    total_cores: int
    numa_nodes: int

    @property
    def cores_per_numa(self) -> int:
        return self.total_cores // self.numa_nodes

    def numa_of(self, core: int) -> int:
        return core // self.cores_per_numa


def plan_affinity(topo: NumaTopology, cores_needed: int,
                  reserve_first_numa: bool = True) -> List[int]:
    """Pick cores for one CPU embedding worker per §4.4: reverse index
    order, no NUMA crossing unless unavoidable, first NUMA reserved for the
    service framework."""
    if cores_needed <= 0:
        raise ValueError("cores_needed must be positive")
    cpn = topo.cores_per_numa
    first_allowed = cpn if (reserve_first_numa and topo.numa_nodes > 1) else 0
    avail = list(range(topo.total_cores - 1, first_allowed - 1, -1))
    if cores_needed > len(avail):
        raise ValueError(
            f"need {cores_needed} cores, only {len(avail)} usable "
            f"({topo.total_cores} total, first NUMA reserved)")

    # greedy: fill whole NUMAs from the top; avoid splitting a worker across
    # NUMA boundaries when a single NUMA can hold it
    if cores_needed <= cpn:
        for start_numa in range(topo.numa_nodes - 1,
                                first_allowed // cpn - 1, -1):
            hi = (start_numa + 1) * cpn - 1
            lo = start_numa * cpn
            cores = list(range(hi, hi - cores_needed, -1))
            if all(c >= lo for c in cores):
                return cores
    return avail[:cores_needed]


def numa_crossings(topo: NumaTopology, cores: Sequence[int]) -> int:
    """How many NUMA boundaries a core set spans minus one (0 == no cross)."""
    return len({topo.numa_of(c) for c in cores}) - 1


def apply_affinity(cores: Sequence[int]) -> bool:
    """Best-effort sched_setaffinity; returns False when unsupported."""
    try:
        import os

        os.sched_setaffinity(0, set(cores))
        return True
    except (AttributeError, OSError, ValueError):
        return False
