"""SLO-aware admission control: price a rejection against a predicted miss.

The paper's deployment-cost formula (Eq. 12) makes *accepted concurrency
per node* the quantity that cuts cost — which makes overload the worst
regime the system has: a query that queues past its deadline consumes a
queue slot, a batch slot, and device seconds, and still returns an error.
``AdmissionController`` closes that hole at the only cheap place to close
it: arrival.  ``QueueManager.dispatch`` consults it after the cache tier
(hits are free and always served) and before policy dispatch, and a query
that is predictably late is rejected with a structured
``ServeError(kind="admission")`` instead of being enqueued to die.

Two mechanisms, both deterministic and stateless per decision:

* **Backpressure watermarks** — a tier only *accepts new* work while its
  backlog (queued + in-flight, the paper's ``C``) is under
  ``watermark x depth`` slots; under brownout shedding the watermark
  tightens by ``shed_scale``.  A flash crowd therefore cannot grow queues
  to the hard depth bound: the band between watermark and depth stays
  reserved for retry/failover traffic, and when every tier is over its
  watermark (but slots remain) the arrival is rejected as ``admission``
  rather than queued into a guaranteed deadline miss.  Only when every
  tier is *hard* full does dispatch fall through to the classic
  ``no_capacity`` BUSY verdict.
* **SLO-violation pricing** — with the calibrated Eq. 12 fits
  (``estimator.LatencyFit``, the same objects ``PredictivePolicy`` ranks
  with), the controller predicts the completion latency of joining the
  best passing tier, ``fit.latency(backlog + 1)``.  If even the best tier
  predicts past the query's budget (``min(slo_s, deadline - now)``), then
  serving it has expected cost ``violation_cost`` and rejecting costs
  ``reject_cost``; the query is rejected when rejection is the cheaper
  outcome (``reject_cost < violation_cost``), and unconditionally under
  brownout *shedding*.  Tiers without a fit are optimistic: no prediction,
  no pricing rejection — calibration earns the right to reject.

Determinism contract: no wall clock, no RNG; everything is a pure function
of the queue state both drivers already agree on, so the engine-vs-DES
parity suites extend to admission counters counter-for-counter.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, Optional, Sequence, Set

from repro.core.health import SHEDDING

__all__ = ["AdmissionController"]


class AdmissionController:
    """Arrival-time admit/reject oracle for ``QueueManager.dispatch``.

    ``decide`` returns ``None`` to reject the query (``admission``
    verdict), or the set of tier names the query may be enqueued on.  An
    empty set means every tier is hard-full: dispatch falls through to its
    normal push loop and reports BUSY (``no_capacity``), keeping the two
    rejection reasons distinct in telemetry.
    """

    def __init__(self, fits: Optional[Dict[str, object]] = None,
                 slo_s: float = 1.0, reject_cost: float = 0.5,
                 violation_cost: float = 1.0, watermark: float = 1.0,
                 shed_scale: float = 0.5):
        if slo_s <= 0:
            raise ValueError("slo_s must be positive")
        if reject_cost < 0 or violation_cost <= 0:
            raise ValueError("costs must be nonnegative (violation positive)")
        if not 0.0 < watermark <= 1.0:
            raise ValueError("watermark must be in (0, 1]")
        if not 0.0 < shed_scale <= 1.0:
            raise ValueError("shed_scale must be in (0, 1]")
        self.fits: Dict[str, object] = dict(fits or {})
        self.slo_s = slo_s
        self.reject_cost = reject_cost
        self.violation_cost = violation_cost
        self.watermark = watermark
        self.shed_scale = shed_scale
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def update_fit(self, tier: str, fit) -> None:
        """Install/replace a tier's calibrated fit (online recalibration)."""
        with self._lock:
            self.fits[tier] = fit

    def watermark_slots(self, depth: int, stage: str = "normal") -> int:
        """Accepting-new-work slot bound for a tier of ``depth``: floor of
        the (stage-scaled) watermark fraction, at least 1 for any usable
        tier, never above the hard depth."""
        w = self.watermark * (self.shed_scale if stage == SHEDDING else 1.0)
        return min(int(depth), max(1, int(math.floor(depth * w + 1e-9))))

    def decide(self, query, tiers: Sequence, qm, now: float,
               stage: str = "normal") -> Optional[Set[str]]:
        """Admit/reject ``query`` against the live queue state.

        Returns ``None`` (reject as ``admission``) or the set of passing
        tier names (possibly empty — see class docstring).
        """
        from repro.core.routing import dispatchable  # cycle-free at call time

        passing = []
        hard_free = False
        for t in dispatchable(tiers):
            q = qm.queues.get(t.name)
            if q is None:
                continue
            backlog = len(q)
            if backlog < q.depth:
                hard_free = True
            if backlog < self.watermark_slots(q.depth, stage):
                passing.append((t.name, backlog))
        if not passing:
            # over every watermark: reject (backpressure) while hard slots
            # remain; once nothing is even hard-free, let dispatch report
            # the classic no_capacity BUSY instead
            return None if hard_free else set()

        budget = self.slo_s
        if query is not None and getattr(query, "deadline", None) is not None:
            budget = min(budget, float(query.deadline) - float(now))
        with self._lock:
            best: Optional[float] = None
            unknown = False
            for name, backlog in passing:
                fit = self.fits.get(name)
                if fit is None:
                    unknown = True
                    break
                pred = float(fit.latency(backlog + 1))
                best = pred if best is None else min(best, pred)
            reject_cheaper = self.reject_cost < self.violation_cost
        if not unknown and best is not None and best > budget + 1e-12:
            # predictably late everywhere it could go: serving costs an
            # expected SLO violation, rejecting costs reject_cost
            if stage == SHEDDING or reject_cheaper:
                return None
        return {name for name, _ in passing}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"AdmissionController(slo_s={self.slo_s}, "
                f"reject_cost={self.reject_cost}, "
                f"watermark={self.watermark}, fits={sorted(self.fits)})")
