"""Discrete-event serving simulator with paper-calibrated device models.

This CPU-only container has no NPU/GPU, so the paper's hardware is modeled:
each device's processing latency under concurrency C follows the paper's
Eq. 12 shape with a small convex term,

    t_d(C) = beta_d + b_d * C + a_d * C^2 ,

where (b_d, a_d) are solved EXACTLY from the paper's two stress-test anchors
(C@1s, C@2s from Tables 1-3) and beta_d from Fig. 4.  The mild convexity is
what the paper itself observed: its linear-regression estimator slightly
undershoots the fine-tuned depth (Table 3, V100: regression 40 vs fine-tuned
44) — this simulator reproduces that emergently.

The DES engine is the second *driver* of the shared scheduling core
(``repro.core.routing``): it feeds arrival traces through the SAME
``QueueManager.dispatch`` + ``DispatchPolicy`` the threaded engine uses and
measures e2e latency / SLO violations / busy rate, so the no-offload vs
CPU-offload comparison (Tables 1-2) runs end to end with dispatch semantics
that cannot diverge from the real engine's.
"""
from __future__ import annotations

import heapq
import math
import random
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.routing import (ADMISSION, BUSY, CPU, EXPIRED, NPU,
                                DispatchPolicy, Query, QueueManager,
                                RetryPolicy, TierSpec)
from repro.core.telemetry import SimResult, Telemetry


# ---------------------------------------------------------------------------
# calibrated device latency models
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DeviceModel:
    name: str
    beta: float                  # fixed cost (Fig. 4 intercepts)
    b: float                     # linear term
    a: float                     # convex term (anchor-solved)
    noise_std: float = 0.0       # relative noise (Atlas/Kunpeng outliers §5.3)
    # query-length scaling (paper §5.4: latency grows with input length;
    # default length 75 tokens is the paper's RAG segmentation setting)
    ref_length: int = 75

    def latency(self, concurrency: float, length: int = 75,
                rng: Optional[random.Random] = None) -> float:
        c = max(0.0, float(concurrency))
        t = self.beta + self.b * c + self.a * c * c
        # linear-in-length scaling of the compute part (embedding FLOPs are
        # ~linear in tokens for fixed batch)
        t = self.beta + (t - self.beta) * (length / self.ref_length)
        if self.noise_std and rng is not None:
            t *= max(0.1, 1.0 + rng.gauss(0.0, self.noise_std))
        return t


def solve_anchors(beta: float, c1: float, t1: float, c2: float, t2: float
                  ) -> Tuple[float, float]:
    """Solve (b, a) so beta + b*c + a*c^2 passes exactly through both
    stress-test anchors (c1, t1), (c2, t2)."""
    d1, d2 = t1 - beta, t2 - beta
    det = c1 * c2 * c2 - c2 * c1 * c1
    a = (c1 * d2 - c2 * d1) / det
    b = (d1 - a * c1 * c1) / c1
    return b, a


def _mk(name: str, beta: float, c1: float, t1: float, c2: float, t2: float,
        noise: float = 0.0) -> DeviceModel:
    b, a = solve_anchors(beta, c1, t1, c2, t2)
    if a < 0.0:
        # anchors imply concavity for the given beta: fall back to the pure
        # linear Eq. 12 through both anchors (beta refit, a = 0)
        b = (t2 - t1) / (c2 - c1)
        beta = t1 - b * c1
        a = 0.0
    return DeviceModel(name, beta, b, a, noise)


# Anchors: Tables 1-3 (bge) and Table 2 (jina); betas: Fig. 4.
PAPER_DEVICES: Dict[str, DeviceModel] = {
    # bge-large-zh-v1.5 calibration
    "tesla-v100/bge": _mk("tesla-v100/bge", 0.27, 44, 1.0, 96, 2.0),
    "xeon-e5-2690/bge": _mk("xeon-e5-2690/bge", 0.32, 8, 1.0, 22, 2.0),
    "atlas-300i-duo/bge": _mk("atlas-300i-duo/bge", 0.24, 84, 1.0, 172, 2.0,
                              noise=0.03),
    "kunpeng-920/bge": _mk("kunpeng-920/bge", 0.85, 2, 1.0, 8, 2.0,
                           noise=0.05),
    # jina calibration
    "tesla-v100/jina": _mk("tesla-v100/jina", 0.25, 48, 1.0, 112, 2.0),
    "xeon-e5-2690/jina": _mk("xeon-e5-2690/jina", 0.30, 11, 1.0, 30, 2.0),
    "atlas-300i-duo/jina": _mk("atlas-300i-duo/jina", 0.22, 128, 1.0, 256, 2.0,
                               noise=0.03),
    "kunpeng-920/jina": _mk("kunpeng-920/jina", 0.80, 6, 1.0, 20, 2.0,
                            noise=0.05),
}


def _pow2_chunks(batch: int, floor: int) -> List[int]:
    """Pow2 chunk sizes covering ``batch`` with every chunk >= ``floor``.

    Mirrors ``BucketedEmbedderBackend._batch_plan`` (greedy binary
    decomposition, single rounded-up launch preferred when it pads no more
    rows) so the DES models the same executions the real sharded backend
    performs.  Duplicated rather than imported: ``bucketing`` sits above the
    engine layer and importing it here would cycle."""
    g = max(1, floor)
    greedy: List[int] = []
    rem = int(batch)
    while rem > 0:
        c = max(1 << (rem.bit_length() - 1), g)   # largest pow2 <= rem
        greedy.append(c)
        rem -= min(c, rem)
    single = g if batch <= g else 1 << (int(batch) - 1).bit_length()
    return [single] if single <= sum(greedy) else greedy


@dataclass(frozen=True)
class FanOutModel:
    """Sharded accelerator tier: one batch fans out over ``devices``.

    The paper's Eq. 12 fits the *measured per-tier service curve*; when the
    tier is a device mesh (``ShardedEmbedderBackend``), that curve is NOT
    the single-device one — a batch is bucketed to pow2 chunks floored at
    the mesh size, each chunk runs data-parallel with ``chunk/devices`` rows
    per device, and the chunk completes when the SLOWEST device does.  This
    model reproduces exactly that shape so ``estimate_depth`` calibrated on
    it matches the depth calibrated on the real sharded backend:

    * ``chunk_plan`` mirrors the bucketed backend's binary batch
      decomposition with the floor raised to the largest power of two that
      fits the device count — a *degraded* mesh (one host quarantined by
      its breaker leaves e.g. 6 of 8 devices) stays plannable: chunks stay
      pow2 (compile-cache bucketing preserved) and the straggler device
      takes ``ceil(chunk / devices)`` rows;
    * per-device service time comes from the wrapped single-device
      ``DeviceModel`` at the per-device row count (the existing
      length/batch cost model, unchanged);
    * each chunk adds a fan-out/gather overhead term
      (``fanout_beta_s * log2(devices)`` — a tree scatter+gather, plus
      ``interhost_beta_s * log2(hosts)`` when the mesh spans hosts: the
      cross-host all-gather rides the slower network fabric), and a
      noisy base model samples each device independently, so the chunk
      latency is the straggler's (max over devices);
    * chunks of one batch serialize (the real backend enqueues them on the
      same mesh back to back).

    ``devices=1`` is rejected — use the base ``DeviceModel`` directly
    (``sharded_model`` below does this), so a 1-device tier stays bitwise
    the PR 2 path.
    """

    base: DeviceModel
    devices: int
    fanout_beta_s: float = 0.0
    hosts: int = 1
    interhost_beta_s: float = 0.0

    def __post_init__(self):
        if self.devices < 2:
            raise ValueError("FanOutModel needs >= 2 devices; use the base "
                             "DeviceModel for a single device")
        if self.hosts < 1:
            raise ValueError(f"hosts must be >= 1, got {self.hosts}")
        if self.devices % self.hosts:
            raise ValueError(f"devices ({self.devices}) must split evenly "
                             f"over hosts ({self.hosts})")

    # profile_fn_for / telemetry duck-type these off DeviceModel
    @property
    def name(self) -> str:
        tag = f"{self.base.name}x{self.devices}dev"
        return tag if self.hosts <= 1 else f"{tag}x{self.hosts}h"

    @property
    def noise_std(self) -> float:
        return self.base.noise_std

    @property
    def ref_length(self) -> int:
        return self.base.ref_length

    @property
    def overhead_s(self) -> float:
        """Per-execution scatter+gather cost of the mesh: the intra-host
        tree (depth log2(devices)) plus, when the mesh spans hosts, a
        cross-host gather tree on the network fabric (depth log2(hosts))."""
        over = self.fanout_beta_s * math.log2(self.devices)
        if self.hosts > 1:
            over += self.interhost_beta_s * math.log2(self.hosts)
        return over

    @property
    def chunk_floor(self) -> int:
        """Largest power of two <= ``devices``: chunks stay pow2 (the
        compile-cache bucket grid) even when the device count is degraded
        mid-outage to a non-pow2 value."""
        return 1 << (self.devices.bit_length() - 1)

    def chunk_plan(self, batch: int) -> List[int]:
        """Pow2 execution chunks for a batch (floored at the largest pow2
        that fits the — possibly degraded — mesh size)."""
        return _pow2_chunks(batch, self.chunk_floor)

    def latency(self, concurrency: float, length: int = 75,
                rng: Optional[random.Random] = None) -> float:
        batch = max(1, int(math.ceil(concurrency)))
        total = 0.0
        for chunk in self.chunk_plan(batch):
            # ceil: on a non-pow2 (degraded) mesh the rows split unevenly
            # and the chunk completes with the fullest device; exact
            # division — bitwise the old path — when devices is pow2
            rows = -(-chunk // self.devices)
            if self.base.noise_std and rng is not None:
                # independent per-device noise: the chunk finishes with the
                # straggler (the Atlas/Kunpeng outliers of §5.3, fanned out)
                per_dev = max(self.base.latency(rows, length, rng)
                              for _ in range(self.devices))
            else:
                per_dev = self.base.latency(rows, length)
            total += self.overhead_s + per_dev
        return total


def sharded_model(base: DeviceModel, devices: int = 1,
                  fanout_beta_s: float = 0.0, hosts: int = 1,
                  interhost_beta_s: float = 0.0):
    """The DES-side mirror of ``ShardedEmbedderBackend``'s mesh degrade
    rule: 1 device IS the base model (bitwise the single-device path),
    2+ devices wrap it in the fan-out service-curve model — spanning
    ``hosts`` machines when a replica group is carved across the pool."""
    if devices <= 1:
        return base
    return FanOutModel(base, devices, fanout_beta_s, hosts, interhost_beta_s)


def cpu_core_scaled(dev: DeviceModel, cores: int, full_cores: int = 44
                    ) -> DeviceModel:
    """§5.4 CPU-core scalability, calibrated to the paper's Fig. 6:

    * above the knee (``full_cores``): near-linear speedup, capped at 2x —
      "the concurrency can not be improved continuously after a border, due
      to the bottleneck of host memory bandwidth";
    * below the knee: a CLIFF — "the loss of computing ability leads to the
      dramatical increase of CPU latency", i.e. <44 cores bring no benefit
      at the 1s SLO and <36 none at 2s.  Modeled as 10^((full-cores)/8)."""
    if cores <= 0:
        raise ValueError("cores must be positive")
    if cores >= full_cores:
        scale = max(full_cores / cores, 0.5)      # bandwidth saturation cap
    else:
        scale = 10.0 ** ((full_cores - cores) / 8.0)
    return DeviceModel(f"{dev.name}@{cores}c", dev.beta, dev.b * scale,
                       dev.a * scale, dev.noise_std, dev.ref_length)


def quantized_model(dev: DeviceModel, slope_scale: float,
                    tag: str = "w8a8") -> DeviceModel:
    """DES mirror of a quantized serving policy on ``dev``: the measured
    quantized/fp32 service-time ratio scales the concurrency-dependent
    terms (b, a — the per-query slope the estimator fits as ``beta_s``)
    while the fixed dispatch cost ``beta`` stays.  ``slope_scale < 1``
    (quantization helps) therefore raises the Eq. 11 depth
    ``(SLO - beta)/alpha`` — the DES and ``estimator.quantized_fit`` agree
    on how the quantized tier is priced."""
    if slope_scale <= 0:
        raise ValueError(f"slope_scale must be positive, got {slope_scale}")
    return DeviceModel(f"{dev.name}+{tag}", dev.beta, dev.b * slope_scale,
                       dev.a * slope_scale, dev.noise_std, dev.ref_length)


# ---------------------------------------------------------------------------
# discrete-event simulation
# ---------------------------------------------------------------------------

class ServingSimulator:
    """Event-driven WindVE: DES driver of the shared scheduling core.

    New-style: ``ServingSimulator(tiers=[TierSpec(name, depth, model=...),
    ...], slo_s=..., policy=...)`` for arbitrary topologies.  Legacy form
    ``ServingSimulator(npu_model, cpu_model, npu_depth, cpu_depth, slo_s)``
    builds the paper's 2-tier cascade.

    Fault tolerance (mirrors the threaded engine event for event, so the
    DES can *size* a topology under failures, not just under load):

    * ``deadline_s`` arms every arrival with a relative deadline; queued
      queries past it are swept out at exact per-query "expire" events and
      ``pop_batch`` sweeps before every batch formation — dead work never
      reaches a device model;
    * ``retry`` re-dispatches failed batches through the policy path with
      bounded attempts; the exponential backoff is *priced* as simulated
      delay on the failed tier (its server sleeps it, like the engine's
      worker thread does);
    * ``faults`` maps tier name -> :class:`~repro.core.faults.FaultModel`
      — the DES-side injector matching the engine's ``FaultyBackend``
      (same ordinal-plan / wall-time-schedule vocabularies);
    * a ``TierSpec.breaker`` trips/recovers on the simulated clock via the
      same ``QueueManager.tier_success`` / ``tier_failure`` bridges;
    * ``admission`` / ``brownout`` plug the engine's overload controllers
      (:class:`~repro.core.admission.AdmissionController`,
      :class:`~repro.core.health.BrownoutController`) into the shared
      ``QueueManager`` — the capacity planner (``repro.core.planner``)
      sweeps them against load and outage traces.
    """

    def __init__(self, npu: Optional[DeviceModel] = None,
                 cpu: Optional[DeviceModel] = None,
                 npu_depth: int = 0, cpu_depth: int = 0, slo_s: float = 1.0,
                 query_length: int = 75, seed: int = 0, *,
                 tiers: Optional[Sequence[TierSpec]] = None,
                 policy: Optional[DispatchPolicy] = None,
                 retry: Optional[RetryPolicy] = None,
                 deadline_s: Optional[float] = None,
                 faults: Optional[Dict[str, "object"]] = None,
                 admission: "object" = None,
                 brownout: "object" = None):
        if tiers is None:
            if npu is None:
                raise ValueError("need an NPU model or an explicit tier list")
            tiers = [TierSpec(NPU, npu_depth, model=npu)]
            if cpu is not None and cpu_depth > 0:
                tiers.append(TierSpec(CPU, cpu_depth, model=cpu))
        tiers = list(tiers)
        for t in tiers:
            if t.model is None and t.cache is None:
                raise ValueError(f"tier {t.name!r} has no DeviceModel")
        self.qm = QueueManager(tiers, policy=policy,
                               stats=Telemetry(slo=slo_s),
                               admission=admission, brownout=brownout)
        self.slo = slo_s
        self.length = query_length
        self.rng = random.Random(seed)
        # same default as the engine: one attempt, structured failure
        self.retry = retry if retry is not None else RetryPolicy(max_retries=0)
        self.deadline_s = deadline_s
        self.faults: Dict[str, "object"] = dict(faults or {})

    # legacy accessors (pre-TierSpec callers peeked at these)
    @property
    def npu_model(self) -> DeviceModel:
        return self.qm.tiers[0].model

    @property
    def cpu_model(self) -> Optional[DeviceModel]:
        return self.qm.tiers[1].model if len(self.qm.tiers) > 1 else None

    def run_burst(self, n_queries: int) -> SimResult:
        """The paper's stress scenario: n queries arrive simultaneously."""
        return self.run([(0.0, self.length)] * n_queries)

    def run(self, arrivals: List[Tuple[float, int]]) -> SimResult:
        """arrivals: list of (time, query_length) or (time, query_length,
        payload) — the optional payload gives a query its cache identity
        (exact-match key) when the topology carries a cache tier; without
        it, payload-less queries of one length share one key, mirroring the
        engine's deterministic synthetic token streams."""
        res = self.qm.reset(stats=Telemetry(slo=self.slo))
        # every terminal death (queued expiry, retry exhaustion, re-dispatch
        # into a full topology) counts `failed` — same bridge the engine's
        # future-failing path drives
        self.qm.on_expire = lambda q: res.record_failed()
        for fm in self.faults.values():
            fm.reset()
        # event key: (time, priority, seq) — device "kick"s run AFTER every
        # same-instant arrival so a burst is batched, not started one-by-one;
        # "expire" sweeps run after kicks (pop_batch sweeps first anyway, so
        # a same-instant batch never contains the dead query either way)
        events: List[Tuple[float, int, int, str, object]] = []
        for i, arr in enumerate(arrivals):
            t, ln = arr[0], arr[1]
            payload = arr[2] if len(arr) > 2 else None
            dl = None if self.deadline_s is None else t + self.deadline_s
            heapq.heappush(events, (t, 0, i, "arrive",
                                    Query(qid=i, payload=payload, length=ln,
                                          arrival_t=t, deadline=dl)))
        device_tiers = [t for t in self.qm.tiers if t.cache is None]
        admit = bool(self.qm.cache_tiers)
        free_at = {t.name: 0.0 for t in device_tiers}
        models = {t.name: t.model for t in device_tiers}
        seq = len(arrivals)

        def nseq() -> int:
            nonlocal seq
            seq += 1
            return seq

        def armed(q: Query, tier: str) -> None:
            """A queued query with a deadline gets an exact expiry sweep."""
            if q.deadline is not None:
                heapq.heappush(events, (q.deadline, 2, nseq(),
                                        "expire", tier))

        def try_start(tier: str, now: float):
            if free_at[tier] > now + 1e-12:
                return
            # qm.pop_batch: same batch-formation code as the threaded engine
            # (bucket_fn-aware, deadline-swept); latency follows the LONGEST
            # query — the batch is one padded execution, not batch[0]'s
            batch = self.qm.pop_batch(tier, now=now)
            if not batch:
                return
            fm = self.faults.get(tier)
            failed, extra = fm.outcome(now) if fm is not None else (False, 0.)
            if failed:
                # the execution dies instead of serving: it costs failure
                # *detection* (plus any injected stall), never service
                dur = fm.fail_latency_s + extra
            else:
                dur = extra + models[tier].latency(
                    len(batch), max(q.length for q in batch), self.rng)
                res.record_batch(tier, dur)  # same tail metric as engine
            done = now + dur
            free_at[tier] = done
            heapq.heappush(events, (done, 0, nseq(), "done",
                                    (tier, batch, failed, dur)))

        def on_batch_failed(tier: str, batch: List[Query], now: float):
            """Mirror of the engine's ``_retry_or_fail``: bounded attempts,
            exhaustion counts ``failed``, survivors re-dispatch after the
            backoff — which the failed tier's server sits out."""
            self.qm.tier_failure(tier, now)
            retryable: List[Query] = []
            for q in batch:
                q.attempts += 1
                if q.attempts > self.retry.max_retries:
                    res.record_failed()
                else:
                    retryable.append(q)
            if not retryable:
                try_start(tier, now)
                return
            t2 = now + self.retry.backoff(retryable[0].attempts)
            free_at[tier] = max(free_at[tier], t2)
            heapq.heappush(events, (t2, 1, nseq(), "redispatch",
                                    (tier, retryable)))

        def on_redispatch(tier: str, qs: List[Query], now: float):
            kicked = {tier}
            for q in qs:
                if q.expired(now):
                    # burned its last attempt waiting out the backoff
                    res.record_deadline_miss(tier)
                    res.record_failed()
                    continue
                res.record_retry(tier)
                verdict = self.qm.dispatch(q, now=now)
                if verdict == BUSY or verdict == ADMISSION:
                    # no surviving capacity / admission shed a retry that
                    # already burned device time — terminal either way
                    # (mirror of the engine's _retry_or_fail)
                    res.record_failed()
                    continue
                if self.qm.is_cache_tier(verdict):
                    q.done_t = now
                    res.record_completion(q, verdict)
                    continue
                armed(q, verdict)
                kicked.add(verdict)
            for t2 in kicked:
                try_start(t2, now)

        while events:
            now, _, _, kind, obj = heapq.heappop(events)
            if kind == "arrive":
                verdict = self.qm.dispatch(obj)
                if verdict == BUSY:
                    continue
                if verdict == ADMISSION:
                    # shed at arrival: a rejection (rejections_admission),
                    # not a terminal failure — same as the engine's submit
                    continue
                if verdict == EXPIRED:
                    res.record_failed()
                    continue
                if self.qm.is_cache_tier(verdict):
                    # zero-latency tier: the hit completes at +0 service
                    # time — no queue slot, no device event
                    obj.done_t = now
                    res.record_completion(obj, verdict)
                    continue
                armed(obj, verdict)
                heapq.heappush(events, (now, 1, nseq(), "kick", verdict))
            elif kind == "kick":
                try_start(obj, now)
            elif kind == "expire":
                self.qm.sweep(obj, now)
            elif kind == "redispatch":
                on_redispatch(obj[0], obj[1], now)
            else:
                tier, batch, failed, dur = obj
                self.qm.queues[tier].finish(len(batch))
                if failed:
                    on_batch_failed(tier, batch, now)
                    continue
                self.qm.tier_success(tier, dur, now)
                for q in batch:
                    q.done_t = now
                    res.record_completion(q, tier)
                    if admit:
                        # admission hook: the computed embedding (a value
                        # the DES never materializes) enters the cache the
                        # instant its batch completes
                        self.qm.admit(q)
                try_start(tier, now)
        return res


# ---------------------------------------------------------------------------
# stress / profile helpers used by the estimator benchmarks
# ---------------------------------------------------------------------------

def profile_fn_for(dev: DeviceModel, length: int = 75,
                   seed: int = 0) -> Callable[[int], float]:
    """Latency-at-concurrency probe (one batched execution, like the paper's
    standalone profiling runs)."""
    rng = random.Random(seed)
    return lambda c: dev.latency(c, length, rng if dev.noise_std else None)


def poisson(rng: random.Random, lam: float) -> int:
    """Poisson sample (Knuth's product method; Gaussian tail for large lam).

    stdlib ``random`` has no Poisson sampler — the seed's
    ``hasattr(rng, "poissonvariate")`` branch was dead code and every trace
    silently fell back to a rounded Gaussian.  Knuth's method is exact for
    the moderate rates the Fig.-2 traces use; above ``lam > 100`` the normal
    approximation is within the model noise and avoids O(lam) sampling.
    """
    if lam <= 0.0:
        return 0
    if lam > 100.0:
        return max(0, int(round(rng.gauss(lam, math.sqrt(lam)))))
    L = math.exp(-lam)
    k, p = 0, 1.0
    while p > L:
        k += 1
        p *= rng.random()
    return k - 1


def diurnal_trace(n_seconds: int, base_rate: float, peak_rate: float,
                  length: int = 75, seed: int = 0) -> List[Tuple[float, int]]:
    """Fig.-2-style day curve: sinusoidal Poisson rate between base and peak."""
    rng = random.Random(seed)
    out: List[Tuple[float, int]] = []
    for s in range(n_seconds):
        phase = math.sin(2 * math.pi * s / max(n_seconds, 1) - math.pi / 2)
        rate = base_rate + (peak_rate - base_rate) * (phase + 1) / 2
        for _ in range(poisson(rng, rate)):
            out.append((s + rng.random(), length))
    out.sort()
    return out
