"""Device detector — Algorithm 2 of the paper.

Detects available accelerator (NPU/GPU/TPU) and CPU devices, decides the
main/auxiliary roles and worker counts, and force-disables heterogeneous
computing when only one device type exists.

In this JAX port "NPU" means any non-CPU jax backend (TPU/GPU); the CPU
pool is the host.  ``detect()`` can also be fed an explicit inventory so
tests and the simulator can exercise every branch of Algorithm 2.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional


@dataclass(frozen=True)
class DeviceInventory:
    npus: int            # accelerator instance slots (I in the paper)
    cpus: int            # CPU instance slots (J in the paper)


@dataclass(frozen=True)
class DetectionResult:
    device_main: str                 # 'npu' | 'cpu' | 'none'
    device_auxiliary: str            # 'cpu' | 'none'
    worker_num_main: int
    worker_num_auxiliary: int
    heter_enable: bool


def detect(inventory: Optional[DeviceInventory] = None,
           heter_requested: bool = True) -> DetectionResult:
    """Algorithm 2, verbatim branch structure."""
    if inventory is None:
        inventory = probe_jax_devices()
    I, J = inventory.npus, inventory.cpus

    if I > 0:  # npu is available
        if heter_requested and J > 0:
            return DetectionResult("npu", "cpu", I, J, True)
        return DetectionResult("npu", "none", I, 0, False)
    # no NPU: CPU-only service; heterogeneous computing force-disabled
    if J > 0:
        return DetectionResult("cpu", "none", J, 0, False)
    return DetectionResult("none", "none", 0, 0, False)


def probe_jax_devices() -> DeviceInventory:
    import jax

    accel = [d for d in jax.devices() if d.platform not in ("cpu",)]
    # paper recommendation (§4.3): one CPU instance per machine
    return DeviceInventory(npus=len(accel), cpus=1)
