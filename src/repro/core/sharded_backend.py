"""Device-sharded, donation-aware bf16 embedding serving backend.

The paper's deployment-cost formula (Eq. 12) makes per-batch service time on
the accelerator tier the lever behind concurrency-per-device; PR 2 made the
hot path's shapes stable and enumerable (the bucketed (B, S) compile cache).
This module spends that stability on the device side of the batch:

* **mesh fan-out** — one embedding tier becomes a jax ``Mesh`` over N local
  devices.  Every bucketed batch is data-parallel sharded over the mesh
  using the serve-mode rules in ``repro.parallel.sharding``
  (``serve_embed_shardings``: weights RESIDENT — no ``data``-axis FSDP
  specs, so no per-batch weight all-gathers — batch over ``data``).  A
  single-device mesh degrades to exactly the PR 2 bucketed behaviour.
* **bf16-resident serving weights** — ``dtype="bf16"`` casts the param tree
  ONCE at load and runs every trunk matmul in bf16; the ``pool_norm``
  epilogue always accumulates fp32 (see ``repro.kernels.pool_norm``), so
  served vectors stay fp32 unit vectors within 1e-2 cosine of the
  ``dtype="fp32"`` oracle (guarded by tests + the sharded microbench).
* **buffer donation** — ``donate=True`` passes the token/mask device buffers
  as ``jit(..., donate_argnums=(1, 2))`` so XLA may reuse their memory
  instead of allocating fresh HBM per batch; paired with one reusable host
  staging array pair per (B, S) bucket, steady-state serving performs zero
  fresh host allocations and zero retraces.
* **async dispatch** — ``embed_batch_async`` returns as soon as every chunk
  execution is enqueued; the returned fetch thunk blocks for device->host
  transfer.  The engine worker (``repro.core.windve``) double-buffers: batch
  N-1's fetch overlaps batch N's compute, so the worker thread stops
  blocking on ``device_get``.

Correctness notes: the batch bucket floor is raised to the mesh's
data-parallel size so every chunk's batch dim divides the mesh exactly (jit
input shardings require it); padding rows carry an all-zero mask and pool to
zero vectors that are dropped from the output, so sharding never changes
served embeddings.
"""
from __future__ import annotations

import threading
import warnings
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bucketing import BucketedEmbedderBackend, default_buckets, \
    next_pow2
from repro.core.routing import Query
from repro.core.telemetry import Telemetry


_cpu_donation_warning_filtered = False


def _filter_cpu_donation_warning() -> None:
    """Once-only: silence XLA's "donated buffers were not usable" warning on
    the CPU backend, where donation is unimplemented and the warning cannot
    indicate a real mis-specification."""
    global _cpu_donation_warning_filtered
    if not _cpu_donation_warning_filtered:
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        _cpu_donation_warning_filtered = True


def _serve_devices(devices=None) -> list:
    """Local devices the serve mesh fans out over, clamped to a power of two
    so every pow2 batch bucket divides the data axis exactly."""
    import jax

    devices = list(jax.local_devices() if devices is None else devices)
    if not devices:
        raise ValueError("need at least one device")
    usable = 1 << (len(devices).bit_length() - 1)   # largest pow2 <= n
    return devices[:usable]


class ShardedEmbedderBackend(BucketedEmbedderBackend):
    """Bucketed embedder fanned out over a data-parallel device mesh.

    ``dtype`` / ``donate`` / ``async_dispatch`` default to the §Perf flags
    (``embed_dtype`` / ``embed_donate`` / ``embed_async``), so a
    default-constructed backend is the paper-faithful fp32 synchronous
    baseline and every optimization is a reproducible baseline-vs-change
    row.  ``dtype`` policies (``repro.models.quantize.serve_params``):
    ``fp32`` oracle, ``bf16`` resident weights, ``int8`` weight-only
    quantized projections (int8 weights + fp32 dequant scales, fp32
    activations, the fused quant matmul in the trunk; served vectors stay
    fp32 unit vectors within 1e-2 cosine of the oracle), or ``int8_w8a8``
    (the same tree with dynamic per-row activation quantization — int8 x
    int8 projections, int32 accumulation, within 2e-2 cosine).  Counters are
    inherited from the bucketed backend (``traces``, ``bucket_hits``,
    ``real_tokens``/``padded_tokens``, ``truncated``).
    """

    def __init__(self, cfg, params, max_tokens: int = 128, *,
                 mesh=None, devices=None,
                 dtype: Optional[str] = None,
                 donate: Optional[bool] = None,
                 async_dispatch: Optional[bool] = None,
                 min_seq_bucket: int = 16, min_batch_bucket: int = 1,
                 staging_slots: int = 4,
                 telemetry: Optional[Telemetry] = None,
                 prewarm_buckets: Sequence[Tuple[int, int]] = ()):
        import jax

        from repro import perf_flags
        from repro.launch.mesh import make_serve_mesh
        from repro.models import embedder
        from repro.models.quantize import serve_params, wants_act_quant
        from repro.parallel.sharding import dp_axes, serve_embed_shardings

        flags = perf_flags.FLAGS
        dtype = flags.embed_dtype if dtype is None else dtype
        # realise the serving precision policy ONCE at load: fp32 oracle,
        # bf16-resident weights, or int8 weight-only quantized projections
        # (validates dtype and raises a ValueError listing the policies)
        served, cdt = serve_params(params, dtype)
        donate = flags.embed_donate if donate is None else bool(donate)
        self.async_dispatch = (flags.embed_async if async_dispatch is None
                               else bool(async_dispatch))
        if mesh is None:
            mesh = make_serve_mesh(_serve_devices(devices))
        self.mesh = mesh
        ndev = 1
        for a in dp_axes(mesh):
            ndev *= mesh.shape[a]
        if ndev != next_pow2(ndev):
            raise ValueError(f"data-parallel mesh size must be a power of "
                             f"two, got {ndev}")
        self.device_count = ndev
        self.donate = donate

        # the parent wires counters, telemetry and the bucket planner; its
        # single-device jit is replaced below, before anything compiles
        # batch buckets must divide the data axis: floor the bucket at the
        # mesh size and keep it a power of two
        super().__init__(cfg, params, max_tokens,
                         min_seq_bucket=min_seq_bucket,
                         min_batch_bucket=max(next_pow2(min_batch_bucket),
                                              ndev),
                         telemetry=telemetry)
        self.dtype = dtype
        # the trunk's ACTIVATION dtype: weight-only int8 keeps fp32
        # activations, so quantization error enters via the weights alone;
        # int8_w8a8 additionally quantizes activations per projection
        self.serve_dtype = cdt
        aq = wants_act_quant(dtype)
        self.act_quant = aq
        self.name = (f"jax-sharded/{cfg.name}@{ndev}dev/{dtype}"
                     + ("+donate" if donate else "")
                     + ("+async" if self.async_dispatch else ""))

        # (a) weights realised ONCE at load (cast / quantized) and laid out
        # resident on the mesh; dequant scales ride the tree as fp32 leaves
        psh, bsh = serve_embed_shardings(
            mesh, jax.eval_shape(lambda: served))
        self.params = jax.device_put(served, psh)
        self._batch_sharding = bsh

        def _fn(p, toks, mask):
            self.traces += 1          # python side effect: runs once per trace
            return embedder.embed(p, cfg, toks, mask, compute_dtype=cdt,
                                  act_quant=aq)

        # (b) donate the per-batch token/mask device buffers; on a backend
        # where donation is unimplemented (this CPU container) the
        # "not usable" warning is pure noise, so it is filtered ONCE and
        # only there — on TPU/GPU a donation diagnostic stays visible
        jit_kw = {}
        if donate:
            jit_kw["donate_argnums"] = (1, 2)
            if jax.default_backend() == "cpu":
                _filter_cpu_donation_warning()
        self._embed = jax.jit(_fn, in_shardings=(psh, bsh, bsh),
                              out_shardings=bsh, **jit_kw)
        self._jax = jax

        # reusable pinned host staging arrays: a small RING of pairs per
        # (B, S) bucket.  ``device_put`` may defer (or, for large aligned
        # arrays, zero-copy alias) the host buffer, so a slot must not be
        # refilled while an enqueued execution can still read it.  The
        # default depth covers the worker's double-buffering discipline (at
        # most 2 undelivered batches per worker) for up to 2 workers;
        # callers sharing one backend across more workers, or holding more
        # fetches back, must raise ``staging_slots`` to 2 x workers.
        # Steady-state host allocation stays bounded at ``staging_slots``
        # pairs per live bucket.
        self._staging_slots = max(2, int(staging_slots))
        self._staging: dict = {}        # (bb, sb) -> list[(toks, mask)]
        self._staging_use: dict = {}    # (bb, sb) -> fills so far
        self._staging_lock = threading.Lock()
        # overrun guard: staged-but-unfetched executions per bucket.  A slot
        # is reused ``staging_slots`` stagings later; if that many are still
        # pending, refilling would overwrite host data a deferred/aliased
        # ``device_put`` may still read — the served embeddings would be
        # silently ROTATED between batches.  Raise loudly instead (the
        # documented fix: staging_slots >= 2 x worker threads).  Every
        # fetch thunk returned by ``embed_batch_async`` must be called
        # exactly once — dropping one permanently occupies its slots.
        self._staging_pending: dict = {}   # (bb, sb) -> in-flight stagings
        self._staging_tl = threading.local()

        if prewarm_buckets:
            self.prewarm(prewarm_buckets)

    # ------------------------------------------------------------------
    def warm_grid(self, max_batch: int) -> List[Tuple[int, int]]:
        """The enumerable (B, S) grid this backend serves ``max_batch`` with
        (batch buckets floored at the mesh size) — feed to ``prewarm``."""
        return default_buckets(max(max_batch, self.min_batch_bucket),
                               self.max_tokens, self.min_seq_bucket,
                               self.min_batch_bucket)

    def _stage_chunk(self, chunk: Sequence[Query], bb: int, sb: int):
        """Tokenize into the (bb, sb) bucket's next staging slot and ship it
        to the mesh.  The slot rotates through the ring so a buffer is only
        refilled ``staging_slots`` batches later — by which point the
        double-buffered worker has fetched (hence the device has consumed)
        the execution that read it.  The lock covers slot pick + fill +
        transfer, so worker threads can share one backend (raise
        ``staging_slots`` beyond 2 workers)."""
        key = (bb, sb)
        with self._staging_lock:
            pending = self._staging_pending.get(key, 0)
            if pending >= self._staging_slots:
                raise RuntimeError(
                    f"staging ring overrun on bucket {key}: {pending} "
                    f"staged batches not yet fetched with staging_slots="
                    f"{self._staging_slots}.  Refilling now would overwrite "
                    f"host buffers an enqueued execution may still read "
                    f"(rotated embeddings).  More than 2 worker threads — "
                    f"or callers holding fetches back beyond the worker's "
                    f"double-buffering — share this backend: construct it "
                    f"with staging_slots >= 2 x workers.")
            self._staging_pending[key] = pending + 1
            try:
                ring = self._staging.setdefault(key, [])
                use = self._staging_use.get(key, 0)
                self._staging_use[key] = use + 1
                if len(ring) < self._staging_slots:
                    ring.append((np.zeros((bb, sb), np.int32),
                                 np.zeros((bb, sb), np.float32)))
                out = ring[use % len(ring)]
                toks, mask, real, truncated = self._tokenize(chunk, sb,
                                                             out=out)
                td = self._jax.device_put(toks, self._batch_sharding)
                md = self._jax.device_put(mask, self._batch_sharding)
            except Exception:
                # failed BEFORE the caller could capture the key for its
                # own rollback: undo the pending count here or the bucket
                # is poisoned into spurious overrun errors forever
                n = self._staging_pending.get(key, 1) - 1
                if n > 0:
                    self._staging_pending[key] = n
                else:
                    self._staging_pending.pop(key, None)
                raise
        keys = getattr(self._staging_tl, "keys", None)
        if keys is not None:        # capture for the enclosing async call
            keys.append(key)
        return td, md, real, truncated

    def _release_staging(self, keys) -> None:
        with self._staging_lock:
            for k in keys:
                n = self._staging_pending.get(k, 0) - 1
                if n > 0:
                    self._staging_pending[k] = n
                else:
                    self._staging_pending.pop(k, None)

    def embed_batch_async(self, queries: Sequence[Query]
                          ) -> Callable[[], List[np.ndarray]]:
        """Enqueue every chunk of the batch; returns the deferred fetch.

        (c) async dispatch: jit calls return as soon as the computation is
        enqueued, so this method costs staging + dispatch only (the shared
        chunking/accounting path in ``BucketedEmbedderBackend
        ._enqueue_chunks``).  The fetch thunk performs the blocking
        device->host copy — the engine worker calls it one batch late
        (double buffering) so the copy overlaps the next batch's compute.
        """
        self._staging_tl.keys = []
        try:
            handles = self._enqueue_chunks(queries)
        except Exception:
            # roll back this call's pending counts (e.g. the overrun guard
            # fired on a later chunk) so one failed batch cannot poison the
            # accounting for every batch after it
            self._release_staging(self._staging_tl.keys)
            raise
        finally:
            keys, self._staging_tl.keys = self._staging_tl.keys, None

        def fetch() -> List[np.ndarray]:
            try:
                out: List[np.ndarray] = []
                for n, dev in handles:
                    arr = np.asarray(dev)  # blocks until ready; gathers
                    out.extend(arr[i] for i in range(n))
            finally:
                # results copied out: the executions consumed their staged
                # inputs, so the slots may rotate again
                self._release_staging(keys)
            return out

        return fetch

    def embed_batch(self, queries: Sequence[Query]) -> List[np.ndarray]:
        # route the sync path through the async one so staging-pending
        # accounting (stage -> fetch) stays balanced for every caller
        return self.embed_batch_async(queries)()
