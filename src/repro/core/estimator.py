"""Linear-regression queue-depth estimator — paper §4.2.2 (Eq. 12).

Observed (and assumed by SLSC and Mooncake, per the paper): processing
latency is linear in concurrency,

    t_proc(C) = alpha_d * C + beta_d ,   alpha_d, beta_d >= 0.

Fit (alpha, beta) from a handful of profiling points, then the queue depth
for SLO ``T`` is the largest C with t(C) <= T:

    C_max = floor((T - beta) / alpha).

Also provides the stress-test procedure (Eqs. 7-10) the paper compares
against, so Table 3 can be reproduced with both methods.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Mapping, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class LatencyFit:
    alpha: float      # s per concurrent query
    beta: float       # s fixed (model-load / dispatch) cost
    r2: float

    def latency(self, concurrency) -> np.ndarray:
        return self.alpha * np.asarray(concurrency, dtype=float) + self.beta

    def max_concurrency(self, slo_s: float) -> int:
        """C_max = floor((T - beta)/alpha); 0 when even C=1 misses the SLO
        (the paper's Eq. 11 'CPU cannot be used' case)."""
        if self.latency(1) > slo_s:
            return 0
        if self.alpha <= 0:
            return 10 ** 9  # degenerate flat fit: unbounded under this model
        # epsilon guards exact-boundary float error ((1-0.4)/0.1 -> 5.999...)
        return int(np.floor((slo_s - self.beta) / self.alpha + 1e-9))


def fit_latency(concurrency: Sequence[float], latency_s: Sequence[float],
                ) -> LatencyFit:
    """Non-negative least squares fit of Eq. 12 (alpha, beta >= 0)."""
    c = np.asarray(concurrency, dtype=float)
    t = np.asarray(latency_s, dtype=float)
    if c.size < 2:
        raise ValueError("need >= 2 profiling points")
    A = np.stack([c, np.ones_like(c)], axis=1)
    (alpha, beta), *_ = np.linalg.lstsq(A, t, rcond=None)
    # enforce the paper's alpha,beta >= 0 constraint by projected refit
    if alpha < 0:
        alpha, beta = 0.0, float(t.mean())
    elif beta < 0:
        beta = 0.0
        alpha = float((c @ t) / (c @ c))
    pred = alpha * c + beta
    ss_res = float(((t - pred) ** 2).sum())
    ss_tot = float(((t - t.mean()) ** 2).sum()) or 1e-12
    return LatencyFit(float(alpha), float(beta), 1.0 - ss_res / ss_tot)


def quantized_fit(fit: LatencyFit, slope_scale: float) -> LatencyFit:
    """Re-price an Eq. 12 fit for a quantized serving path.

    Quantization (weight-only int8, or the W8A8 int8 x int8 trunk) shrinks
    the per-query service slope ``beta_s`` (our ``alpha``) by the measured
    GEMM-level speedup while the fixed dispatch/load cost ``beta`` stays —
    exactly the transform the paper's deployment-cost argument cares about,
    since depth is ``(SLO - beta) / alpha``.  ``slope_scale`` is the
    measured quantized/fp32 service-time ratio (< 1 when quantization
    helps; the ``w8a8_slope_scale`` metric in ``BENCH_quant_embed.json`` is
    the live source).  A scaled fit lets ``estimate_depth_per_bucket`` /
    ``PredictivePolicy`` price the quantized tier without a second full
    profiling sweep; ``r2`` is inherited (the residuals scale with the
    curve).
    """
    if slope_scale <= 0:
        raise ValueError(f"slope_scale must be positive, got {slope_scale}")
    return LatencyFit(fit.alpha * slope_scale, fit.beta, fit.r2)


def cached_fit(fit: LatencyFit, hit_rate: float) -> LatencyFit:
    """Re-price an Eq. 12 fit for a device tier sitting BEHIND a cache tier.

    With an exact-match cache at the head of the topology serving hit
    fraction ``p`` at ~zero latency and zero FLOPs, only ``(1 - p)`` of the
    arrival stream ever reaches the device: at arrival-level concurrency C
    the device's resident load is ``(1 - p) * C``, so the service curve the
    ARRIVAL stream experiences is

        t(C) = beta + alpha * (1 - p) * C ,

    i.e. the per-query slope shrinks by ``(1 - p)`` while the fixed cost
    stays — the same transform shape as ``quantized_fit``, with the scale
    coming from traffic skew instead of GEMM precision.  The resulting
    ``max_concurrency`` is the ARRIVAL-level depth,
    ``floor((T - beta) / (alpha * (1 - p)))`` — the honest Eq. 12 depth
    when a fraction p of traffic never reaches the device (its closed form
    is ``cost_model.cached_depth``).  ``hit_rate`` must be < 1: an
    all-hits tier needs no device to price.
    """
    if not 0.0 <= hit_rate < 1.0:
        raise ValueError(f"hit_rate must be in [0, 1), got {hit_rate}")
    return LatencyFit(fit.alpha * (1.0 - hit_rate), fit.beta, fit.r2)


def fanout_probe_points(devices: int,
                        base: Sequence[int] = (1, 4, 16, 64),
                        ) -> Tuple[int, ...]:
    """Probe points for an N-device fan-out tier: multiples of the device
    count.  A mesh-floored backend pads every batch below ``devices`` up to
    one identical per-device row count, so probing raw (1, 4, ...) on an
    8-device tier measures the SAME execution several times, fits a flat
    line and trips the estimator's unbounded-depth sentinel — each probe
    must exercise a distinct per-device row count."""
    d = max(1, int(devices))
    return tuple(d * int(c) for c in base)


def fit_from_model(model, probe_points: Sequence[int] = (1, 4, 16, 64),
                   length: int = 75) -> LatencyFit:
    """Eq. 12 fit of any ``latency(concurrency, length)`` curve — a DES
    ``DeviceModel``/``FanOutModel`` probed noise-free.

    This is how the capacity planner (and its admission controllers) get
    service pricing that is *consistent with the simulator they run in*:
    the same object the DES samples batch latencies from yields the fit
    ``AdmissionController``/``PredictivePolicy`` price against, so a
    planner verdict never hinges on two divergent calibrations.
    """
    pts = [(int(c), float(model.latency(int(c), length)))
           for c in probe_points]
    return fit_latency([p[0] for p in pts], [p[1] for p in pts])


def replica_fits(models: Mapping[str, object],
                 probe_points: Sequence[int] = (1, 4, 16, 64),
                 length: int = 75) -> Dict[str, "LatencyFit"]:
    """One Eq. 12 fit PER replica tier, keyed by the replica's tier name.

    Cross-replica predictive routing prices each replica's backlog against
    its OWN service curve — replicas are independently-failing (and, after
    a partial outage, independently-*degraded*) capacity units, so a
    single shared fit would misprice a replica running on fewer devices or
    across more hosts.  ``models`` maps replica tier name (e.g.
    ``NPU@h0r1``, see ``routing.replica_name``) to its ``DeviceModel`` /
    ``FanOutModel``; the returned dict plugs directly into
    ``PredictivePolicy(fits=...)`` and ``AdmissionController(fits=...)``.
    Probe points should come from ``fanout_probe_points`` at each
    replica's own device count when the replicas are meshes.
    """
    return {name: fit_from_model(model, probe_points, length)
            for name, model in models.items()}


def estimate_depth(profile_fn: Callable[[int], float], slo_s: float,
                   probe_points: Sequence[int] = (1, 4, 16, 64),
                   ) -> Tuple[int, LatencyFit]:
    """The paper's fast estimator: profile a FEW concurrency points, fit
    Eq. 12, and read the depth off the line (no exhaustive sweep)."""
    pts = [(c, profile_fn(c)) for c in probe_points]
    fit = fit_latency([p[0] for p in pts], [p[1] for p in pts])
    return fit.max_concurrency(slo_s), fit


def estimate_depth_per_bucket(
        profile_fn: Callable[[int, int], float], slo_s: float,
        bucket_lengths: Sequence[int],
        probe_points: Sequence[int] = (1, 4, 16, 64),
) -> Dict[int, Tuple[int, LatencyFit]]:
    """One Eq. 12 fit PER seq-length bucket: ``{bucket: (depth, fit)}``.

    ``profile_fn(concurrency, length)`` measures one batch at one padded
    length.  A single global fit averages the paper's Fig. 5 structure
    away — a bucketed (and quantized) CPU tier serves a 16-token bucket
    several times faster than a 96-token one, so its SLO-safe depth is a
    per-bucket quantity.  Feed the result to
    ``repro.core.routing.LengthAwarePolicy.from_bucket_depths`` so the
    dispatch threshold follows the measured service curve instead of a
    hand-picked constant.
    """
    return {int(b): estimate_depth(lambda c: profile_fn(c, int(b)), slo_s,
                                   probe_points)
            for b in bucket_lengths}


def stress_test_depth(profile_fn: Callable[[int], float], slo_s: float,
                      step: int = 8, c_max_bound: int = 4096) -> int:
    """The baseline the paper compares against (§4.2.2): increase
    concurrency by ``step`` until the SLO breaks; depth = last passing C.
    The paper notes the step-size trade-off — a large step can overshoot the
    true peak (their Table 3 Atlas/2s row) — which this reproduces."""
    last_ok = 0
    c = step
    while c <= c_max_bound:
        if profile_fn(c) <= slo_s:
            last_ok = c
        else:
            break
        c += step
    return last_ok


def fine_tune_depth(profile_fn: Callable[[int], float], slo_s: float,
                    start: int, radius: int = 8) -> int:
    """Refine an estimated depth (the paper's 'fine-tuned' Table 3 column):
    search downward from start+radius and return the largest passing C —
    robust to estimates that overshoot on noisy devices."""
    for c in range(start + radius, 0, -1):
        if profile_fn(c) <= slo_s:
            return c
    return 0
