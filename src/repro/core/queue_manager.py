"""Compat shim — the queue manager now lives in ``repro.core.routing``.

The seed's two-queue Algorithm 1 grew into the policy-driven N-tier
scheduling core shared by the threaded engine, the DES and the online
calibrator.  Everything this module used to define is re-exported so
``from repro.core.queue_manager import QueueManager`` (and the NPU/CPU/BUSY
constants, ``Query``, ``BoundedQueue``, ``DispatchStats``) keeps working;
new code should import from :mod:`repro.core.routing` directly.
"""
from __future__ import annotations

from repro.core.routing import (BUSY, CPU, NPU, BoundedQueue, CascadePolicy,
                                DispatchPolicy, Query, QueueManager, TierSpec)
from repro.core.telemetry import DispatchStats, Telemetry

__all__ = ["BUSY", "CPU", "NPU", "BoundedQueue", "CascadePolicy",
           "DispatchPolicy", "DispatchStats", "Query", "QueueManager",
           "Telemetry", "TierSpec"]
