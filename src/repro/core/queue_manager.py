"""DEPRECATED compat shim — the queue manager lives in ``repro.core.routing``.

The seed's two-queue Algorithm 1 grew into the policy-driven N-tier
scheduling core shared by the threaded engine, the DES and the online
calibrator; batch formation has exactly ONE import path —
``repro.core.routing.QueueManager.pop_batch`` — and this module is a pure,
documented re-export kept only so pre-refactor call sites
(``from repro.core.queue_manager import QueueManager`` and the NPU/CPU/BUSY
constants, ``Query``, ``BoundedQueue``, ``DispatchStats``) keep importing.

It defines nothing of its own and never will: new code must import from
:mod:`repro.core.routing` (scheduling) / :mod:`repro.core.telemetry`
(stats) directly.  Importing this module emits a ``DeprecationWarning`` so
lingering call sites surface in test logs rather than silently pinning the
alias forever.
"""
from __future__ import annotations

import warnings

from repro.core.routing import (BUSY, CPU, NPU, BoundedQueue, CascadePolicy,
                                DispatchPolicy, Query, QueueManager, TierSpec)
from repro.core.telemetry import DispatchStats, Telemetry

warnings.warn(
    "repro.core.queue_manager is a deprecated alias; import from "
    "repro.core.routing (scheduling) / repro.core.telemetry (stats) instead",
    DeprecationWarning, stacklevel=2)

__all__ = ["BUSY", "CPU", "NPU", "BoundedQueue", "CascadePolicy",
           "DispatchPolicy", "DispatchStats", "Query", "QueueManager",
           "Telemetry", "TierSpec"]
