"""Queue manager — Algorithm 1 of the paper, verbatim semantics.

Two bounded FIFO queues: the main (NPU/TPU) queue and the auxiliary (CPU)
queue.  Dispatch policy:

* main queue not full      -> enqueue on main, return "NPU"
* else, heter enabled and
  aux queue not full       -> enqueue on aux, return "CPU"
* else                     -> reject, return "BUSY"

Queue depths are the SLO contract: depth == the largest concurrency whose
processing latency still meets the SLO (estimated by
``repro.core.estimator``).  Thread-safe; the real engine (windve.py) drives
it from a request thread while worker threads drain it.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional

NPU = "NPU"
CPU = "CPU"
BUSY = "BUSY"


@dataclass
class Query:
    qid: int
    payload: Any = None          # token ids / text
    length: int = 75             # paper default query length (tokens)
    arrival_t: float = 0.0
    # filled by the system:
    device: Optional[str] = None
    start_t: float = 0.0
    done_t: float = 0.0

    @property
    def e2e_latency(self) -> float:
        return self.done_t - self.arrival_t


class BoundedQueue:
    """FIFO with a hard depth bound == the device's C^max."""

    def __init__(self, depth: int):
        if depth < 0:
            raise ValueError("queue depth must be >= 0")
        self.depth = depth
        self._q: Deque[Query] = deque()
        self._lock = threading.Lock()
        # paper semantics: queue length counts queued AND in-flight queries —
        # C^max bounds *concurrency*, not just waiting items.
        self._in_flight = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._q) + self._in_flight

    @property
    def is_full(self) -> bool:
        return len(self) >= self.depth

    def push(self, q: Query) -> bool:
        with self._lock:
            if len(self._q) + self._in_flight >= self.depth:
                return False
            self._q.append(q)
            return True

    def pop_batch(self, max_batch: int) -> List[Query]:
        """Dequeue up to max_batch queries and mark them in-flight."""
        out: List[Query] = []
        with self._lock:
            while self._q and len(out) < max_batch:
                out.append(self._q.popleft())
            self._in_flight += len(out)
        return out

    def finish(self, n: int) -> None:
        with self._lock:
            self._in_flight -= n
            assert self._in_flight >= 0


@dataclass
class DispatchStats:
    to_npu: int = 0
    to_cpu: int = 0
    busy: int = 0

    @property
    def accepted(self) -> int:
        return self.to_npu + self.to_cpu


class QueueManager:
    """Algorithm 1.  ``depths[NPU]`` / ``depths[CPU]`` are C^max_NPU/CPU."""

    def __init__(self, npu_depth: int, cpu_depth: int = 0,
                 heter_enable: bool = True):
        self.queues: Dict[str, BoundedQueue] = {NPU: BoundedQueue(npu_depth)}
        self.heter_enable = heter_enable and cpu_depth > 0
        if self.heter_enable:
            self.queues[CPU] = BoundedQueue(cpu_depth)
        self.stats = DispatchStats()
        self._lock = threading.Lock()

    def dispatch(self, query: Query) -> str:
        """Route one query.  Returns NPU / CPU / BUSY (Algorithm 1)."""
        with self._lock:
            if self.queues[NPU].push(query):
                query.device = NPU
                self.stats.to_npu += 1
                return NPU
            if self.heter_enable and self.queues[CPU].push(query):
                query.device = CPU
                self.stats.to_cpu += 1
                return CPU
            self.stats.busy += 1
            return BUSY

    def depth(self, device: str) -> int:
        return self.queues[device].depth if device in self.queues else 0

    @property
    def max_concurrency(self) -> int:
        """C_NPU + C_CPU — the paper's headline metric."""
        return sum(q.depth for q in self.queues.values())
