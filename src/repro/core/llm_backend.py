"""LM generation backend: serve the ASSIGNED architectures through WindVE.

The paper serves an embedding model; the same queue-manager technique
applies to any jit-compiled request kind (DESIGN.md §4).  This backend runs
prefill + greedy decode for the decoder-LM archs (dense / MoE / SSM /
hybrid), so `WindVE(npu_backend=LMGenerateBackend(...), ...)` serves token
generation with the identical Algorithm-1 dispatch, estimator calibration
and BUSY semantics.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.core.routing import Query
from repro.core.windve import Backend


class LMGenerateBackend(Backend):
    """Batched prompt -> greedy continuation on the host CPU."""

    def __init__(self, cfg, params, max_prompt: int = 64,
                 max_new_tokens: int = 16):
        import jax
        import jax.numpy as jnp

        from repro.models import lm

        self.cfg = cfg
        self.params = params
        self.max_prompt = max_prompt
        self.max_new = max_new_tokens
        self.name = f"jax-lm/{cfg.name}"
        self._jax, self._jnp, self._lm = jax, jnp, lm

        total = max_prompt + max_new_tokens
        if cfg.frontend == "vision":
            total += cfg.num_patches

        def prefill(params, toks):
            return lm.prefill(params, cfg, toks, max_len=total,
                              cache_dtype=jnp.float32)

        def decode(params, tok, cache):
            logits, cache = lm.decode_step(params, cfg, tok, cache)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode)

    def embed_batch(self, queries: Sequence[Query]) -> List[np.ndarray]:
        """Returns the generated continuation token ids per query."""
        jnp = self._jnp
        B = len(queries)
        toks = np.ones((B, self.max_prompt), np.int32)   # pad id 1
        for i, q in enumerate(queries):
            ids = q.payload
            if ids is None:
                ids = (np.arange(q.length) % (self.cfg.vocab_size - 2)) + 2
            n = min(len(ids), self.max_prompt)
            toks[i, -n:] = np.asarray(ids[:n], np.int32)  # right-aligned

        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs = [tok]
        for _ in range(self.max_new - 1):
            tok, cache = self._decode(self.params, tok, cache)
            outs.append(tok)
        gen = np.stack([np.asarray(t) for t in outs], axis=1)  # (B, new)
        return [gen[i] for i in range(B)]
