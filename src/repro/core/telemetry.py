"""Unified serving telemetry — one counter object for every driver.

The seed carried three divergent stat records: ``DispatchStats`` (queue
manager), ``EngineStats`` (threaded engine) and ``SimResult`` (DES).  They
counted the same events with different names, so the drivers could silently
disagree about what "accepted" meant.  ``Telemetry`` is the single record
now: the ``QueueManager`` writes dispatch verdicts into it, the drivers
(threads or DES) write completions into it, and every legacy accessor
(``to_npu``, ``rejected``, ``max_ok_concurrency``, ``p(50)``, ...) reads the
same underlying counts.

``DispatchStats``/``EngineStats``/``SimResult`` remain as aliases so older
call sites keep importing their familiar name.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Optional

import numpy as np

if TYPE_CHECKING:  # pragma: no cover - avoid circular import at runtime
    from repro.core.routing import Query


@dataclass
class Telemetry:
    """Counts for one serving run: dispatch verdicts + completions.

    ``completed`` keeps the Query objects (the DES analyses them per run);
    ``latencies`` mirrors their e2e latencies for percentile/SLO queries
    without re-walking the list.  Long-running drivers (the threaded engine)
    set ``keep_queries=False`` so payloads are not pinned forever — every
    derived metric here reads ``latencies``, not ``completed``.
    """

    slo: float = 1.0
    busy: int = 0
    keep_queries: bool = True
    truncated: int = 0
    dispatched: Dict[str, int] = field(default_factory=dict)
    per_device: Dict[str, int] = field(default_factory=dict)
    # fault-tolerance counters (all zero / empty on a fault-free run, and
    # omitted from summary() so existing consumers see an unchanged shape):
    # deadline misses keyed by the tier the query was queued on ("arrival"
    # when it was already dead at dispatch), retries / backend errors /
    # breaker transitions keyed by the failing tier, plus terminal counts
    deadline_misses: Dict[str, int] = field(default_factory=dict)
    retries: Dict[str, int] = field(default_factory=dict)
    backend_errors: Dict[str, int] = field(default_factory=dict)
    breaker_trips: Dict[str, int] = field(default_factory=dict)
    breaker_recoveries: Dict[str, int] = field(default_factory=dict)
    failed: int = 0              # queries whose futures terminally failed
    hook_errors: int = 0         # batch hooks that raised (and were caught)
    # overload-control counters: rejections broken down by reason
    # ("no_capacity" = classic BUSY, "admission" = priced/watermark shed,
    # "expired" = dead on arrival at dispatch) and brownout stage
    # transitions keyed by the stage entered — all empty on a run that
    # never rejected, and omitted from summary() then
    rejections: Dict[str, int] = field(default_factory=dict)
    brownout_transitions: Dict[str, int] = field(default_factory=dict)
    # set by WindVE.shutdown(): False when a worker thread failed to join
    # (leaked); None until shutdown (and always None for the DES)
    clean_shutdown: Optional[bool] = None
    # zero-cost cache tier counters, keyed by cache tier name; hit ages are
    # entry staleness samples (hit time - insert time, driver clock)
    cache_hits: Dict[str, int] = field(default_factory=dict)
    cache_misses: Dict[str, int] = field(default_factory=dict)
    cache_inserts: Dict[str, int] = field(default_factory=dict)
    cache_evictions: Dict[str, int] = field(default_factory=dict)
    cache_hit_ages: Dict[str, List[float]] = field(default_factory=dict)
    completed: List["Query"] = field(default_factory=list)
    latencies: List[float] = field(default_factory=list)
    batch_latencies: List[float] = field(default_factory=list)
    tier_batch_latencies: Dict[str, List[float]] = field(default_factory=dict)
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    # -- writers (QueueManager.dispatch / the drivers) ---------------------
    def record_dispatch(self, tier: str) -> None:
        with self._lock:
            self.dispatched[tier] = self.dispatched.get(tier, 0) + 1

    def record_busy(self) -> None:
        with self._lock:
            self.busy += 1
            self.rejections["no_capacity"] = \
                self.rejections.get("no_capacity", 0) + 1

    def record_rejection(self, reason: str) -> None:
        """One arrival turned away for ``reason`` (``admission`` /
        ``expired``; ``no_capacity`` is written by :meth:`record_busy` so
        the legacy ``rejected == busy`` reader stays exact)."""
        with self._lock:
            self.rejections[reason] = self.rejections.get(reason, 0) + 1

    def record_brownout(self, stage: str) -> None:
        """The brownout controller entered ``stage`` (counted per stage
        entered, so ``brownout_transitions`` reads as a transition log)."""
        with self._lock:
            self.brownout_transitions[stage] = \
                self.brownout_transitions.get(stage, 0) + 1

    def record_truncations(self, n: int) -> None:
        """Queries whose payload was cut to the backend's max_tokens: the
        served embedding silently covers a prefix of the document, which is
        a quality bug, not a latency one — count it so operators see it."""
        if n:
            with self._lock:
                self.truncated += n

    def record_batch(self, tier: str, service_s: float) -> None:
        """One batch execution's service latency (enqueue -> results ready).
        Both drivers report it, so tail service latency (``batch_p``) is a
        first-class metric next to per-query e2e latency — means hide the
        p99 stalls that actually break the SLO contract.  Kept per tier as
        well: a modeled NPU tier and a real CPU tier have very different
        distributions, and mixing them would mask a tail regression."""
        with self._lock:
            self.batch_latencies.append(service_s)
            self.tier_batch_latencies.setdefault(tier, []).append(service_s)

    def record_cache_hit(self, tier: str, age_s: float) -> None:
        """One exact-match cache hit: the query is served at ~zero latency
        and zero FLOPs.  ``age_s`` is the entry's staleness at hit time —
        how long ago the served embedding was computed."""
        with self._lock:
            self.cache_hits[tier] = self.cache_hits.get(tier, 0) + 1
            self.cache_hit_ages.setdefault(tier, []).append(float(age_s))

    def record_cache_miss(self, tier: str) -> None:
        with self._lock:
            self.cache_misses[tier] = self.cache_misses.get(tier, 0) + 1

    def record_cache_insert(self, tier: str, evicted: int = 0) -> None:
        with self._lock:
            self.cache_inserts[tier] = self.cache_inserts.get(tier, 0) + 1
            if evicted:
                self.cache_evictions[tier] = \
                    self.cache_evictions.get(tier, 0) + int(evicted)

    # -- fault-tolerance writers ------------------------------------------
    def record_deadline_miss(self, tier: str) -> None:
        """One query expired before serving: swept out of ``tier``'s queue
        past its deadline, or dead on arrival (``tier == "arrival"``)."""
        with self._lock:
            self.deadline_misses[tier] = self.deadline_misses.get(tier, 0) + 1

    def record_retry(self, tier: str) -> None:
        """One re-dispatch attempt burned after ``tier`` failed a batch."""
        with self._lock:
            self.retries[tier] = self.retries.get(tier, 0) + 1

    def record_backend_error(self, tier: str) -> None:
        """One batch execution on ``tier`` raised instead of returning."""
        with self._lock:
            self.backend_errors[tier] = self.backend_errors.get(tier, 0) + 1

    def record_breaker_trip(self, tier: str) -> None:
        with self._lock:
            self.breaker_trips[tier] = self.breaker_trips.get(tier, 0) + 1

    def record_breaker_recovery(self, tier: str) -> None:
        with self._lock:
            self.breaker_recoveries[tier] = \
                self.breaker_recoveries.get(tier, 0) + 1

    def record_failed(self) -> None:
        """One query terminally failed: its future carries a ServeError
        (retries exhausted / worker death), not an embedding."""
        with self._lock:
            self.failed += 1

    def record_hook_error(self) -> None:
        """A batch-completion hook raised; the worker loop survived it but
        silent hook death is an observability bug, so it is counted."""
        with self._lock:
            self.hook_errors += 1

    def record_completion(self, query: "Query", tier: str) -> None:
        """The driver sets ``query.done_t`` first; latency is derived."""
        with self._lock:
            if self.keep_queries:
                self.completed.append(query)
            self.latencies.append(query.e2e_latency)
            self.per_device[tier] = self.per_device.get(tier, 0) + 1

    # -- dispatch-side readers --------------------------------------------
    @property
    def accepted(self) -> int:
        return sum(self.dispatched.values())

    @property
    def rejected(self) -> int:
        return self.busy

    @property
    def admission_rejected(self) -> int:
        """Arrivals shed by the admission controller (priced / watermark)."""
        return self.rejections.get("admission", 0)

    @property
    def to_npu(self) -> int:      # legacy DispatchStats field
        return self.dispatched.get("NPU", 0)

    @property
    def to_cpu(self) -> int:      # legacy DispatchStats field
        return self.dispatched.get("CPU", 0)

    # -- completion-side readers (all derived from ``latencies`` so they
    # work with keep_queries=False) ---------------------------------------
    @property
    def n_completed(self) -> int:
        return len(self.latencies)

    @property
    def violations(self) -> int:
        return sum(1 for l in self.latencies if l > self.slo + 1e-9)

    @property
    def max_ok_concurrency(self) -> int:
        """Largest number of simultaneously-resident queries that all met
        the SLO (the paper's 'maximum concurrency' metric)."""
        return sum(1 for l in self.latencies if l <= self.slo + 1e-9)

    # -- cache-tier readers ------------------------------------------------
    def cache_hit_rate(self, tier: Optional[str] = None) -> float:
        """Fraction of cache lookups that hit (``tier`` restricts to one
        cache tier; default aggregates every cache tier consulted)."""
        if tier is None:
            h = sum(self.cache_hits.values())
            m = sum(self.cache_misses.values())
        else:
            h = self.cache_hits.get(tier, 0)
            m = self.cache_misses.get(tier, 0)
        return h / (h + m) if (h + m) else 0.0

    def cache_staleness(self, q: float = 50.0,
                        tier: Optional[str] = None) -> float:
        """Percentile of entry age at hit time (seconds): how stale the
        embeddings actually being served from cache are."""
        if tier is None:
            ages = [a for v in self.cache_hit_ages.values() for a in v]
        else:
            ages = self.cache_hit_ages.get(tier, [])
        return float(np.percentile(ages, q)) if ages else 0.0

    def p(self, q: float) -> float:
        return float(np.percentile(self.latencies, q)) if self.latencies else 0.0

    def batch_p(self, q: float, tier: Optional[str] = None) -> float:
        """Percentile of per-batch service latency (seconds); ``tier``
        restricts to one device pool's batches."""
        lats = self.batch_latencies if tier is None else \
            self.tier_batch_latencies.get(tier, [])
        return float(np.percentile(lats, q)) if lats else 0.0

    def throughput(self, window_s: float) -> float:
        return self.accepted / window_s if window_s > 0 else 0.0

    def replica_rollup(self) -> Dict[str, Dict[str, object]]:
        """Per-tier counters regrouped by LOGICAL tier — the replica lens.

        Every counter here is already per-replica (replicas are ordinary
        tiers keyed by their ``NPU@h0r1``-style names); this rolls them
        back up by ``routing.replica_base`` so a serve summary can show
        both the logical total and the per-replica split:
        ``{"NPU": {"replicas": ["NPU@h0r0", ...], "dispatched": 120,
        "dispatched_by_replica": {"NPU@h0r0": 61, ...}, ...}}``.  Tiers
        that were never replicated group under their own name with a
        single-entry replica list, so the rollup is safe on any topology.
        """
        from repro.core.routing import replica_base
        per_tier = {
            "dispatched": self.dispatched,
            "completed": self.per_device,
            "deadline_misses": self.deadline_misses,
            "retries": self.retries,
            "backend_errors": self.backend_errors,
            "breaker_trips": self.breaker_trips,
            "breaker_recoveries": self.breaker_recoveries,
        }
        groups: Dict[str, Dict[str, object]] = {}
        names: Dict[str, set] = {}
        for metric, counts in per_tier.items():
            for name, v in counts.items():
                base = replica_base(name)
                g = groups.setdefault(base, {})
                names.setdefault(base, set()).add(name)
                g[metric] = g.get(metric, 0) + v
                g.setdefault(f"{metric}_by_replica", {})[name] = v
        for base, g in groups.items():
            g["replicas"] = sorted(names[base])
        return groups

    def summary(self) -> Dict[str, float]:
        """One flat record of the run: dispatch verdicts, completions, SLO
        compliance and payload-truncation count (quality loss is surfaced
        next to latency, not hidden in a backend counter).  When a cache
        tier was consulted, hit-rate / counter / staleness fields join the
        record; when any fault-tolerance event occurred (deadline miss,
        retry, backend error, breaker transition, terminal failure, hook
        error), the fault counters join it too (omitted entirely on
        fault-free cache-less runs so existing consumers see an unchanged
        shape).  The same invariant holds for overload control:
        per-reason ``rejections_*`` and per-stage ``brownout_to_*`` keys
        join the record only when a rejection or brownout transition
        actually happened.  ``clean_shutdown`` appears once the engine has shut down:
        1.0 when every worker thread joined, 0.0 when one leaked."""
        fault: Dict[str, float] = {}
        if (self.deadline_misses or self.retries or self.backend_errors
                or self.breaker_trips or self.breaker_recoveries
                or self.failed or self.hook_errors):
            fault = {
                "deadline_misses": sum(self.deadline_misses.values()),
                "retries": sum(self.retries.values()),
                "backend_errors": sum(self.backend_errors.values()),
                "breaker_trips": sum(self.breaker_trips.values()),
                "breaker_recoveries": sum(self.breaker_recoveries.values()),
                "failed": self.failed,
                "hook_errors": self.hook_errors,
                **{f"deadline_misses_{k}": v
                   for k, v in sorted(self.deadline_misses.items())},
                **{f"backend_errors_{k}": v
                   for k, v in sorted(self.backend_errors.items())},
            }
        if self.clean_shutdown is not None:
            fault["clean_shutdown"] = float(self.clean_shutdown)
        overload: Dict[str, float] = {}
        if any(self.rejections.values()) or self.brownout_transitions:
            overload = {f"rejections_{k}": v
                        for k, v in sorted(self.rejections.items()) if v}
            overload.update({f"brownout_to_{k}": v for k, v in
                             sorted(self.brownout_transitions.items())})
        cache: Dict[str, float] = {}
        if self.cache_hits or self.cache_misses or self.cache_inserts:
            cache = {
                "cache_hit_rate": self.cache_hit_rate(),
                "cache_hits": sum(self.cache_hits.values()),
                "cache_misses": sum(self.cache_misses.values()),
                "cache_inserts": sum(self.cache_inserts.values()),
                "cache_evictions": sum(self.cache_evictions.values()),
                "cache_staleness_p50_s": self.cache_staleness(50),
                "cache_staleness_p95_s": self.cache_staleness(95),
                **{f"cache_hit_rate_{k}": self.cache_hit_rate(k)
                   for k in sorted(set(self.cache_hits)
                                   | set(self.cache_misses))},
            }
        return {
            **fault,
            **overload,
            **cache,
            "accepted": self.accepted,
            "rejected": self.rejected,
            "completed": self.n_completed,
            "violations": self.violations,
            "truncated": self.truncated,
            "p50_s": self.p(50),
            "p95_s": self.p(95),
            "p99_s": self.p(99),
            "batch_p50_s": self.batch_p(50),
            "batch_p95_s": self.batch_p(95),
            "batch_p99_s": self.batch_p(99),
            **{f"batch_p95_{k}": self.batch_p(95, k)
               for k in sorted(self.tier_batch_latencies)},
            **{f"dispatched_{k}": v for k, v in sorted(self.dispatched.items())},
            **{f"completed_{k}": v for k, v in sorted(self.per_device.items())},
        }


# Back-compat names: the three seed-era records are now literally the same
# object so engine/simulator/calibrator can no longer diverge.
DispatchStats = Telemetry
EngineStats = Telemetry
SimResult = Telemetry
