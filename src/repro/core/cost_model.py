"""Deployment-cost model — paper §3 (Eqs. 1-6) and §3.2 savings analysis.

Two provisioning regimes:
* throughput-provisioned (Eq. 5):  Cost = (N / n) / T * D * P
* peak-provisioned       (Eq. 6):  Cost = N_peak / C * D * P

and the §3.2 headline results for CPU offloading:
* peak-provisioned saving     = C_CPU / (C_CPU + C_NPU)
* average-provisioned uplift  = C_CPU / C_NPU
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class Deployment:
    device_per_instance: int = 1     # D
    price_per_device: float = 1.0    # P


def waiting_slots(t_total_max: float, t_proc: float) -> int:
    """Eq. 4: n = floor((t^max_total - t_proc) / t_proc) — how many other
    queries may be processed while one waits without breaking the SLO."""
    if t_proc <= 0:
        raise ValueError("t_proc must be positive")
    return max(0, math.floor((t_total_max - t_proc) / t_proc))


def cost_throughput(n_queries_per_s: float, t_total_max: float,
                    t_proc: float, throughput: float,
                    d: Deployment = Deployment()) -> float:
    """Eq. 5 — provision by average throughput T with n-deep waiting."""
    n = max(1, waiting_slots(t_total_max, t_proc))
    return (n_queries_per_s / n) / throughput * d.device_per_instance * \
        d.price_per_device


def cost_peak(n_peak: float, max_concurrency: float,
              d: Deployment = Deployment()) -> float:
    """Eq. 6 — provision by peak query rate over system max concurrency."""
    if max_concurrency <= 0:
        raise ValueError("max concurrency must be positive")
    return n_peak / max_concurrency * d.device_per_instance * d.price_per_device


def peak_saving(c_npu: int, c_cpu: int) -> float:
    """§3.2: deployment-cost saving when peak-provisioned: C_CPU/(C_CPU+C_NPU)."""
    if c_npu <= 0:
        raise ValueError("c_npu must be positive")
    return c_cpu / (c_cpu + c_npu)


def throughput_uplift(c_npu: int, c_cpu: int) -> float:
    """§3.2: average-throughput uplift: C_CPU/C_NPU (also the paper's
    'concurrency improvement' in Tables 1-2)."""
    if c_npu <= 0:
        raise ValueError("c_npu must be positive")
    return c_cpu / c_npu


def fanout_depth(alpha: float, beta: float, devices: int, slo_s: float,
                 overhead_s: float = 0.0) -> int:
    """Closed-form Eq. 12 depth for an N-device fan-out tier.

    With the per-device curve t(c) = beta + alpha * c and a batch of C
    spreading C/N rows per device (plus a per-execution fan-out/gather
    overhead), the tier's service curve is

        t(C) = beta + overhead + alpha * C / N ,

    so the SLO-safe depth scales ~N-fold minus what the overhead eats:

        C_max = N * floor((T - beta - overhead) / alpha).
    """
    if alpha <= 0:
        raise ValueError("alpha must be positive")
    if devices < 1:
        raise ValueError("devices must be >= 1")
    budget = slo_s - beta - overhead_s
    if budget < alpha:            # even 1 row per device misses the SLO
        return 0
    return devices * math.floor(budget / alpha + 1e-9)


def mesh_overhead(fanout_beta_s: float, devices: int,
                  interhost_beta_s: float = 0.0, hosts: int = 1) -> float:
    """Per-execution scatter/gather overhead of a (possibly multi-host)
    replica mesh — the ``overhead_s`` term :func:`fanout_depth` subtracts
    from the SLO budget, and the closed form of
    ``simulator.FanOutModel.overhead_s``:

        fanout_beta * log2(devices) + interhost_beta * log2(hosts).

    The intra-host tree rides the device interconnect; when the replica's
    device group is carved across ``hosts`` machines the gather's top
    ``log2(hosts)`` levels ride the network fabric instead, which is why
    depth calibration at cluster scale must price the two terms separately
    (``interhost_beta_s`` is typically orders of magnitude above
    ``fanout_beta_s``)."""
    if devices < 1 or hosts < 1:
        raise ValueError("devices and hosts must be >= 1")
    if devices % hosts:
        raise ValueError(f"devices ({devices}) must split evenly over "
                         f"hosts ({hosts})")
    over = fanout_beta_s * math.log2(devices) if devices > 1 else 0.0
    if hosts > 1:
        over += interhost_beta_s * math.log2(hosts)
    return over


def replica_capacity(depth: int, replicas: int, down: int = 0) -> int:
    """System max concurrency of R identical replicas with k quarantined:
    ``(R - k) * depth`` — the replica-topology instance of
    :func:`degraded_capacity`, and what the Eq. 6 peak-provisioned cost
    divides by while k hosts are down.  A replica is a whole capacity unit:
    its breaker trips it entirely, so partial-replica capacity shows up as
    a *changed per-replica depth* (recalibrate on the degraded device
    count via :func:`fanout_depth`), never as a fractional replica."""
    if depth < 0:
        raise ValueError("depth must be >= 0")
    if replicas < 1:
        raise ValueError("replicas must be >= 1")
    if not 0 <= down <= replicas:
        raise ValueError(f"down must be in [0, {replicas}], got {down}")
    return (replicas - down) * depth


def fanout_efficiency(depth_n: int, depth_1: int, devices: int) -> float:
    """Fraction of the ideal N-fold depth scaling a fan-out tier realises:
    depth_N / (N * depth_1).  1.0 == perfect linear scaling; the
    fan-out/gather overhead and pow2 chunk padding pull it below."""
    if depth_1 <= 0 or devices < 1:
        raise ValueError("need positive single-device depth and devices")
    return depth_n / (devices * depth_1)


def cache_uplift(hit_rate: float) -> float:
    """Effective-concurrency uplift from an exact-match cache tier serving
    hit fraction p at ~zero latency: only (1 - p) of arrivals consume a
    device slot, so system capacity (and the Eq. 5/6 deployment-cost
    denominators) scale by 1 / (1 - p).  p = 0.5 doubles capacity — more
    than any single-device speedup in Tables 1-2 buys."""
    if not 0.0 <= hit_rate < 1.0:
        raise ValueError(f"hit_rate must be in [0, 1), got {hit_rate}")
    return 1.0 / (1.0 - hit_rate)


def cached_depth(depth: int, hit_rate: float) -> int:
    """Arrival-level SLO-safe concurrency of a device tier of depth
    ``depth`` behind a cache with hit fraction p: the device still bounds
    its RESIDENT load at ``depth``, but the arrival stream that load maps
    to is ``depth / (1 - p)`` — the closed form of
    ``estimator.cached_fit(fit, p).max_concurrency(slo)`` (p of the extra
    arrivals are hits that never occupy a slot)."""
    if depth < 0:
        raise ValueError("depth must be >= 0")
    return math.floor(depth * cache_uplift(hit_rate) + 1e-9)


def availability(mttf_s: float, mttr_s: float) -> float:
    """Steady-state availability of a repairable tier: MTTF/(MTTF+MTTR) —
    the up fraction of the alternating-renewal process
    ``faults.FaultSchedule.from_mttf`` draws its down windows from."""
    if mttf_s <= 0 or mttr_s <= 0:
        raise ValueError("mttf_s and mttr_s must be positive")
    return mttf_s / (mttf_s + mttr_s)


def degraded_capacity(depths: "dict[str, int]",
                      down: "Iterable[str]" = ()) -> int:
    """System max concurrency with the named tiers tripped/failed: the sum
    of C^max over the tiers dispatch can still reach — the closed form of
    ``QueueManager.degraded_max_concurrency`` while breakers are open.
    The paper's Eq. 6 peak-provisioned cost divides by THIS during an
    outage, not by the fault-free total."""
    unknown = set(down) - set(depths)
    if unknown:
        raise ValueError(f"unknown tier(s) {sorted(unknown)}; "
                         f"have {sorted(depths)}")
    return sum(d for name, d in depths.items() if name not in down)


def expected_capacity(depths: "dict[str, int]",
                      avail: "dict[str, float]") -> float:
    """Long-run expected max concurrency of a topology whose tiers fail
    independently with per-tier availability ``avail`` (missing tiers
    count as always-up): sum_t A_t * C^max_t.  What a fault-aware sizing
    pass should provision against instead of the fault-free sum."""
    for name, a in avail.items():
        if not 0.0 <= a <= 1.0:
            raise ValueError(f"availability[{name!r}] must be in [0, 1]")
    return sum(d * avail.get(name, 1.0) for name, d in depths.items())


def cost_per_million_queries(price_per_s: float, horizon_s: float,
                             accepted: int) -> float:
    """The planner's headline unit economics: what one million *accepted*
    queries cost on a topology priced at ``price_per_s`` over a serving
    window of ``horizon_s`` in which it accepted ``accepted`` queries.

    Accepted — not offered — is the denominator the paper's deployment
    argument implies: a topology that rejects half its arrivals under a
    flash crowd pays full price for half the work, which is exactly the
    signal a sizing sweep must surface.  A window that accepted nothing
    costs infinity per query (the topology is pure waste at this load).
    """
    if price_per_s < 0:
        raise ValueError("price_per_s must be >= 0")
    if horizon_s <= 0:
        raise ValueError("horizon_s must be positive")
    if accepted < 0:
        raise ValueError("accepted must be >= 0")
    if accepted == 0:
        return math.inf
    return price_per_s * horizon_s / accepted * 1e6


def overload_shed_fraction(arrival_rate: float, capacity_rate: float) -> float:
    """Lower bound on the fraction of arrivals ANY loss system must turn
    away at steady state: ``max(0, 1 - capacity/arrivals)``.  An admission
    controller cannot beat this bound — it can only choose *which* queries
    make up the shed fraction (the predictably-late ones) instead of
    letting the queue choose (the unlucky ones, after wasting device time
    on them)."""
    if arrival_rate <= 0:
        raise ValueError("arrival_rate must be positive")
    if capacity_rate < 0:
        raise ValueError("capacity_rate must be >= 0")
    return max(0.0, 1.0 - capacity_rate / arrival_rate)


def concurrency_uplift_bound(alpha_npu: float, alpha_cpu: float) -> float:
    """Ineq. 19: C_CPU/C_NPU < alpha_NPU/alpha_CPU — the uplift is bounded by
    the device performance-gap ratio."""
    if alpha_cpu <= 0:
        raise ValueError("alpha_cpu must be positive")
    return alpha_npu / alpha_cpu
