"""Seeded fault injection for both drivers of the scheduling core.

The fault-tolerance layer (deadlines, retry/failover, circuit breaking) is
only trustworthy if it is *exercised*: this module injects failures into the
engine (``FaultyBackend`` — a ``Backend`` wrapper) and the DES
(``FaultModel`` — consulted by ``ServingSimulator`` per batch execution)
from the SAME two schedule vocabularies, so an engine run and a DES run can
be subjected to the identical fault sequence and their telemetry compared:

* **ordinal plans** (:class:`FaultPlan`) — "batch executions #2 and #3 on
  this tier fail / stall / corrupt".  Batch ordinals are deterministic under
  both drivers whenever the batch sequences are (the parity property suite's
  pinned-GIL bursts), so this is the vocabulary of the engine-vs-DES
  fault-parity tests.
* **wall-time schedules** (:class:`FaultSchedule`) — down-time windows, or
  MTTF/MTTR exponential draws (``from_mttf``) over a horizon.  This is the
  vocabulary of the chaos microbench: a tier goes down mid-run and the
  serving layer must fail over, then recover when the window closes.

``BackendError`` is what an injected failure raises — a stand-in for the
device-pool exceptions (HBM OOM, collective timeout, RPC reset) a real
deployment throws.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.routing import Query


class BackendError(RuntimeError):
    """An injected (or real) device-pool failure for one batch execution."""


@dataclass(frozen=True)
class FaultPlan:
    """Per-tier *ordinal* fault plan: which batch executions (0-based, in
    tier execution order) fail, stall, or corrupt.  Deterministic by
    construction — the parity vocabulary."""

    fail: frozenset = frozenset()
    stall: frozenset = frozenset()
    corrupt: frozenset = frozenset()
    stall_s: float = 0.0

    def __post_init__(self):
        if self.stall_s < 0:
            raise ValueError("stall_s must be >= 0")
        # frozenset() accepts any iterable; normalize lists/sets passed in
        object.__setattr__(self, "fail", frozenset(self.fail))
        object.__setattr__(self, "stall", frozenset(self.stall))
        object.__setattr__(self, "corrupt", frozenset(self.corrupt))


@dataclass(frozen=True)
class FaultSchedule:
    """Wall-time down windows ``[(start_s, end_s), ...]`` on a tier-relative
    clock (engine: seconds since the wrapper saw its first batch; DES:
    simulated seconds).  ``from_mttf`` draws the windows from exponential
    MTTF/MTTR — the classic repairable-system availability model, so the
    expected up fraction is ``mttf / (mttf + mttr)``."""

    windows: Tuple[Tuple[float, float], ...] = ()

    def __post_init__(self):
        for s, e in self.windows:
            if e <= s:
                raise ValueError(f"empty/backwards down window ({s}, {e})")
        object.__setattr__(self, "windows",
                           tuple(sorted(tuple(map(float, w))
                                        for w in self.windows)))

    @classmethod
    def from_mttf(cls, mttf_s: float, mttr_s: float, horizon_s: float,
                  seed: int = 0) -> "FaultSchedule":
        if mttf_s <= 0 or mttr_s <= 0 or horizon_s <= 0:
            raise ValueError("mttf_s, mttr_s, horizon_s must be positive")
        rng = random.Random(seed)
        t, wins = 0.0, []
        while t < horizon_s:
            t += rng.expovariate(1.0 / mttf_s)          # time to failure
            if t >= horizon_s:
                break
            repair = rng.expovariate(1.0 / mttr_s)      # time to repair
            wins.append((t, min(t + repair, horizon_s)))
            t += repair
        return cls(tuple(wins))

    def is_down(self, t: float) -> bool:
        return any(s <= t < e for s, e in self.windows)

    def next_up(self, t: float) -> float:
        """The instant the tier is next up at-or-after ``t``."""
        for s, e in self.windows:
            if s <= t < e:
                return e
        return t

    @property
    def down_s(self) -> float:
        return sum(e - s for s, e in self.windows)


def _corrupted(embs: List[np.ndarray]) -> List[np.ndarray]:
    """A silently-wrong batch result: right shape/dtype, wrong values —
    the failure golden-parity checks exist to catch."""
    return [np.asarray(e) * -1.0 + 1.0 for e in embs]


class FaultyBackend:
    """Engine-side fault injector: wraps any ``Backend`` and subjects its
    batch executions to an ordinal :class:`FaultPlan` and/or a wall-time
    :class:`FaultSchedule` (clock starts at the first execution, so the
    schedule is phase-aligned with the run, not with process start).

    Duck-types ``Backend`` (name / telemetry / embed_batch); telemetry
    wiring is forwarded to the wrapped backend so truncation counting etc.
    keeps working through the wrapper.
    """

    async_dispatch = False

    def __init__(self, inner, plan: Optional[FaultPlan] = None,
                 schedule: Optional[FaultSchedule] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.inner = inner
        self.plan = plan or FaultPlan()
        self.schedule = schedule
        self._clock = clock
        self._t0: Optional[float] = None
        self.executions = 0
        self.injected_failures = 0
        self.injected_stalls = 0
        self.injected_corruptions = 0
        self.name = f"faulty({getattr(inner, 'name', 'backend')})"

    # WindVE wires its shared Telemetry into backends that left it None —
    # forward so the wrapped backend reports quality events as usual
    @property
    def telemetry(self):
        return getattr(self.inner, "telemetry", None)

    @telemetry.setter
    def telemetry(self, value):
        self.inner.telemetry = value

    def elapsed(self) -> float:
        """Tier-relative clock the wall-time schedule runs on."""
        if self._t0 is None:
            self._t0 = self._clock()
        return self._clock() - self._t0

    def embed_batch(self, queries: Sequence[Query]) -> List[np.ndarray]:
        i = self.executions
        self.executions += 1
        t = self.elapsed()
        if i in self.plan.stall:
            self.injected_stalls += 1
            time.sleep(self.plan.stall_s)
        if i in self.plan.fail or \
                (self.schedule is not None and self.schedule.is_down(t)):
            self.injected_failures += 1
            raise BackendError(f"injected fault (execution #{i}, t={t:.3f}s)")
        out = self.inner.embed_batch(queries)
        if i in self.plan.corrupt:
            self.injected_corruptions += 1
            out = _corrupted(out)
        return out


@dataclass
class FaultModel:
    """DES-side mirror of :class:`FaultyBackend` for a ``ModeledBackend``
    tier: the simulator consults it once per batch execution (same per-tier
    ordinal counter, same schedule vocabulary on simulated time).

    ``fail_latency_s`` prices failure *detection* — a raise is near-instant
    on the engine (default 0.0), but a collective timeout on real hardware
    is not, so the chaos bench can model slow failure discovery.
    """

    plan: FaultPlan = field(default_factory=FaultPlan)
    schedule: Optional[FaultSchedule] = None
    fail_latency_s: float = 0.0
    executions: int = 0
    injected_failures: int = 0
    injected_stalls: int = 0

    def __post_init__(self):
        if self.fail_latency_s < 0:
            raise ValueError("fail_latency_s must be >= 0")

    def reset(self) -> None:
        """Fresh ordinal counters — one DES run's fault state."""
        self.executions = 0
        self.injected_failures = 0
        self.injected_stalls = 0

    def outcome(self, now: float) -> Tuple[bool, float]:
        """One batch execution at simulated time ``now``.  Returns
        ``(failed, extra_s)``: ``failed`` batches cost ``fail_latency_s``
        *instead of* service time; surviving stalled batches cost
        ``extra_s`` *on top of* the modeled service time (what trips a
        latency-EWMA breaker)."""
        i = self.executions
        self.executions += 1
        extra = 0.0
        if i in self.plan.stall:
            self.injected_stalls += 1
            extra = self.plan.stall_s
        if i in self.plan.fail or \
                (self.schedule is not None and self.schedule.is_down(now)):
            self.injected_failures += 1
            return True, extra
        return False, extra
