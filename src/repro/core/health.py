"""Per-tier health state: a deterministic, clock-free circuit breaker.

WindVE's deployment-cost argument (Eq. 12) assumes every provisioned tier
keeps serving; production traffic guarantees the opposite.  A tier whose
backend has started failing (crashed worker pool, stalled device, network
partition to a remote mesh) must be *routed around*, not hammered: every
query dispatched into a dead tier's queue is a client future that either
burns a retry attempt or times out against its deadline.

``CircuitBreaker`` is the standard three-state machine, shaped for the
shared scheduling core:

* **closed** — healthy.  Consecutive backend failures (``record_failure``)
  and a service-latency EWMA crossing ``latency_trip_s`` (a *stall* is a
  failure that never raises) both count toward a trip.
* **open** — tripped.  :func:`repro.core.routing.dispatchable` filters the
  tier out, so all four dispatch policies transparently route around it
  (exactly like cache tiers are filtered — the topology list is unchanged,
  only the candidate set shrinks).  Queries already queued on the tier are
  still drained by its workers: the breaker gates *admission*, not drain.
* **half-open** — after ``cooldown_s`` the tier becomes dispatchable again
  and the next completed batch is the probe: success closes the breaker
  (recovery), failure re-opens it for another cooldown.

Determinism contract (same as the cache tier): the breaker never reads a
wall clock.  Callers pass ``now`` — the threaded engine passes
``time.monotonic()``, the DES passes simulated time — and the internal
clock is monotone (``max`` of everything seen), so a seeded DES run replays
the identical trip/recover sequence.  Thread-safe for the engine.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half-open"


class CircuitBreaker:
    """Trip on consecutive failures or a latency-EWMA stall; recover via a
    half-open probe.  Attach one per device tier (``TierSpec.breaker``)."""

    def __init__(self, failure_threshold: int = 3, cooldown_s: float = 1.0,
                 latency_trip_s: Optional[float] = None,
                 ewma_alpha: float = 0.3):
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        if cooldown_s <= 0:
            raise ValueError("cooldown_s must be positive")
        if latency_trip_s is not None and latency_trip_s <= 0:
            raise ValueError("latency_trip_s must be positive when set")
        if not 0.0 < ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        self.failure_threshold = failure_threshold
        self.cooldown_s = cooldown_s
        self.latency_trip_s = latency_trip_s
        self.ewma_alpha = ewma_alpha
        self._lock = threading.Lock()
        self._init_state()

    def _init_state(self) -> None:
        self.state = CLOSED
        self.consecutive_failures = 0
        self.latency_ewma_s: Optional[float] = None
        self.trips = 0
        self.recoveries = 0
        self.last_trip_reason: Optional[str] = None
        self._open_until = 0.0
        self._now = 0.0

    # ------------------------------------------------------------------
    @property
    def dispatchable(self) -> bool:
        """May new work be routed here?  Open == no; half-open == yes (the
        probe); callers must ``tick(now)`` first so open -> half-open
        transitions happen on the driver's clock, not a hidden one."""
        with self._lock:
            return self.state != OPEN

    def tick(self, now: float) -> str:
        """Advance the breaker's clock (monotone).  An open breaker whose
        cooldown has elapsed transitions to half-open — the next dispatch
        becomes the recovery probe.  Returns the post-tick state."""
        with self._lock:
            self._now = max(self._now, now)
            if self.state == OPEN and self._now >= self._open_until:
                self.state = HALF_OPEN
            return self.state

    def _trip(self, reason: str) -> None:
        self.state = OPEN
        self.trips += 1
        self.last_trip_reason = reason
        self.consecutive_failures = 0
        self._open_until = self._now + self.cooldown_s

    def record_success(self, latency_s: float, now: float) -> None:
        """One completed batch.  Resets the failure streak; in half-open
        this is the probe succeeding (recovery).  A closed breaker with
        ``latency_trip_s`` set trips when the latency EWMA crosses it —
        the tier is *stalling*, which a raise-based detector never sees."""
        with self._lock:
            self._now = max(self._now, now)
            self.consecutive_failures = 0
            if self.state == HALF_OPEN:
                self.state = CLOSED
                self.recoveries += 1
                # the stale pre-trip EWMA must not instantly re-trip a
                # freshly recovered tier: restart it from the probe
                self.latency_ewma_s = float(latency_s)
                return
            a = self.ewma_alpha
            self.latency_ewma_s = float(latency_s) if \
                self.latency_ewma_s is None else \
                a * float(latency_s) + (1.0 - a) * self.latency_ewma_s
            if (self.state == CLOSED and self.latency_trip_s is not None
                    and self.latency_ewma_s > self.latency_trip_s):
                self._trip("latency")

    def record_failure(self, now: float) -> None:
        """One failed batch.  Half-open: the probe failed — re-open for
        another cooldown.  Closed: count toward the consecutive-failure
        threshold.  Open (in-flight work finishing after the trip): extend
        the cooldown from ``now``."""
        with self._lock:
            self._now = max(self._now, now)
            if self.state == HALF_OPEN:
                self._trip("probe-failure")
            elif self.state == OPEN:
                self._open_until = max(self._open_until,
                                       self._now + self.cooldown_s)
            else:
                self.consecutive_failures += 1
                if self.consecutive_failures >= self.failure_threshold:
                    self._trip("failures")

    def reset(self) -> None:
        """Fresh closed breaker (counters included) — one DES run's state."""
        with self._lock:
            self._init_state()

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            return {
                "state": self.state,
                "consecutive_failures": self.consecutive_failures,
                "latency_ewma_s": self.latency_ewma_s,
                "trips": self.trips,
                "recoveries": self.recoveries,
                "last_trip_reason": self.last_trip_reason,
            }

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"CircuitBreaker(state={self.state!r}, trips={self.trips}, "
                f"recoveries={self.recoveries})")
