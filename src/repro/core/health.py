"""Per-tier health state: a deterministic, clock-free circuit breaker.

WindVE's deployment-cost argument (Eq. 12) assumes every provisioned tier
keeps serving; production traffic guarantees the opposite.  A tier whose
backend has started failing (crashed worker pool, stalled device, network
partition to a remote mesh) must be *routed around*, not hammered: every
query dispatched into a dead tier's queue is a client future that either
burns a retry attempt or times out against its deadline.

``CircuitBreaker`` is the standard three-state machine, shaped for the
shared scheduling core:

* **closed** — healthy.  Consecutive backend failures (``record_failure``)
  and a service-latency EWMA crossing ``latency_trip_s`` (a *stall* is a
  failure that never raises) both count toward a trip.
* **open** — tripped.  :func:`repro.core.routing.dispatchable` filters the
  tier out, so all four dispatch policies transparently route around it
  (exactly like cache tiers are filtered — the topology list is unchanged,
  only the candidate set shrinks).  Queries already queued on the tier are
  still drained by its workers: the breaker gates *admission*, not drain.
* **half-open** — after ``cooldown_s`` the tier becomes dispatchable again
  and the next completed batch is the probe: success closes the breaker
  (recovery), failure re-opens it for another cooldown.

Determinism contract (same as the cache tier): the breaker never reads a
wall clock.  Callers pass ``now`` — the threaded engine passes
``time.monotonic()``, the DES passes simulated time — and the internal
clock is monotone (``max`` of everything seen), so a seeded DES run replays
the identical trip/recover sequence.  Thread-safe for the engine.

``BrownoutController`` is the second health machine here: a three-stage
*overload* controller (normal -> degraded -> shedding) driven by a
utilization EWMA sampled at dispatch time.  Where the breaker reacts to a
tier *failing*, brownout reacts to the whole topology *saturating* — and
sheds quality before the admission controller sheds queries.  Same
determinism contract: no wall clock, EWMA updates are keyed to dispatch
events (identical in both drivers under the parity suites' pinned bursts),
so a seeded DES run replays the identical stage sequence.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Sequence

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half-open"

# brownout stages, in escalation order
NORMAL = "normal"
DEGRADED = "degraded"
SHEDDING = "shedding"
_STAGES = (NORMAL, DEGRADED, SHEDDING)


class CircuitBreaker:
    """Trip on consecutive failures or a latency-EWMA stall; recover via a
    half-open probe.  Attach one per device tier (``TierSpec.breaker``)."""

    def __init__(self, failure_threshold: int = 3, cooldown_s: float = 1.0,
                 latency_trip_s: Optional[float] = None,
                 ewma_alpha: float = 0.3):
        if failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        if cooldown_s <= 0:
            raise ValueError("cooldown_s must be positive")
        if latency_trip_s is not None and latency_trip_s <= 0:
            raise ValueError("latency_trip_s must be positive when set")
        if not 0.0 < ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        self.failure_threshold = failure_threshold
        self.cooldown_s = cooldown_s
        self.latency_trip_s = latency_trip_s
        self.ewma_alpha = ewma_alpha
        self._lock = threading.Lock()
        self._init_state()

    def _init_state(self) -> None:
        self.state = CLOSED
        self.consecutive_failures = 0
        self.latency_ewma_s: Optional[float] = None
        self.trips = 0
        self.recoveries = 0
        self.last_trip_reason: Optional[str] = None
        self._open_until = 0.0
        self._now = 0.0

    # ------------------------------------------------------------------
    @property
    def dispatchable(self) -> bool:
        """May new work be routed here?  Open == no; half-open == yes (the
        probe); callers must ``tick(now)`` first so open -> half-open
        transitions happen on the driver's clock, not a hidden one."""
        with self._lock:
            return self.state != OPEN

    def tick(self, now: float) -> str:
        """Advance the breaker's clock (monotone).  An open breaker whose
        cooldown has elapsed transitions to half-open — the next dispatch
        becomes the recovery probe.  Returns the post-tick state."""
        with self._lock:
            self._now = max(self._now, now)
            if self.state == OPEN and self._now >= self._open_until:
                self.state = HALF_OPEN
            return self.state

    def _trip(self, reason: str) -> None:
        self.state = OPEN
        self.trips += 1
        self.last_trip_reason = reason
        self.consecutive_failures = 0
        self._open_until = self._now + self.cooldown_s

    def record_success(self, latency_s: float, now: float) -> None:
        """One completed batch.  Resets the failure streak; in half-open
        this is the probe succeeding (recovery).  A closed breaker with
        ``latency_trip_s`` set trips when the latency EWMA crosses it —
        the tier is *stalling*, which a raise-based detector never sees."""
        with self._lock:
            self._now = max(self._now, now)
            self.consecutive_failures = 0
            if self.state == HALF_OPEN:
                self.state = CLOSED
                self.recoveries += 1
                # the stale pre-trip EWMA must not instantly re-trip a
                # freshly recovered tier: restart it from the probe
                self.latency_ewma_s = float(latency_s)
                return
            a = self.ewma_alpha
            self.latency_ewma_s = float(latency_s) if \
                self.latency_ewma_s is None else \
                a * float(latency_s) + (1.0 - a) * self.latency_ewma_s
            if (self.state == CLOSED and self.latency_trip_s is not None
                    and self.latency_ewma_s > self.latency_trip_s):
                self._trip("latency")

    def record_failure(self, now: float) -> None:
        """One failed batch.  Half-open: the probe failed — re-open for
        another cooldown.  Closed: count toward the consecutive-failure
        threshold.  Open (in-flight work finishing after the trip): extend
        the cooldown from ``now``."""
        with self._lock:
            self._now = max(self._now, now)
            if self.state == HALF_OPEN:
                self._trip("probe-failure")
            elif self.state == OPEN:
                self._open_until = max(self._open_until,
                                       self._now + self.cooldown_s)
            else:
                self.consecutive_failures += 1
                if self.consecutive_failures >= self.failure_threshold:
                    self._trip("failures")

    def reset(self) -> None:
        """Fresh closed breaker (counters included) — one DES run's state."""
        with self._lock:
            self._init_state()

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            return {
                "state": self.state,
                "consecutive_failures": self.consecutive_failures,
                "latency_ewma_s": self.latency_ewma_s,
                "trips": self.trips,
                "recoveries": self.recoveries,
                "last_trip_reason": self.last_trip_reason,
            }

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"CircuitBreaker(state={self.state!r}, trips={self.trips}, "
                f"recoveries={self.recoveries})")


class BrownoutController:
    """Three-stage overload controller: shed *quality* before shedding
    queries.

    ``QueueManager.dispatch`` feeds every arrival's topology utilization
    (queued + in-flight over total calibrated depth) into ``observe``; the
    EWMA of those samples drives the stage machine:

    * **normal** — EWMA below ``degraded_at``: no behaviour change.
    * **degraded** — EWMA crossed ``degraded_at``: candidate tiers are
      re-ranked to prefer the quantized (W8A8/int8) tier at equal backlog
      (``reorder``) and effective deadlines are tightened by
      ``deadline_scale`` (``tighten``) so queued work that cannot finish in
      time expires early instead of burning device time late.  Cache tiers
      are consulted *before* brownout in dispatch, so repeat-heavy traffic
      keeps being served from cache for free at every stage.
    * **shedding** — EWMA crossed ``shedding_at``: everything above, plus
      the admission controller switches to its shedding watermark and
      rejects any query its fits predict late (see
      :class:`repro.core.admission.AdmissionController`).

    De-escalation applies ``hysteresis``: the EWMA must fall below the
    stage's entry threshold minus the hysteresis band before the controller
    steps down, so a flapping load signal does not flap the stage.

    Clock-free like :class:`CircuitBreaker`: ``now`` is only tracked for
    the snapshot/tighten math, never read from a wall clock, and the EWMA
    advances on dispatch events only — so the DES replays a seeded stage
    sequence deterministically and the pinned-GIL parity bursts see the
    identical transitions in the threaded engine.
    """

    def __init__(self, degraded_at: float = 0.7, shedding_at: float = 0.9,
                 ewma_alpha: float = 0.3, hysteresis: float = 0.1,
                 deadline_scale: float = 0.5):
        if not 0.0 < degraded_at < shedding_at:
            raise ValueError("need 0 < degraded_at < shedding_at")
        if not 0.0 < ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        if hysteresis < 0.0:
            raise ValueError("hysteresis must be >= 0")
        if not 0.0 < deadline_scale <= 1.0:
            raise ValueError("deadline_scale must be in (0, 1]")
        self.degraded_at = degraded_at
        self.shedding_at = shedding_at
        self.ewma_alpha = ewma_alpha
        self.hysteresis = hysteresis
        self.deadline_scale = deadline_scale
        self._lock = threading.Lock()
        self._init_state()

    def _init_state(self) -> None:
        self.stage = NORMAL
        self.utilization_ewma: Optional[float] = None
        self.transitions = 0

    # ------------------------------------------------------------------
    def observe(self, utilization: float, now: float = 0.0) -> str:
        """Fold one dispatch-time utilization sample into the EWMA and
        return the (possibly new) stage.  Escalation is immediate on the
        updated EWMA; de-escalation waits out the hysteresis band."""
        with self._lock:
            x = max(0.0, float(utilization))
            a = self.ewma_alpha
            self.utilization_ewma = x if self.utilization_ewma is None \
                else a * x + (1.0 - a) * self.utilization_ewma
            u = self.utilization_ewma
            if u >= self.shedding_at:
                target = SHEDDING
            elif u >= self.degraded_at:
                target = DEGRADED
            else:
                target = NORMAL
            cur = _STAGES.index(self.stage)
            new = _STAGES.index(target)
            if new < cur:
                # stepping down: require clearance below the *current*
                # stage's entry threshold by the hysteresis band
                entry = self.shedding_at if self.stage == SHEDDING \
                    else self.degraded_at
                if u >= entry - self.hysteresis:
                    return self.stage
            if target != self.stage:
                self.stage = target
                self.transitions += 1
            return self.stage

    def tighten(self, deadline: Optional[float], now: float) -> Optional[float]:
        """Degraded/shedding deadline tightening: scale the *remaining*
        budget by ``deadline_scale`` so predictably-late work expires in
        the queue early.  Identity in the normal stage or without a
        deadline."""
        with self._lock:
            if deadline is None or self.stage == NORMAL:
                return deadline
            remaining = max(0.0, float(deadline) - float(now))
            return float(now) + remaining * self.deadline_scale

    def reorder(self, names: Sequence[str], qm) -> Sequence[str]:
        """Degraded/shedding candidate re-rank: stable-sort the policy's
        candidate tiers by backlog, breaking ties in favour of quantized
        tiers — at equal backlog the cheap W8A8 tier absorbs the overload
        first.  Identity in the normal stage (the policy's order stands)."""
        with self._lock:
            if self.stage == NORMAL:
                return names
        spec = {t.name: t for t in qm.tiers}
        return sorted(
            names,
            key=lambda n: (len(qm.queues[n]) if n in qm.queues else 0,
                           0 if getattr(spec.get(n), "quantized", False)
                           else 1))

    def reset(self) -> None:
        """Fresh normal-stage controller — one DES run's state."""
        with self._lock:
            self._init_state()

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            return {
                "stage": self.stage,
                "utilization_ewma": self.utilization_ewma,
                "transitions": self.transitions,
            }

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"BrownoutController(stage={self.stage!r}, "
                f"ewma={self.utilization_ewma}, "
                f"transitions={self.transitions})")
