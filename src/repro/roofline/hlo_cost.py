"""Trip-count-aware cost model over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers program (ours: 24-80 layer stacks, flash-attention chunk
scans, CE chunk scans) is undercounted by the trip count (we measured 16x on
a 24-layer model).  This module re-derives FLOPs / HBM bytes / collective
bytes by parsing the per-device optimized HLO, walking the call graph, and
multiplying ``while`` bodies by their ``known_trip_count``.

Costing rules (roofline-grade, not cycle-accurate):
* flops: ``dot``/``convolution`` = 2 x prod(result_dims) x prod(contracted lhs
  dims); elementwise/transcendental/reduce = prod(result or operand) — noise
  next to the dots but included for completeness.  Fusion computations are
  descended into for flops (a fused dot still runs on the MXU).
* bytes: each top-level op in a sequential computation reads its operands and
  writes its result (fusions count as one op — their internals live in
  registers/VMEM).  ``dynamic-update-slice`` counts the update slice, not the
  full buffer (XLA updates in place — decisive for KV-cache decode steps).
* collectives: result bytes per kind, x trip count when inside a loop.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*.*\{\s*$")
_OPCODE_RE = re.compile(r"^([a-z][a-z0-9\-]*)\((.*)$")


def _parse_op_line(line: str):
    """Robustly split '%name = <type> opcode(args), attrs' (types may be
    arbitrarily nested tuples, which defeats a regex)."""
    s = line.strip()
    is_root = s.startswith("ROOT ")
    if is_root:
        s = s[5:]
    if not s.startswith("%") or "=" not in s:
        return None
    eq = s.index("=")
    name = s[:eq].strip().lstrip("%")
    rest = s[eq + 1:].strip()
    depth = 0
    j = -1
    for i, ch in enumerate(rest):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == " " and depth == 0:
            j = i
            break
    if j < 0:
        return None
    type_str, tail = rest[:j], rest[j + 1:]
    m = _OPCODE_RE.match(tail)
    if not m:
        return None
    return name, type_str, m.group(1), m.group(2), is_root
_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*((?:\([^)]*\))|(?:[a-z][a-z0-9]*\[[^\]]*\]))")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"')
_CALLED = re.compile(r"(?:calls|to_apply|body|condition|true_computation|"
                     r"false_computation)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")

_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "sine", "cosine", "rsqrt", "sqrt", "negate",
    "abs", "floor", "ceil", "round-nearest-afz", "logistic", "expm1", "log1p",
    "atan2", "remainder", "select", "clamp", "compare", "convert",
}
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "iota", "partition-id", "replica-id", "reshape"}
_CONTROL_OPS = {"while", "conditional", "call", "fusion", "async-start",
                "async-update", "async-done", "custom-call"}


def _parse_shape(type_str: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    """Returns (total_bytes, [(dtype, dims), ...]) for a possibly-tuple type."""
    shapes = []
    total = 0
    for dtype, dims_s in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in dims_s.split(",") if d]
        n = 1
        for d in dims:
            n *= d
        shapes.append((dtype, dims))
        total += n * _DTYPE_BYTES[dtype]
    return total, shapes


def _split_args(argstr: str) -> Tuple[List[str], str, str]:
    """Split 'a, b, c), attr=...' into (operand names, attr tail, raw args)."""
    depth = 0
    for i, ch in enumerate(argstr):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            if depth == 0:
                operands = argstr[:i]
                attrs = argstr[i + 1:]
                names = re.findall(r"%([\w.\-]+)", operands)
                return names, attrs, operands
            depth -= 1
    return re.findall(r"%([\w.\-]+)", argstr), "", argstr


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str
    bytes_total: int
    dims: List[Tuple[str, List[int]]]
    raw_args: str = ""
    is_root: bool = False


@dataclass
class Computation:
    name: str
    is_entry: bool
    ops: List[Op] = field(default_factory=list)
    shapes: Dict[str, Op] = field(default_factory=dict)
    root: Optional[str] = None


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k in COLLECTIVE_KINDS:
            self.coll[k] += other.coll[k]
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m,
                    {k: v * m for k, v in self.coll.items()})

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


def parse_module(hlo_text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo_text.splitlines():
        hdr = _COMP_HDR.match(line.strip()) if ("{" in line and "->" in line) else None
        if hdr:
            cur = Computation(hdr.group(2), bool(hdr.group(1)))
            comps[cur.name] = cur
            # parameters declared in the header get shapes too
            for pname, ptype in _PARAM_RE.findall(hdr.group(3)):
                b, dims = _parse_shape(ptype)
                cur.shapes[pname] = Op(pname, ptype, "parameter", [], "", b, dims)
            continue
        if cur is None:
            continue
        parsed = _parse_op_line(line)
        if parsed is None:
            continue
        name, type_str, opcode, rest, is_root = parsed
        operands, attrs, raw_args = _split_args(rest)
        b, dims = _parse_shape(type_str)
        op = Op(name, type_str, opcode, operands, attrs, b, dims, raw_args,
                is_root)
        cur.ops.append(op)
        cur.shapes[name] = op
        if is_root:
            cur.root = name
    return comps


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = 1
    for _, dims in op.dims:
        for d in dims:
            out_elems *= d
    contract = 1
    mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    lhs = comp.shapes.get(op.operands[0]) if op.operands else None
    if mm and lhs and lhs.dims:
        ldims = lhs.dims[0][1]
        for idx in mm.group(1).split(","):
            if idx and int(idx) < len(ldims):
                contract *= ldims[int(idx)]
    return 2.0 * out_elems * contract


def _conv_flops(op: Op, comp: Computation) -> float:
    out_elems = 1
    for _, dims in op.dims:
        for d in dims:
            out_elems *= d
    rhs = comp.shapes.get(op.operands[1]) if len(op.operands) > 1 else None
    kernel = 1
    if rhs and rhs.dims:
        for d in rhs.dims[0][1]:
            kernel *= d
    # per output element: kernel_elems/out_features multiply-adds (approx)
    return 2.0 * out_elems * max(kernel, 1) ** 0.5  # coarse; convs are stubs here


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps = parse_module(hlo_text)
        self._memo: Dict[str, Cost] = {}
        self.entry = next((c for c in self.comps.values() if c.is_entry), None)

    _SLICE_OPS = ("dynamic-slice", "slice", "gather")
    _EXTERNAL = ("parameter", "get-tuple-element", "constant")

    def _fusion_param_read(self, fused_name: str, arg_index: int,
                           full_bytes: int) -> float:
        """Bytes a fusion reads from its arg_index-th operand: if every use
        inside the fused computation is slice-like, the slices; else full."""
        comp = self.comps.get(fused_name)
        if comp is None:
            return float(full_bytes)
        pname = None
        for op in comp.ops:
            if op.opcode == "parameter":
                m = re.match(r"\s*(\d+)", op.raw_args)
                if m and int(m.group(1)) == arg_index:
                    pname = op.name
                    break
        if pname is None:
            return float(full_bytes)
        consumers = [o for o in comp.ops if pname in o.operands]
        if not consumers:
            return 0.0
        total = 0.0
        for c in consumers:
            if c.opcode in self._SLICE_OPS:
                total += c.bytes_total
            elif (c.opcode == "dynamic-update-slice" and c.operands
                  and c.operands[0] == pname):
                # in-place update of the big buffer: read ~update-size only
                upd = comp.shapes.get(c.operands[1]) if len(c.operands) > 1 else None
                total += upd.bytes_total if upd else full_bytes
            else:
                return float(full_bytes)
        return float(total)

    def _fusion_write_bytes(self, op: Op) -> float:
        """Result write bytes of a fusion; a root dynamic-update-slice writes
        its update in place, not the whole buffer."""
        c = _CALLED.search(op.attrs)
        fused = self.comps.get(c.group(1)) if c else None
        if fused is None or fused.root is None:
            return float(op.bytes_total)

        def one(o: Optional[Op]) -> float:
            if o is None:
                return 0.0
            if o.opcode == "dynamic-update-slice":
                upd = fused.shapes.get(o.operands[1]) if len(o.operands) > 1 else None
                return float(upd.bytes_total if upd else o.bytes_total)
            return float(o.bytes_total)

        root = fused.shapes.get(fused.root)
        if root is not None and root.opcode == "tuple":
            return sum(one(fused.shapes.get(n)) for n in root.operands)
        return one(root)

    def _external_read_bytes(self, comp: Computation, op: Op) -> float:
        total = 0.0
        for idx, oname in enumerate(op.operands):
            src = comp.shapes.get(oname)
            if src is None or src.opcode not in self._EXTERNAL:
                continue
            if src.opcode == "constant":
                continue
            full = src.bytes_total
            if op.opcode in self._SLICE_OPS:
                total += op.bytes_total if idx == 0 else 0
            elif op.opcode == "fusion":
                c = _CALLED.search(op.attrs)
                if c:
                    total += self._fusion_param_read(c.group(1), idx, full)
                else:
                    total += full
            else:
                total += full
        return total

    # -- flops inside fusion computations (descend, x1) --------------------
    def _flops_only(self, cname: str) -> float:
        comp = self.comps.get(cname)
        if comp is None:
            return 0.0
        total = 0.0
        for op in comp.ops:
            if op.opcode == "dot":
                total += _dot_flops(op, comp)
            elif op.opcode == "convolution":
                total += _conv_flops(op, comp)
            elif op.opcode in _ELEMENTWISE_FLOP_OPS:
                b = 1
                for _, dims in op.dims:
                    n = 1
                    for d in dims:
                        n *= d
                    b += n
                total += b
            elif op.opcode in ("fusion", "call"):
                c = _CALLED.search(op.attrs)
                if c:
                    total += self._flops_only(c.group(1))
        return total

    def cost(self, cname: Optional[str] = None) -> Cost:
        if cname is None:
            if self.entry is None:
                return Cost()
            cname = self.entry.name
        if cname in self._memo:
            return self._memo[cname]
        comp = self.comps.get(cname)
        out = Cost()
        if comp is None:
            return out
        self._memo[cname] = out  # guard (no recursion in valid HLO anyway)
        for op in comp.ops:
            oc = op.opcode
            if oc in _FREE_OPS:
                continue
            if oc == "while":
                trip_m = _TRIP_RE.search(op.attrs)
                trip = int(trip_m.group(1)) if trip_m else 1
                called = dict.fromkeys(_CALLED.findall(op.attrs))
                for sub in called:
                    out += self.cost(sub).scaled(trip)
                continue
            if oc == "conditional":
                branches = _CALLED.findall(op.attrs)
                bm = _BRANCHES.search(op.attrs)
                if bm:
                    branches += re.findall(r"%([\w.\-]+)", bm.group(1))
                costs = [self.cost(b) for b in dict.fromkeys(branches)]
                if costs:
                    best = max(costs, key=lambda c: c.flops + c.bytes)
                    out += best
                continue
            if oc == "call" or oc.startswith("async"):
                c = _CALLED.search(op.attrs)
                if c:
                    out += self.cost(c.group(1))
                continue

            # ---- leaf-ish ops: bytes ----
            # write-once + read-external model: every op writes its result;
            # reads are counted only for EXTERNAL buffers (computation
            # parameters / tuple elements of the loop carry) because internal
            # producer->consumer traffic is already counted at the producer's
            # write.  Reads through slice-like consumers count the slice, not
            # the whole buffer (a scan slicing one layer from stacked weights
            # reads one layer's bytes, not 24 layers').
            if oc == "dynamic-update-slice":
                upd = comp.shapes.get(op.operands[1]) if len(op.operands) > 1 else None
                out.bytes += 2.0 * (upd.bytes_total if upd else op.bytes_total)
            else:
                out.bytes += (self._fusion_write_bytes(op) if oc == "fusion"
                              else op.bytes_total)
                out.bytes += self._external_read_bytes(comp, op)

            # ---- collectives ----
            base = oc[:-6] if oc.endswith("-start") else oc
            if base in COLLECTIVE_KINDS and not oc.endswith("-done"):
                out.coll[base] += op.bytes_total

            # ---- flops ----
            if oc == "dot":
                out.flops += _dot_flops(op, comp)
            elif oc == "convolution":
                out.flops += _conv_flops(op, comp)
            elif oc == "fusion":
                c = _CALLED.search(op.attrs)
                if c:
                    out.flops += self._flops_only(c.group(1))
            elif oc in _ELEMENTWISE_FLOP_OPS or oc in ("reduce", "reduce-window"):
                n = 1
                for _, dims in op.dims:
                    for d in dims:
                        n *= d
                out.flops += n
        self._memo[cname] = out
        return out


def analyse_hlo(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).cost()
