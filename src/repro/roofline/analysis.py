"""Roofline-term derivation from a compiled dry-run artifact.

Three terms, all in seconds (per step, per device):

    compute    = HLO_FLOPs / peak_FLOPs_per_chip
    memory     = HLO_bytes_accessed / HBM_bandwidth
    collective = collective_result_bytes / ICI_link_bandwidth

``compiled.cost_analysis()`` reports the PER-DEVICE partitioned program (we
verified: a 64-way-sharded matmul reports total/64 flops), so no division by
chip count is needed.  Collective bytes are NOT in cost_analysis — we parse
the optimized HLO and sum the result-shape bytes of every collective op.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict

# TPU v5e hardware constants (per chip) — see system spec.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# result shapes of a collective op line:  %x = (f32[8,128]{1,0}, ...) all-reduce-start(
_OP_LINE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],{}\s/#_\.]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.IGNORECASE)
_SHAPE = re.compile(r"(pred|[a-z]+\d+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes in the (per-device) optimized HLO.

    ``-done`` ops repeat the ``-start`` result; count only starts + sync ops.
    """
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_LINE.search(line)
        if not m:
            continue
        out[m.group(2).lower()] += _shape_bytes(m.group(1))
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    bytes_accessed: float        # per-device HLO bytes
    coll_bytes: Dict[str, int]   # per-device collective result bytes
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float           # 6ND / 2ND "useful" flops, whole model
    useful_ratio: float          # model_flops / (flops * n_devices)

    def as_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        return d


def analyse(compiled, n_devices: int, model_flops: float) -> Roofline:
    """Derive roofline terms from the compiled per-device program.

    Uses the trip-count-aware HLO walker (hlo_cost.py) because XLA's own
    cost_analysis counts while-loop bodies once (measured 16x undercount on a
    24-layer scanned stack)."""
    from repro.roofline.hlo_cost import analyse_hlo

    c = analyse_hlo(compiled.as_text())
    flops = float(c.flops)
    by = float(c.bytes)
    coll = {k: int(v) for k, v in c.coll.items()}
    total_coll = float(sum(coll.values()))
    terms = {
        "compute": flops / PEAK_FLOPS_BF16,
        "memory": by / HBM_BW,
        "collective": total_coll / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    useful = model_flops / max(flops * n_devices, 1.0)
    return Roofline(
        flops=flops, bytes_accessed=by, coll_bytes=coll,
        compute_s=terms["compute"], memory_s=terms["memory"],
        collective_s=terms["collective"], dominant=dominant,
        model_flops=model_flops, useful_ratio=useful,
    )


def count_params(params_shape, active_moe_fraction: float | None = None,
                 expert_key: str = "ffn") -> Dict[str, float]:
    """Total and active param counts from a ShapeDtypeStruct pytree."""
    import jax
    from jax.tree_util import tree_flatten_with_path, DictKey

    flat, _ = tree_flatten_with_path(params_shape)
    total = 0
    expert = 0
    for path, leaf in flat:
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
        names = [p.key for p in path if isinstance(p, DictKey)]
        # stacked MoE expert weights are 4-D (L, E, ., .)
        if expert_key in names and leaf.ndim >= 3:
            expert += n
    active = total
    if active_moe_fraction is not None and expert:
        active = total - expert + expert * active_moe_fraction
    return {"total": float(total), "active": float(active)}


def model_flops(cfg, shape, params_shape) -> float:
    """Useful-FLOPs yardstick: 6·N·D train, 2·N·D inference (N = active)."""
    frac = (cfg.experts_per_token / cfg.num_experts) if cfg.is_moe else None
    counts = count_params(params_shape, frac)
    n = counts["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
