from repro.data import workload

__all__ = ["workload"]
