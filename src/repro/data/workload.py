"""Synthetic data pipeline: training token streams and serving query loads.

Training: an infinite deterministic stream of zipfian token batches with
next-token labels (no external corpus in this offline container).
Serving: query generators matching the paper's workload (§5.1.3 — default
length 75 tokens, the typical RAG text-segmentation setting; Fig. 5 sweeps
lengths; Fig. 2 diurnal rate curve lives in core.simulator.diurnal_trace).
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class TrainBatchSpec:
    batch: int
    seq_len: int
    vocab_size: int


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int) -> np.ndarray:
    """Zipf-ish distribution over the vocab (natural-language-like ranks)."""
    ranks = rng.zipf(1.3, size=shape)
    return (np.minimum(ranks, vocab - 1)).astype(np.int32)


class TokenStream:
    """Deterministic, restartable training stream: batch dict per step."""

    def __init__(self, spec: TrainBatchSpec, seed: int = 0,
                 extra: Optional[Dict[str, tuple]] = None):
        self.spec = spec
        self.seed = seed
        self.extra = extra or {}
        self._step = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, self._step))
        self._step += 1
        s = self.spec
        toks = _zipf_tokens(rng, (s.batch, s.seq_len + 1), s.vocab_size)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        for name, shape in self.extra.items():
            out[name] = rng.standard_normal((s.batch, *shape)).astype(np.float32)
        return out

    @property
    def step(self) -> int:
        return self._step

    def restore(self, step: int) -> None:
        self._step = step


def query_lengths(n: int, mean: int = 75, jitter: float = 0.0,
                  seed: int = 0) -> List[int]:
    """Paper workload: fixed 75-token queries by default; optional jitter.

    With ``jitter > 0`` lengths are ``Normal(mean, jitter * mean)`` draws
    rounded to the nearest integer and clamped SYMMETRICALLY into
    ``[1, 2 * mean - 1]``: the old path truncated toward zero (biasing every
    draw short) and clamped only the low side, so heavy jitter silently
    shifted the realized mean.  Rounding plus the symmetric window keeps
    the sample mean at ``mean`` no matter how large ``jitter`` gets."""
    if jitter <= 0:
        return [mean] * n
    rng = np.random.default_rng(seed)
    hi = max(1, 2 * mean - 1)
    return [int(np.clip(round(float(x)), 1, hi))
            for x in rng.normal(mean, jitter * mean, size=n)]


def make_queries(n: int, vocab: int, length: int = 75,
                 seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    return [_zipf_tokens(rng, (length,), vocab) for _ in range(n)]


def zipf_queries(n: int, vocab: int, alpha: float = 1.1, unique: int = 64,
                 seed: int = 0, length: int = 75) -> List[np.ndarray]:
    """Deterministic Zipf-skewed repeat-query trace (the cache workload).

    Draws ``n`` queries from a pool of ``unique`` distinct token payloads
    with rank-k probability proportional to ``k ** -alpha`` — the skew real
    query streams show (EdgeRAG's motivating observation): a handful of hot
    queries dominate, the tail is long.  Repeats are the IDENTICAL token
    content (same array object), so an exact-match cache keyed on token
    hashes sees them as hits.  ``alpha ~ 1.1`` with ``unique << n`` yields
    a >= 50% theoretical repeat rate (at most ``unique`` first occurrences
    in ``n`` draws); ``alpha = 0`` degrades to uniform sampling over the
    pool.  Fully deterministic in ``seed`` — reused by the cache microbench
    and the tier-1 suites, same trace every run."""
    if n < 0:
        raise ValueError("n must be >= 0")
    if unique < 1:
        raise ValueError("need at least one unique query")
    if alpha < 0:
        raise ValueError("alpha must be >= 0 (0 == uniform)")
    rng = np.random.default_rng(seed)
    pool = [_zipf_tokens(rng, (length,), vocab) for _ in range(unique)]
    p = np.arange(1, unique + 1, dtype=np.float64) ** -alpha
    p /= p.sum()
    idx = rng.choice(unique, size=n, p=p)
    return [pool[i] for i in idx]


def flash_crowd_trace(n_seconds: int, base_rate: float, burst_mult: float,
                      burst_start: float, burst_len: float,
                      length: int = 75, seed: int = 0
                      ) -> List[Tuple[float, int]]:
    """Flash-crowd arrival trace: baseline Poisson with a seeded
    multiplicative burst window — the overload scenario admission control
    and the capacity planner are sized against.

    Arrivals follow a Poisson process at ``base_rate`` queries/s, except
    inside ``[burst_start, burst_start + burst_len)`` where the rate is
    ``base_rate * burst_mult`` (a link on the front page, a retry storm, a
    failover from a sibling cluster).  Returns sorted ``(time, length)``
    pairs ready for ``ServingSimulator.run`` — same shape as
    ``simulator.diurnal_trace``, and fully deterministic in ``seed`` like
    ``zipf_queries`` so planner sweeps and CI replays see the same crowd.
    """
    if n_seconds < 0:
        raise ValueError("n_seconds must be >= 0")
    if base_rate < 0:
        raise ValueError("base_rate must be >= 0")
    if burst_mult < 1.0:
        raise ValueError("burst_mult must be >= 1 (1 == no burst)")
    if burst_len < 0:
        raise ValueError("burst_len must be >= 0")
    from repro.core.simulator import poisson  # core stays import-light here
    rng = random.Random(seed)
    out: List[Tuple[float, int]] = []
    for s in range(int(n_seconds)):
        rate = base_rate
        if burst_start <= s < burst_start + burst_len:
            rate *= burst_mult
        for _ in range(poisson(rng, rate)):
            out.append((s + rng.random(), length))
    out.sort()
    return out
