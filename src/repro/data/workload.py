"""Synthetic data pipeline: training token streams and serving query loads.

Training: an infinite deterministic stream of zipfian token batches with
next-token labels (no external corpus in this offline container).
Serving: query generators matching the paper's workload (§5.1.3 — default
length 75 tokens, the typical RAG text-segmentation setting; Fig. 5 sweeps
lengths; Fig. 2 diurnal rate curve lives in core.simulator.diurnal_trace).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional

import numpy as np


@dataclass(frozen=True)
class TrainBatchSpec:
    batch: int
    seq_len: int
    vocab_size: int


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int) -> np.ndarray:
    """Zipf-ish distribution over the vocab (natural-language-like ranks)."""
    ranks = rng.zipf(1.3, size=shape)
    return (np.minimum(ranks, vocab - 1)).astype(np.int32)


class TokenStream:
    """Deterministic, restartable training stream: batch dict per step."""

    def __init__(self, spec: TrainBatchSpec, seed: int = 0,
                 extra: Optional[Dict[str, tuple]] = None):
        self.spec = spec
        self.seed = seed
        self.extra = extra or {}
        self._step = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, self._step))
        self._step += 1
        s = self.spec
        toks = _zipf_tokens(rng, (s.batch, s.seq_len + 1), s.vocab_size)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        for name, shape in self.extra.items():
            out[name] = rng.standard_normal((s.batch, *shape)).astype(np.float32)
        return out

    @property
    def step(self) -> int:
        return self._step

    def restore(self, step: int) -> None:
        self._step = step


def query_lengths(n: int, mean: int = 75, jitter: float = 0.0,
                  seed: int = 0) -> List[int]:
    """Paper workload: fixed 75-token queries by default; optional jitter."""
    if jitter <= 0:
        return [mean] * n
    rng = np.random.default_rng(seed)
    return [max(1, int(x)) for x in rng.normal(mean, jitter * mean, size=n)]


def make_queries(n: int, vocab: int, length: int = 75,
                 seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    return [_zipf_tokens(rng, (length,), vocab) for _ in range(n)]
