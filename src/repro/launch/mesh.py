"""Production mesh builders.

Functions, not module-level constants — importing this module never touches
jax device state.  The dry-run entrypoint (launch/dryrun.py) sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` BEFORE importing jax
so these meshes can be built on the CPU-only container.

``jax.sharding.AxisType`` (and ``jax.make_mesh``'s ``axis_types`` kwarg)
only exist on newer jax releases; on older installs the meshes are built
without explicit axis types, which is the same default behaviour.

Every builder validates the requested shape against the available device
count up front: jax's own failure mode is an opaque reshape error from deep
inside ``make_mesh`` ("cannot reshape array of size 1 into shape (16,16)"),
which names neither the mesh nor the fix.  The ``ValueError`` raised here
names both counts so a misconfigured launch (or a degraded host pool) is a
one-line diagnosis.
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence

import jax

try:
    from jax.sharding import AxisType
except ImportError:          # older jax: no AxisType / axis_types kwarg
    AxisType = None


def _require(needed: int, available: int, what: str) -> None:
    """Fail fast with both counts named instead of jax's reshape error."""
    if available < needed:
        raise ValueError(
            f"{what} needs {needed} device(s) but only {available} "
            f"available; set XLA_FLAGS=--xla_force_host_platform_device_"
            f"count={needed} on CPU or shrink the requested topology")


def _mesh(shape, axes, devices=None):
    needed = math.prod(shape)
    available = len(devices) if devices is not None \
        else jax.local_device_count()
    _require(needed, available, f"mesh {dict(zip(axes, shape))}")
    kw = {} if devices is None else {"devices": devices}
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(AxisType.Auto,) * len(axes),
                                 **kw)
        except TypeError:    # AxisType exists but make_mesh predates kwarg
            pass
    return jax.make_mesh(shape, axes, **kw)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (v5e); multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke tests / the real serving engine."""
    return _mesh((1, 1), ("data", "model"))


def make_serve_mesh(devices=None):
    """Data-parallel serving mesh over this host's local devices.

    One embedding tier fans its batches out over every device it was given
    (``('data', 'model')`` axes with the whole device count on ``data``), so
    the serve-mode sharding rules in ``repro.parallel.sharding`` apply
    unchanged: weights resident/replicated, batch sharded over ``data``.
    ``devices=None`` uses all local devices; a single device degrades to
    ``make_host_mesh()`` behaviour.
    """
    devices = list(jax.local_devices() if devices is None else devices)
    if not devices:
        raise ValueError("need at least one device for a serve mesh")
    return _mesh((len(devices), 1), ("data", "model"), devices=devices)


def make_replica_meshes(hosts: int = 1, replicas: int = 1,
                        devices: Optional[Sequence] = None) -> List:
    """Carve a device pool into ``hosts * replicas`` independent serve
    meshes — the hardware side of the multi-replica topology.

    The pool splits into equal contiguous groups, one serve mesh per
    replica, ordered host-major/replica-minor so index ``h * replicas + r``
    is replica ``(h, r)`` — the same order :func:`repro.core.routing.
    replicate` emits its ``TierSpec``s in, so ``zip(replicate(...),
    make_replica_meshes(...))`` pairs each replica tier with its mesh.
    Contiguity keeps a replica's devices on one host when the pool is laid
    out host-major (jax's ``local_devices`` order), which is what makes a
    per-replica breaker a *host* failure domain.

    Degrade rule (mirrors ``replicate`` / ``sharded_model``): ``1 x 1``
    returns ``[make_serve_mesh(devices)]`` — bitwise today's single-replica
    serve mesh.  A pool that does not split evenly raises a ``ValueError``
    naming required vs available counts (never jax's reshape error).
    """
    if hosts < 1 or replicas < 1:
        raise ValueError(f"hosts and replicas must be >= 1, "
                         f"got {hosts}x{replicas}")
    devices = list(jax.local_devices() if devices is None else devices)
    groups = hosts * replicas
    if groups == 1:
        return [make_serve_mesh(devices)]
    _require(groups, len(devices),
             f"replica topology {hosts} host(s) x {replicas} replica(s)")
    if len(devices) % groups:
        raise ValueError(
            f"device pool of {len(devices)} does not split evenly over "
            f"{hosts} host(s) x {replicas} replica(s) = {groups} groups; "
            f"each replica needs an equal device group")
    per = len(devices) // groups
    return [make_serve_mesh(devices[g * per:(g + 1) * per])
            for g in range(groups)]


def mesh_context(mesh):
    """Context manager enabling bare-PartitionSpec sharding constraints.

    ``jax.set_mesh`` on new jax; on older releases entering the ``Mesh``
    itself installs the equivalent resource environment.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
