"""Production mesh builders.

Functions, not module-level constants — importing this module never touches
jax device state.  The dry-run entrypoint (launch/dryrun.py) sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` BEFORE importing jax
so these meshes can be built on the CPU-only container.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (v5e); multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Single-device mesh for CPU smoke tests / the real serving engine."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
