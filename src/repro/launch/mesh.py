"""Production mesh builders.

Functions, not module-level constants — importing this module never touches
jax device state.  The dry-run entrypoint (launch/dryrun.py) sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` BEFORE importing jax
so these meshes can be built on the CPU-only container.

``jax.sharding.AxisType`` (and ``jax.make_mesh``'s ``axis_types`` kwarg)
only exist on newer jax releases; on older installs the meshes are built
without explicit axis types, which is the same default behaviour.
"""
from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType
except ImportError:          # older jax: no AxisType / axis_types kwarg
    AxisType = None


def _mesh(shape, axes, devices=None):
    kw = {} if devices is None else {"devices": devices}
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(AxisType.Auto,) * len(axes),
                                 **kw)
        except TypeError:    # AxisType exists but make_mesh predates kwarg
            pass
    return jax.make_mesh(shape, axes, **kw)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (v5e); multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke tests / the real serving engine."""
    return _mesh((1, 1), ("data", "model"))


def make_serve_mesh(devices=None):
    """Data-parallel serving mesh over this host's local devices.

    One embedding tier fans its batches out over every device it was given
    (``('data', 'model')`` axes with the whole device count on ``data``), so
    the serve-mode sharding rules in ``repro.parallel.sharding`` apply
    unchanged: weights resident/replicated, batch sharded over ``data``.
    ``devices=None`` uses all local devices; a single device degrades to
    ``make_host_mesh()`` behaviour.
    """
    devices = list(jax.local_devices() if devices is None else devices)
    if not devices:
        raise ValueError("need at least one device for a serve mesh")
    return _mesh((len(devices), 1), ("data", "model"), devices=devices)


def mesh_context(mesh):
    """Context manager enabling bare-PartitionSpec sharding constraints.

    ``jax.set_mesh`` on new jax; on older releases entering the ``Mesh``
    itself installs the equivalent resource environment.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
