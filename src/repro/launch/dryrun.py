import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax (device count is now locked at 512) ---
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import (ASSIGNED_ARCHS, INPUT_SHAPES, get_config, get_shape,
                           shape_supported)
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.models import api
from repro.roofline import analysis
from repro.steps import optim
from repro.steps.inputs import cache_specs, input_specs
from repro.steps.serve import (build_decode_step, build_prefill_step,
                               serve_shardings)
from repro.steps.train import build_train_step, train_shardings


def _mem_dict(mem) -> Dict[str, Any]:
    if mem is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes")
    return {k: getattr(mem, k, None) for k in keys}


def dry_run(arch: str, shape_name: str, *, multi_pod: bool = False,
            verbose: bool = True, opt: str = "") -> Dict[str, Any]:
    """Lower + compile one (arch x shape x mesh) combo; return the record."""
    from repro.perf_flags import parse_opt, reset_flags, set_flags

    reset_flags()
    if opt:
        set_flags(**parse_opt(opt))
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
    }
    if opt:
        rec["opt"] = opt
    ok, why = shape_supported(cfg, shape)
    if not ok:
        rec["skipped"] = why
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    key = jax.random.PRNGKey(0)
    t0 = time.time()
    ctx = mesh_context(mesh)  # bare-PartitionSpec constraints need a context
    ctx.__enter__()

    if shape.kind == "train":
        params_shape = jax.eval_shape(
            lambda: api.init_params(key, cfg, jnp.float32))
        opt_shape = jax.eval_shape(optim.init, params_shape)
        step = build_train_step(cfg, shape, mesh)
        (psh, osh, bsh), out_sh = train_shardings(cfg, shape, mesh, params_shape)
        batch = input_specs(cfg, shape)
        fn = jax.jit(step, in_shardings=(psh, osh, bsh), out_shardings=out_sh,
                     donate_argnums=(0, 1))
        lowered = fn.lower(params_shape, opt_shape, batch)
    elif shape.kind == "prefill":
        params_shape = jax.eval_shape(
            lambda: api.init_params(key, cfg, jnp.bfloat16))
        step = build_prefill_step(cfg, shape, mesh)
        psh, bsh = serve_shardings(cfg, shape, mesh, params_shape)
        batch = input_specs(cfg, shape)
        fn = jax.jit(step, in_shardings=(psh, bsh))
        lowered = fn.lower(params_shape, batch)
    else:  # decode
        from repro.perf_flags import FLAGS
        params_shape = jax.eval_shape(
            lambda: api.init_params(key, cfg, jnp.bfloat16))
        cache_shape = cache_specs(
            cfg, shape,
            cache_dtype=jnp.float32 if FLAGS.cache_f32 else jnp.bfloat16)
        step = build_decode_step(cfg, shape, mesh)
        psh, csh, bsh = serve_shardings(cfg, shape, mesh, params_shape,
                                        cache_shape)
        batch = input_specs(cfg, shape)
        fn = jax.jit(step, in_shardings=(psh, csh, bsh), donate_argnums=(1,))
        lowered = fn.lower(params_shape, cache_shape, batch)

    rec["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    try:
        compiled = lowered.compile()
    finally:
        ctx.__exit__(None, None, None)
    rec["compile_s"] = round(time.time() - t1, 2)

    try:
        rec["memory"] = _mem_dict(compiled.memory_analysis())
    except Exception as e:  # CPU backend may not implement it
        rec["memory"] = {"error": str(e)}

    mf = analysis.model_flops(cfg, shape, params_shape)
    roof = analysis.analyse(compiled, mesh.size, mf)
    rec["roofline"] = roof.as_dict()
    counts = analysis.count_params(
        params_shape,
        (cfg.experts_per_token / cfg.num_experts) if cfg.is_moe else None)
    rec["params_total"] = counts["total"]
    rec["params_active"] = counts["active"]
    if verbose:
        print(f"  lower={rec['lower_s']}s compile={rec['compile_s']}s "
              f"dominant={roof.dominant} "
              f"t_comp={roof.compute_s*1e3:.2f}ms t_mem={roof.memory_s*1e3:.2f}ms "
              f"t_coll={roof.collective_s*1e3:.2f}ms useful={roof.useful_ratio:.2f}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run (lower+compile)")
    ap.add_argument("--arch", default=None, help="arch id (default: all assigned)")
    ap.add_argument("--shape", default=None, help="input shape (default: all four)")
    ap.add_argument("--multi-pod", action="store_true", help="2x16x16 mesh")
    ap.add_argument("--out", default="experiments/dryrun.jsonl")
    ap.add_argument("--opt", default="",
                    help="perf flags, e.g. 'mamba_chunk=16,attn_band_skip=1'")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)

    for arch in archs:
        for shape in shapes:
            tag = f"{arch} x {shape} x {'2x16x16' if args.multi_pod else '16x16'}"
            print(f"[dryrun] {tag}", flush=True)
            try:
                rec = dry_run(arch, shape, multi_pod=args.multi_pod,
                              opt=args.opt)
            except Exception:
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if args.multi_pod else "16x16",
                       "error": traceback.format_exc(limit=20)}
                print(rec["error"], flush=True)
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
            jax.clear_caches()


if __name__ == "__main__":
    main()
