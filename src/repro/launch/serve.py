"""Serving driver: the full WindVE pipeline on this host.

Device detector -> estimator calibration (profiling the REAL local JAX
embedder for the CPU pool and the paper-calibrated model for the NPU pool)
-> queue manager -> threaded engine -> workload replay -> stats.

The real embedding pool runs the device-sharded backend
(``repro.core.sharded_backend``): one tier fans its bucketed batches out
over every local device (a single-device host degrades to the PR 2 bucketed
path), and the §Perf serving flags select the optimized rows::

    PYTHONPATH=src python -m repro.launch.serve --queries 64 --slo 1.0 \
        --opt embed_dtype=bf16,embed_donate=1,embed_async=1 --prewarm

``embed_dtype=int8`` serves the weight-only quantized trunk (int8
projections + fp32 dequant scales via the fused quant matmul, 4x smaller
resident weights, >= 0.99 cosine vs the fp32 oracle); ``int8_w8a8`` also
quantizes the activations per batch (int8 x int8 projections with int32
accumulation, >= 0.98 cosine) — the raw-speed policy wherever the backend
has a native int8 GEMM.  With ``--policy length-aware`` the dispatch
threshold is calibrated from one Eq. 12 fit PER seq-length bucket, so it
tracks the bucketed (and quantized) CPU service curve instead of a
hand-picked constant: a quantized policy's smaller per-query slope
(``beta_s``) shows up in those fits directly and raises the calibrated
offload depth (see ``estimator.quantized_fit``).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import perf_flags
from repro.configs import get_config
from repro.core import adaptive
from repro.core.admission import AdmissionController
from repro.core.bucketing import length_bucket_fn
from repro.core.cache import cache_tier
from repro.core.device_detector import DeviceInventory, detect
from repro.core.estimator import (estimate_depth, estimate_depth_per_bucket,
                                  fanout_probe_points, replica_fits)
from repro.core.health import BrownoutController, CircuitBreaker
from repro.core.routing import (CPU, NPU, CascadePolicy, LeastLoadedPolicy,
                                LengthAwarePolicy, PredictivePolicy, Query,
                                RetryPolicy, RoundRobinPolicy, TierSpec,
                                replicate)
from repro.core.sharded_backend import ShardedEmbedderBackend
from repro.core.simulator import PAPER_DEVICES, profile_fn_for
from repro.core.windve import ModeledBackend, WindVE
from repro.data.workload import make_queries
from repro.models import embedder

POLICIES = {
    "cascade": CascadePolicy,
    "length-aware": LengthAwarePolicy,
    "least-loaded": LeastLoadedPolicy,
    "predictive": PredictivePolicy,
    "round-robin": RoundRobinPolicy,
}

MAX_TOKENS = 96
MIN_SEQ_BUCKET = 16


def build_engine(model: str = "bge-large-zh-v1.5", slo: float = 1.0,
                 smoke: bool = True, heter: bool = True,
                 npu_model: str = "tesla-v100/bge", seed: int = 0,
                 policy: str = "cascade", devices: int = 0,
                 npu_devices: int = 1, prewarm: bool = False,
                 hosts: int = 1, replicas: int = 1):
    cfg = get_config(model)
    if smoke:
        cfg = cfg.smoke()
    params = embedder.init_embedder(jax.random.PRNGKey(seed), cfg)

    det = detect(DeviceInventory(npus=1, cpus=1), heter_requested=heter)
    print(f"[serve] detector: main={det.device_main} aux={det.device_auxiliary} "
          f"heter={det.heter_enable}")

    # the modeled accelerator pool: --npu-devices N fans the tier out over
    # an N-device mesh model (per-device pow2 chunks + gather overhead), so
    # the depth calibrated below fits the curve a sharded deployment shows.
    # --hosts H --replicas R expands this tier into H*R replica tiers, each
    # with its OWN backend instance (independently-failing capacity units);
    # 1x1 stays bitwise the single-replica path.
    npu_dev = PAPER_DEVICES[npu_model]

    def npu_backend(h: int, r: int) -> ModeledBackend:
        return ModeledBackend(npu_dev, embed_dim=cfg.d_model,
                              devices=npu_devices)

    npu_be = npu_backend(0, 0)
    # the real pool: one tier fans out over the local device mesh; dtype /
    # donation / async dispatch follow the embed_* §Perf flags
    local = jax.local_devices()
    cpu_be = ShardedEmbedderBackend(
        cfg, params, max_tokens=MAX_TOKENS,
        devices=local[:devices] if devices else None,
        min_seq_bucket=MIN_SEQ_BUCKET)
    print(f"[serve] embed pool: {cpu_be.name} "
          f"(mesh fan-out over {cpu_be.device_count}/{len(local)} devices)")
    if prewarm:
        n = cpu_be.prewarm(cpu_be.warm_grid(max_batch=16))
        print(f"[serve] prewarmed {n} (B, S) buckets — zero compile stalls")

    # --- §4.2.2: calibrate queue depths with the linear-regression estimator
    # (probing the FAN-OUT model at multiples of the device count, so the
    # fitted line is the sharded tier's service curve, not one device's)
    d_npu, fit_n = estimate_depth(profile_fn_for(npu_be.model),
                                  slo,
                                  probe_points=fanout_probe_points(npu_devices))

    def profile_cpu(c: int) -> float:
        qs = make_queries(c, cfg.vocab_size, length=75, seed=seed)
        batch = [Query(qid=i, payload=q, length=75) for i, q in enumerate(qs)]
        t0 = time.monotonic()
        cpu_be.embed_batch(batch)
        return time.monotonic() - t0

    # probe at multiples of the backend's batch-bucket floor: on an N-device
    # mesh every batch pads up to at least N rows, so probing (1, 2, 4, 8)
    # raw would execute ONE identical shape four times, fit a flat line and
    # return the estimator's unbounded-depth sentinel
    base = max(1, cpu_be.min_batch_bucket)
    d_cpu, fit_c = (estimate_depth(profile_cpu, slo,
                                   probe_points=tuple(base * c
                                                      for c in (1, 2, 4, 8)))
                    if det.heter_enable else (0, None))
    d_npu, d_cpu = max(d_npu, 1), max(d_cpu, 0)
    print(f"[serve] depths: C_NPU={d_npu} (a={fit_n.alpha:.4f} b={fit_n.beta:.3f}) "
          f"C_CPU={d_cpu}" + (f" (a={fit_c.alpha:.4f} b={fit_c.beta:.3f})"
                              if fit_c else ""))

    # the accelerator tier, expanded to hosts x replicas first-class tiers
    # (replicate(spec, 1, 1) returns the original spec untouched): each
    # replica gets its own ModeledBackend — and below its own breaker, its
    # own Eq. 12 fit, and its own admission watermark, because a replica is
    # an independently-failing capacity unit
    npu_tiers = replicate(TierSpec(NPU, d_npu, backend=npu_be),
                          hosts, replicas, backend=npu_backend)
    if len(npu_tiers) > 1:
        print(f"[serve] replicas: {hosts} host(s) x {replicas} = "
              f"{len(npu_tiers)} {NPU} replica tier(s), "
              f"C_total={d_npu * len(npu_tiers)}: "
              + " ".join(t.name for t in npu_tiers))
    # per-replica Eq. 12 fits, keyed by replica tier name — what makes the
    # predictive policy and the admission controller price each replica's
    # backlog against its own service curve
    npu_fits = replica_fits(
        {t.name: t.backend.model for t in npu_tiers},
        probe_points=fanout_probe_points(npu_devices))

    policy_obj = POLICIES[policy]()
    if policy == "predictive":
        # seed the latency-predictive dispatch with the offline Eq. 12 fits
        # (per-tier service curves); the online calibrator attached below
        # refreshes them from live traffic through the batch hook
        policy_obj = PredictivePolicy(
            fits={**npu_fits, **({CPU: fit_c} if fit_c else {})},
            bucket_fn=length_bucket_fn(MIN_SEQ_BUCKET, MAX_TOKENS))
    if policy == "length-aware" and det.heter_enable and d_cpu > 0:
        # one Eq. 12 fit PER seq-length bucket: the long-query threshold is
        # the first bucket whose measured CPU depth collapses to 0, so the
        # policy follows the bucketed (and, under embed_dtype=int8,
        # quantized) service curve instead of the hand-picked default
        def profile_bucket(c: int, length: int) -> float:
            batch = [Query(qid=i, length=length) for i in range(c)]
            cpu_be.embed_batch(batch)    # warm this (B, S) bucket: the fit
            best = float("inf")          # must see service time, not compile
            for _ in range(2):
                t0 = time.monotonic()
                cpu_be.embed_batch(batch)
                best = min(best, time.monotonic() - t0)
            return best

        s, lengths = MIN_SEQ_BUCKET, []
        while s < MAX_TOKENS:
            lengths.append(s)
            s *= 2
        lengths.append(MAX_TOKENS)
        fits = estimate_depth_per_bucket(
            profile_bucket, slo, lengths,
            probe_points=tuple(base * c for c in (1, 2, 4)))
        policy_obj = LengthAwarePolicy.from_bucket_depths(
            {b: d for b, (d, _) in fits.items()})
        print("[serve] per-bucket depths: "
              + " ".join(f"S{b}:C={d}" for b, (d, _) in sorted(fits.items()))
              + f" -> long_threshold={policy_obj.long_threshold}")

    # the topology is a TierSpec list: N tiers are a config change, not a
    # rewrite (e.g. append a little-core CPU pool here)
    tiers = list(npu_tiers)
    if det.heter_enable and d_cpu > 0:
        tiers.append(TierSpec(CPU, d_cpu, backend=cpu_be,
                              bucket_fn=length_bucket_fn(MIN_SEQ_BUCKET,
                                                         MAX_TOKENS)))
    # --opt cache=N[,cache_bytes=M]: the zero-cost tier at the head of the
    # topology — exact-match hits bypass every device queue entirely
    flags = perf_flags.FLAGS
    if flags.cache > 0:
        tiers.insert(0, cache_tier(flags.cache,
                                   flags.cache_bytes or None))
        print(f"[serve] cache tier: {flags.cache} entries"
              + (f", {flags.cache_bytes} bytes" if flags.cache_bytes else "")
              + " (exact-match LRU at the head of the topology)")
    # --opt breaker=N[,breaker_cooldown_ms=M]: per-tier circuit breakers —
    # N consecutive batch failures trip a tier out of dispatch until its
    # half-open probe recovers; every policy routes around it transparently
    if flags.breaker > 0:
        for t in tiers:
            if t.cache is None:
                t.breaker = CircuitBreaker(
                    failure_threshold=flags.breaker,
                    cooldown_s=flags.breaker_cooldown_ms / 1e3)
        print(f"[serve] breakers: trip after {flags.breaker} consecutive "
              f"failures, cooldown {flags.breaker_cooldown_ms}ms")
    # --opt retries=N[,retry_backoff_ms=M] + deadline_ms=D: failed batches
    # re-dispatch through the policy path; overdue queued queries expire
    retry = RetryPolicy(max_retries=flags.retries,
                        backoff_s=flags.retry_backoff_ms / 1e3)
    deadline_s = flags.deadline_ms / 1e3 if flags.deadline_ms > 0 else None
    if flags.retries or deadline_s is not None:
        print(f"[serve] fault tolerance: retries={flags.retries} "
              f"backoff={flags.retry_backoff_ms}ms "
              f"deadline={flags.deadline_ms or 'none'}ms")
    # --opt admission=on[,reject_cost=X,watermark=N] + brownout=on: the
    # overload-control pair.  Quantized serving paths mark their tier so
    # brownout degradation can prefer them at equal backlog.
    if flags.embed_dtype.startswith("int8"):
        for t in tiers:
            if t.cache is None and t.backend is cpu_be:
                t.quantized = True
    admission = None
    if flags.admission:
        admission = AdmissionController(
            fits={**npu_fits, **({CPU: fit_c} if fit_c else {})},
            slo_s=slo, reject_cost=flags.reject_cost,
            watermark=flags.watermark)
        print(f"[serve] admission control: reject_cost={flags.reject_cost} "
              f"watermark={flags.watermark} "
              f"(priced against the calibrated Eq. 12 fits)")
    brownout = None
    if flags.brownout:
        brownout = BrownoutController()
        print(f"[serve] brownout: degraded@{brownout.degraded_at} "
              f"shedding@{brownout.shedding_at} "
              f"deadline_scale={brownout.deadline_scale}")
    engine = WindVE(tiers=tiers, policy=policy_obj, retry=retry,
                    default_deadline_s=deadline_s,
                    admission=admission, brownout=brownout)
    if policy == "predictive":
        # live fits: every completed batch feeds the calibrator; every refit
        # streams fresh per-tier (and per-bucket) curves into the policy
        adaptive.attach(engine, adaptive.OnlineCalibrator(slo),
                        policy=policy_obj,
                        bucket_fn=length_bucket_fn(MIN_SEQ_BUCKET,
                                                   MAX_TOKENS))
    return engine, cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="bge-large-zh-v1.5")
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--slo", type=float, default=1.0)
    ap.add_argument("--length", type=int, default=75)
    ap.add_argument("--no-heter", action="store_true",
                    help="disable CPU offloading (the paper's baseline)")
    ap.add_argument("--policy", default="cascade", choices=sorted(POLICIES),
                    help="dispatch policy (cascade == paper Algorithm 1)")
    ap.add_argument("--opt", default="",
                    help="perf flags, e.g. embed_dtype=int8_w8a8,embed_async=1"
                         ",cache=4096,cache_bytes=0 "
                         "(embed_dtype: fp32|bf16|int8|int8_w8a8; cache=N "
                         "puts an N-entry exact-match embedding cache at "
                         "the head of the dispatch topology); fault "
                         "tolerance: deadline_ms=N,retries=N,"
                         "retry_backoff_ms=N,breaker=N,breaker_cooldown_ms=N"
                         "; overload control: admission=on,reject_cost=X,"
                         "watermark=N,brownout=on")
    ap.add_argument("--devices", type=int, default=0,
                    help="devices the embed tier fans out over (0 = all)")
    ap.add_argument("--npu-devices", type=int, default=1,
                    help="devices the MODELED accelerator tier fans out "
                         "over (DES-calibrated Eq. 12 fan-out curve)")
    ap.add_argument("--hosts", type=int, default=1,
                    help="hosts the accelerator tier replicates across; "
                         "each host carries --replicas replica tiers "
                         "(1x1 = today's single-replica path)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="accelerator replicas per host — each an "
                         "independently-failing tier with its own queue, "
                         "breaker, and Eq. 12 fit")
    ap.add_argument("--prewarm", action="store_true",
                    help="compile the (B, S) bucket grid before serving")
    args = ap.parse_args()

    if args.opt:
        perf_flags.set_flags(**perf_flags.parse_opt(args.opt))
    engine, cfg = build_engine(args.model, args.slo, heter=not args.no_heter,
                               policy=args.policy, devices=args.devices,
                               npu_devices=args.npu_devices,
                               prewarm=args.prewarm,
                               hosts=args.hosts, replicas=args.replicas)
    queries = make_queries(args.queries, cfg.vocab_size, args.length)
    t0 = time.monotonic()
    futs = [engine.submit(payload=q, length=args.length) for q in queries]
    done, failures = [], []
    for f in futs:
        if f is None:
            continue
        try:
            done.append(f.result(timeout=60))
        except Exception as e:       # ServeError / DeadlineExceeded
            failures.append(e)
    wall = time.monotonic() - t0
    s = engine.stats
    print(f"[serve] {args.queries} queries in {wall:.2f}s: "
          f"accepted={s.accepted} rejected(BUSY)={s.rejected} "
          f"completed={len(done)} failed={len(failures)}")
    if any(s.rejections.values()) or s.brownout_transitions:
        rej = " ".join(f"{k}={v}" for k, v in sorted(s.rejections.items())
                       if v)
        bro = " ".join(f"->{k}x{v}" for k, v in
                       sorted(s.brownout_transitions.items()))
        print(f"[serve] overload: rejections {rej or 'none'}"
              + (f"  brownout {bro}" if bro else ""))
    if failures or s.deadline_misses or s.backend_errors or s.retries:
        print(f"[serve] faults: deadline_misses="
              f"{sum(s.deadline_misses.values())} "
              f"retries={sum(s.retries.values())} "
              f"backend_errors={sum(s.backend_errors.values())} "
              f"breaker trips={sum(s.breaker_trips.values())} "
              f"recoveries={sum(s.breaker_recoveries.values())}")
    print(f"[serve] per-device: {s.per_device}  "
          f"p50={s.p(50):.3f}s p99={s.p(99):.3f}s  "
          f"SLO({args.slo}s) violations="
          f"{sum(1 for l in s.latencies if l > args.slo)}")
    if args.hosts * args.replicas > 1:
        # replica-aware summary: per-replica counters rolled up by logical
        # tier, so imbalance (and a quarantined replica) is visible at a
        # glance instead of buried in @hXrY-keyed raw counters
        for base, g in sorted(s.replica_rollup().items()):
            if len(g["replicas"]) < 2:
                continue
            split = g.get("dispatched_by_replica", {})
            print(f"[serve] replicas[{base}]: dispatched="
                  f"{g.get('dispatched', 0)} completed="
                  f"{g.get('completed', 0)} over {len(g['replicas'])} "
                  f"replicas  ["
                  + " ".join(f"{n}={split.get(n, 0)}"
                             for n in g["replicas"]) + "]")
    tails = "  ".join(
        f"{t}: p95={s.batch_p(95, t)*1e3:.1f}ms"
        for t in sorted(s.tier_batch_latencies))
    print(f"[serve] batch service tail: p50={s.batch_p(50)*1e3:.1f}ms "
          f"p95={s.batch_p(95)*1e3:.1f}ms p99={s.batch_p(99)*1e3:.1f}ms "
          f"over {len(s.batch_latencies)} batches  [{tails}]")
    if s.cache_hits or s.cache_misses:
        print(f"[serve] cache: hit-rate={s.cache_hit_rate():.1%} "
              f"hits={sum(s.cache_hits.values())} "
              f"misses={sum(s.cache_misses.values())} "
              f"inserts={sum(s.cache_inserts.values())} "
              f"evictions={sum(s.cache_evictions.values())} "
              f"staleness p50={s.cache_staleness(50):.2f}s")
    print(f"[serve] max concurrency C = {engine.max_concurrency}")
    engine.shutdown()
    print(f"[serve] clean shutdown: {engine.stats.clean_shutdown}")


if __name__ == "__main__":
    main()
