"""Training driver: real steps on the host mesh (reduced configs) or a
production-mesh launch on TPU.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --smoke --steps 20 --batch 8 --seq 128 --ckpt /tmp/ck.npz
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.workload import TokenStream, TrainBatchSpec
from repro.launch.mesh import (make_host_mesh, make_production_mesh,
                               mesh_context)
from repro.models import api
from repro.steps import checkpoint, optim
from repro.steps.train import build_train_step, train_shardings


def train(arch: str, steps: int, batch: int, seq: int, smoke: bool = True,
          ckpt: str | None = None, resume: str | None = None,
          lr: float = 3e-4, log_every: int = 10, seed: int = 0,
          production_mesh: bool = False):
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    shape = ShapeConfig("cli", seq_len=seq, global_batch=batch, kind="train")
    mesh = make_production_mesh() if production_mesh else make_host_mesh()

    key = jax.random.PRNGKey(seed)
    params = api.init_params(key, cfg)
    opt_state = optim.init(params)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"batch={batch} seq={seq} steps={steps}")

    extra = {}
    if cfg.frontend == "vision":
        extra["patches"] = (cfg.num_patches, cfg.d_model)
    if cfg.frontend == "audio":
        extra["frames"] = (cfg.num_frames, cfg.d_model)
    text = seq - cfg.num_patches if cfg.frontend == "vision" else seq
    stream = TokenStream(TrainBatchSpec(batch, text, cfg.vocab_size),
                         seed=seed, extra=extra)

    start = 0
    if resume:
        (params, opt_state), meta = checkpoint.load(resume, (params, opt_state))
        start = int(meta.get("step", 0))
        stream.restore(start)
        print(f"[train] resumed from {resume} at step {start}")

    step_fn = jax.jit(build_train_step(
        cfg, shape, mesh, optim.AdamWConfig(lr=lr)), donate_argnums=(0, 1))

    losses = []
    with mesh_context(mesh):
        t0 = time.time()
        for i in range(start, start + steps):
            batch_np = next(stream)
            params, opt_state, metrics = step_fn(params, opt_state, batch_np)
            losses.append(float(metrics["loss"]))
            if (i + 1) % log_every == 0 or i == start:
                dt = (time.time() - t0) / max(1, len(losses))
                print(f"  step {i+1}: loss={losses[-1]:.4f} "
                      f"ce={float(metrics['ce']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.2f} "
                      f"({dt*1e3:.0f} ms/step)")
    if ckpt:
        checkpoint.save(ckpt, (params, opt_state),
                        {"step": start + steps, "arch": cfg.name})
        print(f"[train] checkpoint -> {ckpt}")
    return params, opt_state, losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="use the full (not smoke) config")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()
    train(args.arch, args.steps, args.batch, args.seq, smoke=not args.full,
          ckpt=args.ckpt, resume=args.resume, lr=args.lr)


if __name__ == "__main__":
    main()
