"""granite-moe-3b-a800m — IBM Granite 3.0 MoE, 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    arch_type="moe",
    block="attn",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,                 # per-expert hidden
    vocab_size=49155,
    num_experts=40,
    experts_per_token=8,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base (Granite 3.0 MoE family)",
)
