"""whisper-tiny — encoder-decoder ASR backbone; mel+conv frontend STUBBED
(input_specs provides precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    arch_type="audio",
    block="attn",
    num_layers=4,             # decoder layers
    encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    cross_attention=True,
    frontend="audio",
    num_frames=1500,
    act="gelu",
    norm="layernorm",
    rope_theta=0.0,           # whisper uses learned positions, not RoPE
    source="arXiv:2212.04356 (Robust Speech Recognition via Large-Scale Weak Supervision)",
)
