"""jina-embeddings-v2 — the paper's supplementary embedding model (570M,
8192-token context) [arXiv:2310.19923]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jina-v2",
    arch_type="encoder",
    block="attn",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=61056,
    act="gelu",
    norm="layernorm",
    rope_theta=0.0,            # ALiBi in the real model; stub as learned positions
    pool="mean",
    embed_dim=1024,
    source="arXiv:2310.19923 (Jina Embeddings 2); paper §5.1.2",
)
