"""falcon-mamba-7b — attention-free Mamba-1 LM [arXiv:2410.05355]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    arch_type="ssm",
    block="mamba",
    num_layers=64,
    d_model=4096,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    ssm_expand=2,
    source="arXiv:2410.05355 (Falcon Mamba: The First Competitive Attention-free 7B)",
)
