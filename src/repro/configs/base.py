"""Model / shape configuration dataclasses and the registry.

Every assigned architecture gets one file in this package defining a
``ModelConfig`` with the exact published dimensions (source cited in
``source``), plus a ``smoke()`` reduced variant (<=2 layers, d_model<=512,
<=4 experts) used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Dict, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str            # dense | moe | ssm | hybrid | vlm | audio | encoder
    block: str                # attn | mamba | hybrid
    num_layers: int
    d_model: int
    vocab_size: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0         # 0 -> d_model // num_heads
    d_ff: int = 0             # dense FFN hidden (per-expert hidden for MoE)
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # --- SSM (mamba1) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_dt_rank: int = 0      # 0 -> max(16, d_model // 16)
    ssm_conv: int = 4
    # --- attention details ---
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0   # 0 = full attention
    # --- enc-dec / modality frontend (STUBBED per spec) ---
    frontend: str = "none"    # none | vision | audio
    encoder_layers: int = 0
    cross_attention: bool = False
    num_patches: int = 256    # vision stub: patch-embedding tokens
    num_frames: int = 1500    # audio stub: frame embeddings
    # --- misc ---
    norm_eps: float = 1e-5
    act: str = "silu"         # silu -> SwiGLU MLP; gelu -> plain GELU MLP
    norm: str = "rmsnorm"     # rmsnorm | layernorm
    tie_embeddings: bool = False
    pool: str = "none"        # embedder pooling: none | cls | mean
    embed_dim: int = 0        # embedder output dim (bge: 1024)
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or max(16, self.d_model // 16)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def has_attention(self) -> bool:
        return self.block in ("attn", "hybrid")

    @property
    def has_ssm(self) -> bool:
        return self.block in ("mamba", "hybrid")

    @property
    def subquadratic(self) -> bool:
        """May this arch serve a 500k-token context?  SSM / hybrid / sliding
        window qualify; pure full attention does not (see DESIGN.md §4)."""
        return self.block in ("mamba", "hybrid") or self.sliding_window > 0

    @property
    def has_decoder(self) -> bool:
        return self.arch_type != "encoder"

    def smoke(self) -> "ModelConfig":
        """Reduced same-family variant for CPU smoke tests."""
        changes = dict(
            name=self.name + "-smoke",
            num_layers=2,
            d_model=128,
            vocab_size=512,
            head_dim=32,
        )
        if self.num_heads:
            changes["num_heads"] = 4
            changes["num_kv_heads"] = max(1, min(self.num_kv_heads, 2))
        if self.d_ff:
            changes["d_ff"] = 256 if not self.is_moe else 64
        if self.is_moe:
            changes["num_experts"] = 4
            changes["experts_per_token"] = 2
        if self.encoder_layers:
            changes["encoder_layers"] = 2
        if self.frontend == "vision":
            changes["num_patches"] = 16
        if self.frontend == "audio":
            changes["num_frames"] = 32
        if self.sliding_window:
            changes["sliding_window"] = 16
        if self.embed_dim:
            changes["embed_dim"] = 64
        return replace(self, **changes)

    def replace(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# architecture id -> module name in this package
ARCH_MODULES = {
    "falcon-mamba-7b": "falcon_mamba_7b",
    "internvl2-2b": "internvl2_2b",
    "internlm2-20b": "internlm2_20b",
    "hymba-1.5b": "hymba_1_5b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "qwen2-72b": "qwen2_72b",
    "whisper-tiny": "whisper_tiny",
    "stablelm-1.6b": "stablelm_1_6b",
    "starcoder2-7b": "starcoder2_7b",
    # the paper's own embedding models
    "bge-large-zh-v1.5": "bge_large_zh",
    "jina-v2": "jina_v2",
}

ASSIGNED_ARCHS: Tuple[str, ...] = tuple(k for k in ARCH_MODULES if k not in
                                        ("bge-large-zh-v1.5", "jina-v2"))


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[arch]}")
    return mod.CONFIG


def get_shape(shape: str) -> ShapeConfig:
    return INPUT_SHAPES[shape]


def shape_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Is (arch, shape) runnable?  Returns (ok, reason-if-not)."""
    if shape.kind == "decode" and not cfg.has_decoder:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch; 500k dense cache skipped (DESIGN.md §4)"
    return True, ""
