"""starcoder2-7b — dense GQA LM with RoPE + 4k sliding window [arXiv:2402.19173].

The real StarCoder2 uses a 4096-token sliding window, which is what makes the
long_500k decode shape runnable for this arch (ring-buffer KV cache)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    arch_type="dense",
    block="attn",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    sliding_window=4096,
    act="gelu",
    norm="layernorm",
    source="arXiv:2402.19173 (StarCoder 2 and The Stack v2)",
)
