"""hymba-1.5b — hybrid-head (parallel attention + mamba) LM [arXiv:2411.13676]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    block="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=2,
    # Hymba uses sliding-window attention in most layers; the SWA+SSM combo is
    # what makes it sub-quadratic and long_500k-capable.
    sliding_window=1024,
    source="arXiv:2411.13676 (Hymba: A Hybrid-head Architecture for Small LMs)",
)
