from repro.configs.base import (
    ARCH_MODULES,
    ASSIGNED_ARCHS,
    INPUT_SHAPES,
    ModelConfig,
    ShapeConfig,
    get_config,
    get_shape,
    shape_supported,
)

__all__ = [
    "ARCH_MODULES", "ASSIGNED_ARCHS", "INPUT_SHAPES",
    "ModelConfig", "ShapeConfig", "get_config", "get_shape", "shape_supported",
]
