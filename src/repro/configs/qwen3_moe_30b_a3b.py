"""qwen3-moe-30b-a3b — Qwen3 MoE, 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    arch_type="moe",
    block="attn",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=768,                 # per-expert hidden
    vocab_size=151936,
    num_experts=128,
    experts_per_token=8,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-30B-A3B",
)
