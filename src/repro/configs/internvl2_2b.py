"""internvl2-2b — InternViT frontend (stub) + InternLM2-1.8B LM [arXiv:2404.16821]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    arch_type="vlm",
    block="attn",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    frontend="vision",
    num_patches=256,
    rope_theta=1_000_000.0,
    source="arXiv:2404.16821 (InternVL2; InternViT vision stub + InternLM2 backbone)",
)
