"""bge-large-zh-v1.5 — the paper's primary embedding model (326M BERT-large
style bidirectional encoder, 1024-d fp32 output) [arXiv:2309.07597 C-Pack]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="bge-large-zh-v1.5",
    arch_type="encoder",
    block="attn",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=21128,          # chinese bert vocab
    act="gelu",
    norm="layernorm",
    rope_theta=0.0,            # learned absolute positions
    pool="cls",
    embed_dim=1024,
    source="arXiv:2309.07597 (C-Pack / bge-large-zh-v1.5); paper §5.1.2",
)
