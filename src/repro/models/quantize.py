"""One-shot int8 weight-only quantization of the serving param tree.

The paper's deployment-cost argument (Eq. 12) makes the CPU tier's
per-batch service time the binding constraint on peak offload; the trunk's
dense/attention projections are where that time goes.  This module turns a
float param tree into an int8-weight serving tree ONCE at load:

* **per-output-channel symmetric scales** — each projection weight
  ``w: (K, N)`` (or layer-stacked ``(L, K, N)``) quantizes along its
  contraction axis: ``scale[n] = max|w[:, n]| / 127``,
  ``q = round(w / scale)`` clipped to [-127, 127].  Symmetric (no zero
  point) is what lets the dequant commute with the contraction, so the
  kernel applies the scale once in the epilogue instead of materialising a
  dequantized weight matrix (see ``repro.kernels.quant_matmul``).
* **scales ride in the tree** — the quantized weight keeps its key and a
  sibling ``{name}_scale`` fp32 leaf appears next to it, so the stacked
  ``blocks`` pytree still scans layer-wise and
  ``repro.models.layers.dense_apply`` picks the quantized route purely
  from the params (no config plumbing, no retrace-key changes).
* **what stays float** — norms, biases, and the embedding table (a gather,
  not a contraction), plus anything outside ``DENSE_KEYS``.  MoE expert
  stacks are excluded: their einsum dispatch does not go through
  ``dense_apply`` (exclusion is structural — an expert-stacked leaf has an
  extra leading dim beyond the layer stack).

``serve_params`` is the single load-time entry every serving backend uses
to realise an ``embed_dtype`` policy (fp32 | bf16 | int8 | int8_w8a8).
``int8_w8a8`` serves the SAME quantized tree as ``int8`` — the extra step
(dynamic per-row activation quantization into the int8 x int8 kernel) is a
trace-time choice, signalled by ``wants_act_quant`` and threaded into
``models.embedder.embed(act_quant=...)`` by the backends.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.tree_util import DictKey

from repro.parallel.sharding import STACK_KEYS

Params = Dict[str, Any]

# 2-D dense projections consumed as ``x @ w`` by the trunk's dense apply
# (attention q/k/v/o + both MLP families).  3-D MoE expert weights reuse
# three of these names but are skipped by the effective-ndim check below.
DENSE_KEYS = frozenset({"wq", "wk", "wv", "wo",
                        "w_in", "w_out", "w_gate", "w_up", "w_down"})

# embed_dtype perf-flag values every serving backend accepts
EMBED_DTYPES = ("fp32", "bf16", "int8", "int8_w8a8")

# policies that additionally quantize activations at every projection
ACT_QUANT_DTYPES = frozenset({"int8_w8a8"})


def wants_act_quant(dtype: str | None) -> bool:
    """True when the policy quantizes activations too (W8A8), i.e. the
    backends must thread ``act_quant=True`` into the embed trace."""
    return dtype in ACT_QUANT_DTYPES

SCALE_SUFFIX = "_scale"


def quantize_dense(w: jax.Array, axis: int = -2
                   ) -> Tuple[jax.Array, jax.Array]:
    """(w8 int8, scale fp32) with per-output-channel symmetric scales.

    ``axis`` is the contraction dim of ``x @ w`` (-2: rows of the 2-D
    weight; a leading layer-stack dim broadcasts through).  An all-zero
    output channel gets scale 1 so the dequant never divides by zero.
    """
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=axis, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    return q, jnp.squeeze(scale, axis=axis)


def _is_stacked(path) -> bool:
    return any(p.key in STACK_KEYS for p in path if isinstance(p, DictKey))


def quantize_params(params: Params) -> Params:
    """Return a new tree with every dense projection int8-quantized and its
    ``{name}_scale`` sibling added; float leaves are left untouched (the
    caller owns their dtype policy)."""

    def walk(node, path):
        if not isinstance(node, dict):
            return node
        out = {}
        for name, leaf in node.items():
            sub = path + (DictKey(name),)
            if isinstance(leaf, dict):
                out[name] = walk(leaf, sub)
                continue
            eff_ndim = leaf.ndim - (1 if _is_stacked(sub) else 0)
            if (name in DENSE_KEYS and eff_ndim == 2
                    and jnp.issubdtype(leaf.dtype, jnp.floating)):
                q, scale = quantize_dense(leaf)
                out[name] = q
                out[name + SCALE_SUFFIX] = scale
            else:
                out[name] = leaf
        return out

    return walk(params, ())


def is_quantized(params: Params) -> bool:
    """True if any leaf key carries a dequant scale sibling."""
    found = [False]

    def walk(node):
        if isinstance(node, dict):
            for name, leaf in node.items():
                if name.endswith(SCALE_SUFFIX):
                    found[0] = True
                walk(leaf)

    walk(params)
    return found[0]


def serve_params(params: Params, dtype: str) -> Tuple[Params, Any]:
    """Realise an ``embed_dtype`` serving policy on a float param tree.

    Returns ``(tree, compute_dtype)``:

    * ``fp32`` — the tree untouched, fp32 activations (the precision
      oracle every optimized row is guarded against);
    * ``bf16`` — every float leaf cast ONCE to bf16, bf16 activations;
    * ``int8`` — dense projections quantized per ``quantize_params``
      (weights int8 + fp32 scales), everything else fp32, fp32
      activations — the weight-only policy: quantization error enters
      through the weights alone, and the ``pool_norm`` epilogue keeps
      served vectors fp32 unit vectors for every policy;
    * ``int8_w8a8`` — the same quantized tree, but the backends also turn
      on dynamic per-row int8 activation quantization
      (``wants_act_quant``), so every projection contracts int8 x int8
      with int32 accumulation.  Non-projection compute (norms, softmax,
      pooling) stays fp32.
    """
    if dtype not in EMBED_DTYPES:
        raise ValueError(f"embed dtype must be one of {'|'.join(EMBED_DTYPES)}"
                         f", got {dtype!r}")
    if dtype == "bf16":
        return (jax.tree.map(lambda x: x.astype(jnp.bfloat16)
                             if jnp.issubdtype(x.dtype, jnp.floating) else x,
                             params), jnp.bfloat16)
    if dtype in ("int8", "int8_w8a8"):
        return quantize_params(params), jnp.float32
    return params, jnp.float32
