"""Model-family dispatch: one entry point per step kind regardless of arch."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import embedder, encdec, lm


def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    if cfg.arch_type == "encoder":
        return embedder.init_embedder(key, cfg, dtype)
    if cfg.cross_attention:
        return encdec.init_encdec(key, cfg, dtype)
    return lm.init_lm(key, cfg, dtype)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.bfloat16):
    if cfg.cross_attention:
        return encdec.init_cache(cfg, batch, seq_len, dtype)
    return lm.init_cache(cfg, batch, seq_len, dtype)
