from repro.models import embedder, encdec, layers, lm

__all__ = ["layers", "lm", "encdec", "embedder"]
