"""Whisper-style encoder-decoder backbone.

Per spec, the modality frontend (mel spectrogram + conv downsampler) is a
STUB: ``input_specs`` provides precomputed frame embeddings (B, F, d_model).
This module implements the transformer encoder over those frames and the
decoder (causal self-attention + cross-attention) that consumes them.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.lm import ConstraintFn, _id, cache_len

Params = Dict[str, Any]


def init_enc_block(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "norm1": L.init_norm(cfg, dtype),
        "attn": L.init_attention(ks[0], cfg, dtype),
        "norm2": L.init_norm(cfg, dtype),
        "ffn": L.init_mlp(ks[1], cfg, dtype),
    }


def init_dec_block(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "norm1": L.init_norm(cfg, dtype),
        "attn": L.init_attention(ks[0], cfg, dtype),
        "norm_x": L.init_norm(cfg, dtype),
        "xattn": L.init_attention(ks[1], cfg, dtype, cross=True),
        "norm2": L.init_norm(cfg, dtype),
        "ffn": L.init_mlp(ks[2], cfg, dtype),
    }


def init_encdec(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    return {
        "embed": L._dense_init(ks[2], (cfg.vocab_size, cfg.d_model), dtype, scale=0.02),
        "enc_blocks": jax.vmap(lambda k: init_enc_block(k, cfg, dtype))(enc_keys),
        "enc_norm": L.init_norm(cfg, dtype),
        "dec_blocks": jax.vmap(lambda k: init_dec_block(k, cfg, dtype))(dec_keys),
        "dec_norm": L.init_norm(cfg, dtype),
        "lm_head": L._dense_init(ks[3], (cfg.d_model, cfg.vocab_size), dtype),
    }


def encode(params: Params, cfg: ModelConfig, frames: jax.Array,
           constrain: ConstraintFn = _id) -> jax.Array:
    """frames: (B, F, D) stub frame embeddings -> encoder states (B, F, D)."""
    F = frames.shape[1]
    positions = jnp.arange(F, dtype=jnp.int32)
    frames = frames.astype(L.COMPUTE_DTYPE)
    h = frames + L.sinusoidal_positions(positions, cfg.d_model).astype(frames.dtype)

    def body(h, bp):
        hin = L.apply_norm(bp["norm1"], cfg, h)
        h = h + L.attn_forward(bp["attn"], cfg, hin, positions, causal=False)
        hin = L.apply_norm(bp["norm2"], cfg, h)
        h = constrain(h + L.apply_mlp(bp["ffn"], cfg, hin))
        return h, None

    h, _ = lax.scan(body, h, params["enc_blocks"])
    return L.apply_norm(params["enc_norm"], cfg, h)


def _dec_embed(params: Params, cfg: ModelConfig, tokens: jax.Array, pos0=0):
    h = params["embed"][tokens].astype(L.COMPUTE_DTYPE)
    S = h.shape[1]
    positions = jnp.arange(pos0, pos0 + S, dtype=jnp.int32)
    h = h + L.sinusoidal_positions(positions, cfg.d_model).astype(h.dtype)
    return h, positions


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array,
            frames: jax.Array, remat: bool = False,
            return_hidden: bool = False,
            constrain: ConstraintFn = _id) -> Tuple[jax.Array, jax.Array]:
    """Training forward.  Returns (logits (B,S,V), aux=0)."""
    enc = encode(params, cfg, frames, constrain)
    h, positions = _dec_embed(params, cfg, tokens)
    enc_pos = jnp.arange(enc.shape[1], dtype=jnp.int32)

    def body(h, bp):
        hin = L.apply_norm(bp["norm1"], cfg, h)
        h = h + L.attn_forward(bp["attn"], cfg, hin, positions)
        hin = L.apply_norm(bp["norm_x"], cfg, h)
        h = h + L.attn_forward(bp["xattn"], cfg, hin, positions, causal=False,
                               kv_x=enc, kv_positions=enc_pos)
        hin = L.apply_norm(bp["norm2"], cfg, h)
        h = constrain(h + L.apply_mlp(bp["ffn"], cfg, hin))
        return h, None

    from repro.models.lm import _remat
    body_fn = _remat(body) if remat else body
    h, _ = lax.scan(body_fn, h, params["dec_blocks"])
    h = L.apply_norm(params["dec_norm"], cfg, h)
    if return_hidden:
        return h, jnp.zeros((), jnp.float32)
    return h @ params["lm_head"].astype(h.dtype), jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16) -> Params:
    Lc, hd = cfg.num_layers, cfg.resolved_head_dim
    Sc = cache_len(cfg, seq_len)
    return {
        "pos": jnp.zeros((), jnp.int32),
        "k": jnp.zeros((Lc, batch, Sc, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((Lc, batch, Sc, cfg.num_kv_heads, hd), dtype),
        "kpos": jnp.full((Sc,), -1, jnp.int32),
        "cross_k": jnp.zeros((Lc, batch, cfg.num_frames, cfg.num_kv_heads, hd), dtype),
        "cross_v": jnp.zeros((Lc, batch, cfg.num_frames, cfg.num_kv_heads, hd), dtype),
    }


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array,
            frames: jax.Array, cache_dtype=jnp.bfloat16,
            max_len: Optional[int] = None,
            constrain: ConstraintFn = _id) -> Tuple[jax.Array, Params]:
    """Encode frames + run the decoder prompt; build the decode cache."""
    enc = encode(params, cfg, frames, constrain)
    enc_pos = jnp.arange(enc.shape[1], dtype=jnp.int32)
    h, positions = _dec_embed(params, cfg, tokens)
    S = h.shape[1]
    Sc = cache_len(cfg, max(S, max_len or S))

    def body(h, bp):
        out: Params = {}
        hin = L.apply_norm(bp["norm1"], cfg, h)
        a, k, v = L.attn_forward(bp["attn"], cfg, hin, positions, return_kv=True)
        out["k"], out["v"] = k.astype(cache_dtype), v.astype(cache_dtype)
        h = h + a
        hin = L.apply_norm(bp["norm_x"], cfg, h)
        # cross K/V are position-independent; compute once and cache
        xk = (enc @ bp["xattn"]["wk"].astype(enc.dtype)).reshape(
            enc.shape[0], enc.shape[1], cfg.num_kv_heads, cfg.resolved_head_dim)
        xv = (enc @ bp["xattn"]["wv"].astype(enc.dtype)).reshape(
            enc.shape[0], enc.shape[1], cfg.num_kv_heads, cfg.resolved_head_dim)
        out["cross_k"], out["cross_v"] = xk.astype(cache_dtype), xv.astype(cache_dtype)
        h = h + L.attn_forward(bp["xattn"], cfg, hin, positions, causal=False,
                               kv_x=enc, kv_positions=enc_pos)
        hin = L.apply_norm(bp["norm2"], cfg, h)
        h = constrain(h + L.apply_mlp(bp["ffn"], cfg, hin))
        return h, out

    h, layer_cache = lax.scan(body, h, params["dec_blocks"])
    cache = dict(layer_cache)
    cache["pos"] = jnp.asarray(S, jnp.int32)
    kp = jnp.arange(S, dtype=jnp.int32)
    if Sc > S:
        pad = Sc - S
        cache["k"] = jnp.pad(cache["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache["v"] = jnp.pad(cache["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        kp = jnp.concatenate([kp, jnp.full((pad,), -1, jnp.int32)])
    cache["kpos"] = kp
    h = L.apply_norm(params["dec_norm"], cfg, h[:, -1:])
    return (h @ params["lm_head"].astype(h.dtype))[:, 0], cache


def decode_step(params: Params, cfg: ModelConfig, token: jax.Array,
                cache: Params,
                constrain: ConstraintFn = _id) -> Tuple[jax.Array, Params]:
    pos = cache["pos"]
    h = params["embed"][token[:, None]].astype(L.COMPUTE_DTYPE)
    h = h + L.sinusoidal_positions(pos[None], cfg.d_model).astype(h.dtype)

    Sc = cache["k"].shape[2]
    slot = L.cache_slot(cfg, pos, Sc)
    new_kpos = lax.dynamic_update_slice_in_dim(
        cache["kpos"], pos[None].astype(jnp.int32), slot, axis=0)

    xs = {"bp": params["dec_blocks"], "k": cache["k"], "v": cache["v"],
          "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}

    def body(h, x):
        bp = x["bp"]
        out: Params = {}
        hin = L.apply_norm(bp["norm1"], cfg, h)
        a, nk, nv = L.attn_decode(bp["attn"], cfg, hin, pos,
                                  x["k"], x["v"], new_kpos)[:3]
        out["k"], out["v"] = nk, nv
        h = h + a
        hin = L.apply_norm(bp["norm_x"], cfg, h)
        h = h + L.cross_decode(bp["xattn"], cfg, hin,
                               x["cross_k"], x["cross_v"], cfg.num_frames)
        hin = L.apply_norm(bp["norm2"], cfg, h)
        h = constrain(h + L.apply_mlp(bp["ffn"], cfg, hin))
        return h, out

    h, new_layers = lax.scan(body, h, xs)
    new_cache = dict(cache)
    new_cache.update({k: v for k, v in new_layers.items()})
    new_cache["pos"] = pos + 1
    new_cache["kpos"] = new_kpos
    h = L.apply_norm(params["dec_norm"], cfg, h)
    return (h @ params["lm_head"].astype(h.dtype))[:, 0], new_cache
