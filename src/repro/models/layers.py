"""Neural-net building blocks shared by every architecture.

Functional style: ``init_*`` builds a param pytree (nested dicts of arrays),
``*_forward`` / ``*_decode`` apply it.  Per-layer params are stacked along a
leading layer dim by the model code and consumed via ``jax.lax.scan`` so the
HLO stays O(1) in depth (80 dry-run combos must compile fast).

Attention uses a pure-JAX blockwise flash implementation (two-level chunk scan
with online softmax) so 32k-token prefill never materialises an S x S score
matrix.  The Pallas TPU kernel in ``repro.kernels.flash_attention`` implements
the same math with explicit VMEM BlockSpecs; ``repro.kernels.*.ops`` selects
between them by backend.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig

Params = Dict[str, Any]

# Mixed precision: params may be fp32 (training) but all layer compute runs
# in bf16 (MXU-native); norms/softmax/ssm-state internally upcast to fp32.
COMPUTE_DTYPE = jnp.bfloat16

# ----------------------------------------------------------------------------
# initialisers
# ----------------------------------------------------------------------------

def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ----------------------------------------------------------------------------
# dense apply — the one place a projection weight meets its activations
# ----------------------------------------------------------------------------

def dense_apply(p: Params, name: str, x: jax.Array,
                act_quant: bool = False) -> jax.Array:
    """``x @ p[name]`` with the weight cast to the activation dtype — unless
    the param tree carries a ``{name}_scale`` dequant sibling (see
    ``repro.models.quantize``), in which case the projection routes through
    the fused int8 quant matmul.  Routing is purely param/flag-driven so
    quantized and float trees share every caller and every jit cache key
    shape:

    - float tree (no scale sibling)    -> plain matmul
    - quantized tree, ``act_quant`` off -> weight-only W8A16/W8A32 (int8
      weights x float activations, fp32 accumulation, weight scale applied
      once in the epilogue)
    - quantized tree, ``act_quant`` on  -> W8A8: activations dynamically
      quantized per row (symmetric absmax), int8 x int8 with int32
      accumulation, dequant once by ``act_scale x w_scale`` in the epilogue

    ``act_quant`` on a float tree is a no-op by construction (there is no
    int8 weight to contract against), so callers may thread the flag
    unconditionally."""
    scale = p.get(name + "_scale")
    if scale is None:
        return x @ p[name].astype(x.dtype)
    if act_quant:
        from repro.kernels.quant_matmul.ops import quant_matmul_w8a8
        return quant_matmul_w8a8(x, p[name], scale)
    from repro.kernels.quant_matmul.ops import quant_matmul
    return quant_matmul(x, p[name], scale)


# ----------------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dtype) -> Params:
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def apply_norm(p: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * lax.rsqrt(ms + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ----------------------------------------------------------------------------
# positions: RoPE or sinusoidal-absolute
# ----------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n_heads, head_dim); positions: (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq          # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                               # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions: jax.Array, d_model: int) -> jax.Array:
    half = d_model // 2
    freq = 10000.0 ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------------------
# attention (GQA, optional sliding window / cross attention / bidirectional)
# ----------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype, cross: bool = False) -> Params:
    hd = cfg.resolved_head_dim
    H, KV, D = cfg.num_heads, cfg.num_kv_heads, cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (D, H * hd), dtype),
        "wk": _dense_init(ks[1], (D, KV * hd), dtype),
        "wv": _dense_init(ks[2], (D, KV * hd), dtype),
        "wo": _dense_init(ks[3], (H * hd, D), dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def _project_qkv(p: Params, cfg: ModelConfig, x, kv_x, act_quant: bool = False):
    hd = cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    q = dense_apply(p, "wq", x, act_quant=act_quant)
    k = dense_apply(p, "wk", kv_x, act_quant=act_quant)
    v = dense_apply(p, "wv", kv_x, act_quant=act_quant)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(*x.shape[:-1], H, hd)
    k = k.reshape(*kv_x.shape[:-1], KV, hd)
    v = v.reshape(*kv_x.shape[:-1], KV, hd)
    return q, k, v


def flash_attention_jnp(
    q: jax.Array,            # (B, Sq, H, hd)
    k: jax.Array,            # (B, Sk, KV, hd)
    v: jax.Array,            # (B, Sk, KV, hd)
    q_pos: jax.Array,        # (Sq,) absolute positions of queries
    k_pos: jax.Array,        # (Sk,) absolute positions of keys (-1 = invalid)
    causal: bool,
    window: int = 0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    kv_mask: Optional[jax.Array] = None,   # (B, Sk) per-example key validity
) -> jax.Array:
    """Blockwise online-softmax attention, pure JAX (flash-equivalent).

    Never materialises more than (B, KV, G, q_chunk, kv_chunk) scores.
    ``kv_mask`` masks keys PER EXAMPLE (ragged batches: padded positions
    must not leak into real queries' softmax, or embeddings stop being
    invariant to how far the batch was padded — the property shape
    bucketing relies on).  ``k_pos`` stays shared across the batch.
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    # pad to multiples
    nq, nk = -(-Sq // q_chunk), -(-Sk // kv_chunk)
    pq, pk = nq * q_chunk - Sq, nk * kv_chunk - Sk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pq), constant_values=-(10 ** 9))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pk), constant_values=-1)
        if kv_mask is not None:
            kv_mask = jnp.pad(kv_mask, ((0, 0), (0, pk)))

    # time-major xs so lax.scan slices one chunk per step (scanning over an
    # index and slicing a closured array reads the full array every step in
    # the lowered HLO — both a cost-model and a real-memory hazard)
    qg = jnp.moveaxis(q.reshape(B, nq, q_chunk, KV, G, hd), 1, 0)
    kg = jnp.moveaxis(k.reshape(B, nk, kv_chunk, KV, hd), 1, 0)
    vg = jnp.moveaxis(v.reshape(B, nk, kv_chunk, KV, hd), 1, 0)
    qp = q_pos.reshape(nq, q_chunk)
    kp = k_pos.reshape(nk, kv_chunk)
    kmg = None
    if kv_mask is not None:
        kmg = jnp.moveaxis((kv_mask != 0).reshape(B, nk, kv_chunk), 1, 0)
    scale = 1.0 / math.sqrt(hd)

    def make_q_step(qc, qpc):
        """One query chunk's online-softmax accumulation over kv chunks."""

        def kv_step(carry, kx):
            acc, m, denom = carry
            if kmg is None:
                kc, vc, kpc = kx
                kmc = None
            else:
                kc, vc, kpc, kmc = kx        # kmc: (B, kv_chunk) bool
            # bf16 operands, fp32 MXU accumulation (no upcast traffic)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            valid = kpc[None, :] >= 0
            if causal:
                valid &= kpc[None, :] <= qpc[:, None]
            if window:
                valid &= kpc[None, :] > qpc[:, None] - window
            s = jnp.where(valid[None, None, None], s, -1e30)
            if kmc is not None:
                s = jnp.where(kmc[:, None, None, None, :], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            denom = denom * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(vc.dtype), vc,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (acc, m_new, denom), None

        return kv_step

    init = lambda: (
        jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32),
        jnp.full((B, KV, G, q_chunk), -1e30, jnp.float32),
        jnp.zeros((B, KV, G, q_chunk), jnp.float32),
    )

    def kv_xs(band=slice(None)):
        xs = (kg[band], vg[band], kp[band])
        return xs if kmg is None else xs + (kmg[band],)

    from repro.perf_flags import FLAGS

    if FLAGS.attn_band_skip and causal:
        # §Perf: statically iterate only the kv chunks inside the
        # causal/sliding-window band per q chunk (assumes contiguous
        # positions, which train/prefill provide) — the masked-out chunks
        # above the diagonal (and left of the window) are never computed.
        outs = []
        for qi in range(nq):
            hi = min(nk - 1, (qi * q_chunk + q_chunk - 1) // kv_chunk)
            lo = max(0, (qi * q_chunk - window + 1) // kv_chunk) if window else 0
            band = slice(lo, hi + 1)
            kv_step = make_q_step(qg[qi], qp[qi])
            (acc, _, denom), _ = lax.scan(kv_step, init(), kv_xs(band))
            outs.append(acc / jnp.maximum(denom[..., None], 1e-30))
        outs = jnp.stack(outs)                        # (nq, B, KV, G, qc, hd)
    else:
        def q_step(_, qx):
            qc, qpc = qx                     # (B, qc, KV, G, hd), (qc,)
            (acc, _, denom), _ = lax.scan(make_q_step(qc, qpc), init(),
                                          kv_xs())
            return None, acc / jnp.maximum(denom[..., None], 1e-30)

        _, outs = lax.scan(q_step, None, (qg, qp))    # (nq, B, KV, G, qc, hd)
    out = jnp.moveaxis(outs, 0, 1)                     # (B, nq, KV, G, qc, hd)
    out = jnp.moveaxis(out, -2, 2)                     # (B, nq, qc, KV, G, hd)
    out = out.reshape(B, nq * q_chunk, H, hd)[:, :Sq]
    return out.astype(q.dtype)


def attn_forward(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,                  # (B, S, D)
    positions: jax.Array,          # (S,)
    *,
    causal: bool = True,
    kv_x: Optional[jax.Array] = None,     # cross attention source (B, Skv, D)
    kv_positions: Optional[jax.Array] = None,
    return_kv: bool = False,
    kv_mask: Optional[jax.Array] = None,  # (B, Skv) 1 = real key token
    act_quant: bool = False,              # W8A8 projections (quantized trees)
):
    """Full-sequence attention for train / prefill / encoder / cross.

    ``FLAGS.attn_kernel`` selects the implementation: the chunked pure-JAX
    flash path (baseline), or the Pallas TPU kernel
    (``repro.kernels.flash_attention``) — "auto" picks the kernel exactly
    when running on a TPU backend.  The kernel route assumes contiguous
    [0, S) positions (true for every full-sequence caller here) and turns a
    per-example ``kv_mask`` into prefix lengths, which is what the
    embedder's left-aligned padding produces.
    """
    kv_src = x if kv_x is None else kv_x
    kv_pos = positions if kv_positions is None else kv_positions
    q, k, v = _project_qkv(p, cfg, x, kv_src, act_quant=act_quant)
    if cfg.rope_theta:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_pos, cfg.rope_theta)

    from repro.perf_flags import FLAGS

    backend = FLAGS.attn_kernel
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend in ("pallas", "interpret"):
        from repro.kernels.flash_attention.ops import flash_attention
        kv_len = None
        if kv_mask is not None:
            kv_len = jnp.sum(kv_mask != 0, axis=-1).astype(jnp.int32)
        out = flash_attention(
            jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
            jnp.moveaxis(v, 1, 2), causal=causal,
            window=cfg.sliding_window if causal else 0,
            backend=backend, kv_len=kv_len)
        out = jnp.moveaxis(out, 2, 1)
    else:
        out = flash_attention_jnp(
            q, k, v, positions, kv_pos, causal=causal,
            window=cfg.sliding_window if causal else 0, kv_mask=kv_mask)
    y = dense_apply(p, "wo", out.reshape(*x.shape[:-1], -1),
                    act_quant=act_quant)
    if return_kv:
        return y, k, v
    return y


def cache_slot(cfg: ModelConfig, pos: jax.Array, s_cache: int) -> jax.Array:
    """Which cache slot position ``pos`` writes to (ring buffer if windowed)."""
    if cfg.sliding_window:
        return pos % s_cache
    return jnp.minimum(pos, s_cache - 1)


def attn_decode_kv(p: Params, cfg: ModelConfig, x1: jax.Array, pos: jax.Array):
    """Project the current token's (rope-applied) k, v: (B, 1, KV, hd)."""
    _, k, v = _project_qkv(p, cfg, x1, x1)
    if cfg.rope_theta:
        pvec = pos[None] if pos.ndim == 0 else pos
        k = rope(k, pvec, cfg.rope_theta)
    return k, v


def attn_decode_read(
    p: Params,
    cfg: ModelConfig,
    x1: jax.Array,                 # (B, 1, D)
    pos: jax.Array,
    cache_k: jax.Array,            # (B, S_cache, KV, hd) INCLUDING current tok
    cache_v: jax.Array,
    kpos: jax.Array,               # (S_cache,) already-updated positions
):
    """Attention read against an already-updated cache slice."""
    hd = cfg.resolved_head_dim
    q = x1 @ p["wq"].astype(x1.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x1.dtype)
    B = x1.shape[0]
    H = cfg.num_heads
    q = q.reshape(B, 1, H, hd)
    if cfg.rope_theta:
        pvec = pos[None] if pos.ndim == 0 else pos
        q = rope(q, pvec, cfg.rope_theta)
    KV = cache_k.shape[2]
    G = H // KV
    qf = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qf, cache_k.astype(qf.dtype),
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    valid = (kpos >= 0) & (kpos <= pos)
    if cfg.sliding_window:
        valid &= kpos > pos - cfg.sliding_window
    s = jnp.where(valid[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", w.astype(cache_v.dtype), cache_v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H * hd).astype(x1.dtype) @ p["wo"].astype(x1.dtype)


def project_q(p: Params, cfg: ModelConfig, x1: jax.Array, pos: jax.Array):
    """Current token's rope-applied query: (B, H, hd)."""
    hd = cfg.resolved_head_dim
    q = x1 @ p["wq"].astype(x1.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x1.dtype)
    B = x1.shape[0]
    q = q.reshape(B, 1, cfg.num_heads, hd)
    if cfg.rope_theta:
        pvec = pos[None] if pos.ndim == 0 else pos
        q = rope(q, pvec, cfg.rope_theta)
    return q[:, 0]


def attn_decode_sharded(p: Params, cfg: ModelConfig, x1: jax.Array,
                        pos: jax.Array, cache_k, cache_v, kpos,
                        mesh, dp, seq_axes):
    """Flash-decode via shard_map: the KV cache stays sequence-sharded, each
    shard writes the new token ONLY if it owns the slot (kpos match), attends
    its local slice with a partial softmax, and the shards combine with a
    pmax/psum of (max, denom, weighted-values).

    This replaces GSPMD's lowering of dynamic-update-slice on a sharded dim,
    which rewrites the FULL cache through a select (+ copies) every layer —
    measured 1.3 TB/step on qwen2-72b decode_32k vs ~11 GB here."""
    from jax.sharding import PartitionSpec as P
    import functools as _ft
    try:
        from jax import shard_map as _sm
        shard_map = _ft.partial(_sm, check_vma=False)
    except ImportError:  # older jax
        from jax.experimental.shard_map import shard_map as _sm_old
        shard_map = _ft.partial(_sm_old, check_rep=False)

    hd = cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    B = x1.shape[0]
    G = H // KV
    q = project_q(p, cfg, x1, pos).reshape(B, KV, G, hd)
    knew, vnew = attn_decode_kv(p, cfg, x1, pos)
    comb = tuple(seq_axes)
    scale = 1.0 / math.sqrt(hd)
    window = cfg.sliding_window

    def local_fn(q, knew, vnew, kl, vl, kposl, pos):
        # -- owner-shard-only cache write (tiny: (B, 1, KV, hd)) --
        eq = kposl == pos
        owner = eq.any()
        slot_l = jnp.argmax(eq).astype(jnp.int32)
        cur_k = lax.dynamic_slice_in_dim(kl, slot_l, 1, axis=1)
        cur_v = lax.dynamic_slice_in_dim(vl, slot_l, 1, axis=1)
        kl = lax.dynamic_update_slice_in_dim(
            kl, jnp.where(owner, knew.astype(kl.dtype), cur_k), slot_l, axis=1)
        vl = lax.dynamic_update_slice_in_dim(
            vl, jnp.where(owner, vnew.astype(vl.dtype), cur_v), slot_l, axis=1)
        # -- local partial softmax --
        s = jnp.einsum("bkgh,bskh->bkgs", q, kl.astype(q.dtype),
                       preferred_element_type=jnp.float32) * scale
        valid = (kposl >= 0) & (kposl <= pos)
        if window:
            valid &= kposl > pos - window
        s = jnp.where(valid[None, None, None], s, -1e30)
        m_l = s.max(axis=-1)                                   # (B, KV, G)
        m = lax.pmax(m_l, comb)
        pr = jnp.exp(s - m[..., None])
        pr = jnp.where(valid[None, None, None], pr, 0.0)
        den = lax.psum(pr.sum(axis=-1), comb)
        o = jnp.einsum("bkgs,bskh->bkgh", pr.astype(vl.dtype), vl,
                       preferred_element_type=jnp.float32)
        o = lax.psum(o, comb) / jnp.maximum(den[..., None], 1e-30)
        return o.astype(x1.dtype), kl, vl

    b = dp if B > 1 else None
    seq = comb if len(comb) > 1 else comb[0]
    out, nk, nv = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(b, None, None, None), P(b, None, None, None),
                  P(b, None, None, None), P(b, seq, None, None),
                  P(b, seq, None, None), P(seq), P()),
        out_specs=(P(b, None, None, None), P(b, seq, None, None),
                   P(b, seq, None, None)),
    )(q, knew, vnew, cache_k, cache_v, kpos, pos)
    y = out.reshape(B, 1, H * hd) @ p["wo"].astype(x1.dtype)
    return y, nk, nv


def attn_decode(
    p: Params,
    cfg: ModelConfig,
    x1: jax.Array,                 # (B, 1, D) current token's hidden
    pos: jax.Array,                # scalar int32 absolute position
    cache_k: jax.Array,            # (B, S_cache, KV, hd) rope-applied keys
    cache_v: jax.Array,
    kpos: jax.Array,               # (S_cache,) ALREADY-UPDATED position per slot
):
    """One-token decode against a (possibly ring-buffer) KV cache.

    ``kpos`` is layer-invariant, so the caller updates it once (see
    ``cache_slot``) and passes the updated array in."""
    S_cache = cache_k.shape[1]
    hd = cfg.resolved_head_dim
    q, k, v = _project_qkv(p, cfg, x1, x1)
    if cfg.rope_theta:
        pvec = pos[None] if pos.ndim == 0 else pos
        q = rope(q, pvec, cfg.rope_theta)
        k = rope(k, pvec, cfg.rope_theta)
    slot = cache_slot(cfg, pos, S_cache)
    cache_k = lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), slot, axis=1)
    cache_v = lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), slot, axis=1)

    B, _, H, _ = q.shape
    KV = cache_k.shape[2]
    G = H // KV
    qf = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qf, cache_k.astype(qf.dtype),
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    valid = (kpos >= 0) & (kpos <= pos)
    if cfg.sliding_window:
        valid &= kpos > pos - cfg.sliding_window
    s = jnp.where(valid[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", w.astype(cache_v.dtype), cache_v,
                     preferred_element_type=jnp.float32)
    y = out.reshape(B, 1, H * hd).astype(x1.dtype) @ p["wo"].astype(x1.dtype)
    return y, cache_k, cache_v, kpos


def cross_decode(p: Params, cfg: ModelConfig, x1, cross_k, cross_v, kv_len):
    """Decode-time cross attention against precomputed encoder K/V."""
    hd = cfg.resolved_head_dim
    B = x1.shape[0]
    H, KV = cfg.num_heads, cfg.num_kv_heads
    G = H // KV
    q = (x1 @ p["wq"].astype(x1.dtype)).reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", q, cross_k.astype(q.dtype),
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", w.astype(cross_v.dtype), cross_v,
                     preferred_element_type=jnp.float32)
    y = out.reshape(B, 1, H * hd).astype(x1.dtype) @ p["wo"].astype(x1.dtype)
    return y


# ----------------------------------------------------------------------------
# MLP (SwiGLU or GELU)
# ----------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, dtype) -> Params:
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "silu":
        return {
            "w_gate": _dense_init(ks[0], (D, F), dtype),
            "w_up": _dense_init(ks[1], (D, F), dtype),
            "w_down": _dense_init(ks[2], (F, D), dtype),
        }
    return {
        "w_in": _dense_init(ks[0], (D, F), dtype),
        "w_out": _dense_init(ks[1], (F, D), dtype),
    }


def apply_mlp(p: Params, cfg: ModelConfig, x: jax.Array,
              act_quant: bool = False) -> jax.Array:
    if cfg.act == "silu":
        g = jax.nn.silu(dense_apply(p, "w_gate", x, act_quant=act_quant))
        u = dense_apply(p, "w_up", x, act_quant=act_quant)
        return dense_apply(p, "w_down", g * u, act_quant=act_quant)
    h = jax.nn.gelu(dense_apply(p, "w_in", x, act_quant=act_quant))
    return dense_apply(p, "w_out", h, act_quant=act_quant)


# ----------------------------------------------------------------------------
# MoE (top-k routing, capacity-based gather dispatch — no dense one-hot einsum,
# so HLO FLOPs stay ~= useful FLOPs; see DESIGN.md §5)
# ----------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": _dense_init(ks[0], (D, E), dtype),
        "w_gate": _dense_init(ks[1], (E, D, F), dtype),
        "w_up": _dense_init(ks[2], (E, D, F), dtype),
        "w_down": _dense_init(ks[3], (E, F, D), dtype),
    }


def apply_moe(p: Params, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_load_balance_loss)."""
    from repro.perf_flags import FLAGS

    if FLAGS.moe_row_dispatch:
        return _apply_moe_row(p, cfg, x)
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = B * S
    xf = x.reshape(T, D)
    logits = (xf @ p["router"].astype(xf.dtype)).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, eidx = lax.top_k(probs, K)                                  # (T, K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style): E * sum(frac_tokens * frac_probs)
    me = probs.mean(axis=0)                                             # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    capacity = int(math.ceil(T * K / E * cfg.capacity_factor))
    # position of each (token, k) assignment inside its expert's queue
    flat_e = eidx.reshape(-1)                                           # (T*K,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)                 # (T*K, E)
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1             # (T*K,)
    keep = pos < capacity
    slot = jnp.where(keep, flat_e * capacity + pos, E * capacity)       # overflow slot

    xr = jnp.repeat(xf, K, axis=0)                                      # (T*K, D)
    dispatched = jnp.zeros((E * capacity + 1, D), xf.dtype).at[slot].set(xr)
    ein = dispatched[: E * capacity].reshape(E, capacity, D)

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ein, p["w_gate"].astype(ein.dtype)))
    u = jnp.einsum("ecd,edf->ecf", ein, p["w_up"].astype(ein.dtype))
    eout = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"].astype(ein.dtype))

    eflat = jnp.concatenate([eout.reshape(E * capacity, D),
                             jnp.zeros((1, D), eout.dtype)], axis=0)
    gathered = eflat[slot]                                              # (T*K, D)
    w = (gate_w.reshape(-1) * keep.astype(jnp.float32)).astype(gathered.dtype)
    y = (gathered * w[:, None]).reshape(T, K, D).sum(axis=1)
    return y.reshape(B, S, D), aux


def _mesh_axis_names():
    """Axis names of the mesh currently in context, () if none.

    ``jax.sharding.get_abstract_mesh`` on new jax; older releases stash the
    context mesh in thread resources when a ``Mesh`` is entered.
    """
    get_am = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_am is not None:
        am = get_am()
        if am is None or getattr(am, "empty", True):
            return ()
        return tuple(am.axis_names)
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return () if m is None or m.empty else tuple(m.axis_names)
    except Exception:  # pragma: no cover - internals moved; stay a no-op
        return ()


def _moe_constrain(x: jax.Array, tail_spec) -> jax.Array:
    """with_sharding_constraint(P(dp, *tail_spec)) when a mesh is in context
    (launchers wrap lowering in a mesh context); no-op otherwise."""
    from jax.sharding import PartitionSpec as P

    dp = tuple(a for a in _mesh_axis_names() if a in ("pod", "data"))
    if not dp:
        return x
    b = dp if len(dp) > 1 else dp[0]
    return lax.with_sharding_constraint(x, P(b, *tail_spec))


def _apply_moe_row(p: Params, cfg: ModelConfig, x: jax.Array
                   ) -> Tuple[jax.Array, jax.Array]:
    """§Perf MoE dispatch: bucket tokens per BATCH ROW so scatter/gather
    indices never cross the data-sharded batch dim.  The global-scatter
    baseline makes GSPMD all-gather the full (T*K, D) token array to every
    device (the dominant collective on qwen3-moe train_4k); here the batch
    dim stays sharded end-to-end and the expert einsums shard (B->data,
    E->model) with no token gather."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)      # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, eidx = lax.top_k(probs, K)                                  # (B,S,K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(1.0) / (B * S * K)
    aux = E * jnp.sum(me * ce)

    cap = int(math.ceil(S * K / E * cfg.capacity_factor))
    flat_e = eidx.reshape(B, S * K)                                     # row-local
    # Position of each assignment within its expert's queue via sort-based
    # ranking: all intermediates are (B, S*K) or (B, E) — the one-hot-cumsum
    # formulation materialises (B, S*K, E) (4.3 GB/layer at this scale).
    # Every gather/scatter below goes through take/put_along_axis so GSPMD
    # sees BATCHED operations (batch dim stays data-sharded, no cross-device
    # combine); explicit row-index advanced indexing lowers to unbatched
    # gathers that GSPMD finishes with full-array all-reduces (measured
    # 1.2 TB/step of collectives on qwen3-moe train_4k).
    rows = jnp.arange(B)[:, None]
    counts = jnp.zeros((B, E), jnp.int32).at[rows, flat_e].add(1)       # (B,E)
    starts = jnp.cumsum(counts, axis=1) - counts                        # exclusive
    order = jnp.argsort(flat_e, axis=1, stable=True)                    # (B,S*K)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    pos_sorted = (jnp.arange(S * K, dtype=jnp.int32)[None]
                  - jnp.take_along_axis(starts, sorted_e, axis=1))
    pos = jnp.put_along_axis(jnp.zeros((B, S * K), jnp.int32), order,
                             pos_sorted, axis=1, inplace=False)
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, E * cap)                 # (B,S*K)

    xr = jnp.repeat(x.reshape(B, S, D), K, axis=1)                      # (B,S*K,D)
    # vmap'd per-row scatter -> HLO scatter with operand_batching_dims
    dispatched = jax.vmap(
        lambda s, v: jnp.zeros((E * cap + 1, D), x.dtype).at[s].set(v)
    )(slot, xr)
    # pin the token-major layout (B->data, D->model): the scatter stays
    # local; GSPMD then resharding into the expert einsum's (E->model)
    # layout is one all-to-all instead of a full-array all-reduce combine
    dispatched = _moe_constrain(dispatched, (None, "model"))
    ein = dispatched[:, : E * cap].reshape(B, E, cap, D)

    g = jax.nn.silu(jnp.einsum("becd,edf->becf", ein,
                               p["w_gate"].astype(ein.dtype)))
    u = jnp.einsum("becd,edf->becf", ein, p["w_up"].astype(ein.dtype))
    eout = jnp.einsum("becf,efd->becd", g * u, p["w_down"].astype(ein.dtype))

    eflat = jnp.concatenate([eout.reshape(B, E * cap, D),
                             jnp.zeros((B, 1, D), eout.dtype)], axis=1)
    # reshard expert-major -> token-major BEFORE the combine gather so the
    # gather itself is fully local (batched over B, slot dim replicated)
    eflat = _moe_constrain(eflat, (None, "model"))
    gathered = jnp.take_along_axis(eflat, slot[..., None], axis=1)      # (B,S*K,D)
    w = (gate_w.reshape(B, S * K) * keep.astype(jnp.float32)
         ).astype(gathered.dtype)
    y = (gathered * w[..., None]).reshape(B, S, K, D).sum(axis=2)
    return y, aux


# ----------------------------------------------------------------------------
# Mamba-1 selective scan
# ----------------------------------------------------------------------------

def init_mamba(key, cfg: ModelConfig, dtype) -> Params:
    D, DI, N, R, CK = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    return {
        "in_proj": _dense_init(ks[0], (D, 2 * DI), dtype),
        "conv_w": _dense_init(ks[1], (CK, DI), dtype, scale=1.0 / math.sqrt(CK)),
        "conv_b": jnp.zeros((DI,), dtype),
        "x_proj": _dense_init(ks[2], (DI, R + 2 * N), dtype),
        "dt_proj": _dense_init(ks[3], (R, DI), dtype),
        "dt_bias": jnp.full((DI,), math.log(math.e - 1), dtype),  # softplus^-1(1)
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32),
                                          (DI, N))).astype(jnp.float32),
        "D": jnp.ones((DI,), jnp.float32),
        "out_proj": _dense_init(ks[4], (DI, D), dtype),
    }


def _mamba_core(p: Params, cfg: ModelConfig, xz: jax.Array, conv_state=None):
    """Shared pre-scan computation.  xz: (B, S, 2*DI)."""
    DI, N, R = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    x, z = jnp.split(xz, 2, axis=-1)                          # (B, S, DI)
    # causal depthwise conv along S (kernel CK)
    CK = cfg.ssm_conv
    if conv_state is None:
        xpad = jnp.pad(x, ((0, 0), (CK - 1, 0), (0, 0)))
    else:
        xpad = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    new_conv_state = xpad[:, -(CK - 1):, :]
    conv_w = p["conv_w"].astype(x.dtype)
    xc = sum(xpad[:, i : i + x.shape[1], :] * conv_w[i] for i in range(CK))
    xc = jax.nn.silu(xc + p["conv_b"].astype(x.dtype))
    # input-dependent SSM params
    dbc = xc @ p["x_proj"].astype(xc.dtype)                   # (B, S, R+2N)
    dt, Bm, Cm = jnp.split(dbc, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(dt.dtype)
                         + p["dt_bias"].astype(dt.dtype)).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])                                   # (DI, N)
    return xc, z, dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32), A, new_conv_state


def mamba_scan_ref(xc, dt, Bm, Cm, A, h0=None):
    """Sequential selective scan.  xc: (B,S,DI) dt: (B,S,DI) Bm/Cm: (B,S,N).

    Returns (y: (B,S,DI) fp32, h_final: (B,DI,N) fp32)."""
    B, S, DI = xc.shape
    N = Bm.shape[-1]
    h0 = jnp.zeros((B, DI, N), jnp.float32) if h0 is None else h0
    xf = xc.astype(jnp.float32)

    def step(h, inp):
        dt_t, x_t, b_t, c_t = inp                              # time-major xs
        dA = jnp.exp(dt_t[..., None] * A)                      # (B, DI, N)
        dBx = (dt_t * x_t)[..., None] * b_t[:, None, :]
        h = h * dA + dBx
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (dt, xf, Bm, Cm))
    h, ys = lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h                           # (B,S,DI), (B,DI,N)


def mamba_scan_chunked(xc, dt, Bm, Cm, A, h0=None, chunk: int = 16):
    """Time-chunked selective scan: outer lax.scan over S/chunk chunks with a
    ``jax.checkpoint``-ed unrolled inner body.

    The win is in the BACKWARD: differentiating a per-timestep scan stores
    O(S) copies of (B, DI, N)-sized residuals (measured ~8 buffers = 105
    GB/layer on hymba train_4k); checkpointing at chunk granularity stores
    only the chunk-boundary carries (S/chunk of them) and recomputes inside
    the chunk — the time analogue of remat-over-layers."""
    B, S, DI = xc.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    nc = S // chunk
    h0 = jnp.zeros((B, DI, N), jnp.float32) if h0 is None else h0
    xf = xc.astype(jnp.float32)

    def to_chunks(a):
        return jnp.moveaxis(a.reshape(B, nc, chunk, -1), 1, 0)

    xs = tuple(to_chunks(a) for a in (dt, xf, Bm, Cm))

    @jax.checkpoint
    def outer(h, inp):
        dts, xcs, bs, cs = inp              # (B, chunk, DI/ N)
        ys = []
        for t in range(chunk):              # unrolled: fused by XLA
            dA = jnp.exp(dts[:, t][..., None] * A)
            h = h * dA + (dts[:, t] * xcs[:, t])[..., None] * bs[:, t][:, None, :]
            ys.append(jnp.einsum("bdn,bn->bd", h, cs[:, t]))
        return h, jnp.stack(ys, axis=1)     # (B, chunk, DI)

    h, ys = lax.scan(outer, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, DI)
    return y, h


def default_mamba_scan():
    from repro.perf_flags import FLAGS

    if FLAGS.mamba_chunk > 0:
        return functools.partial(mamba_scan_chunked, chunk=FLAGS.mamba_chunk)
    return mamba_scan_ref


def mamba_forward(p: Params, cfg: ModelConfig, x: jax.Array,
                  scan_fn=None) -> jax.Array:
    """Full-sequence mamba mixer.  scan_fn lets the kernel layer substitute the
    Pallas chunked scan; defaults per perf_flags (baseline: sequential)."""
    scan_fn = scan_fn or default_mamba_scan()
    xz = x @ p["in_proj"].astype(x.dtype)
    xc, z, dt, Bm, Cm, A, _ = _mamba_core(p, cfg, xz)
    y, _ = scan_fn(xc, dt, Bm, Cm, A)
    y = y + p["D"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ p["out_proj"].astype(x.dtype)


def mamba_prefill(p: Params, cfg: ModelConfig, x: jax.Array):
    """Like mamba_forward but also returns (ssm_state, conv_state) for decode."""
    xz = x @ p["in_proj"].astype(x.dtype)
    xc, z, dt, Bm, Cm, A, conv_state = _mamba_core(p, cfg, xz)
    y, h = default_mamba_scan()(xc, dt, Bm, Cm, A)
    y = y + p["D"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ p["out_proj"].astype(x.dtype), h, conv_state


def mamba_decode(p: Params, cfg: ModelConfig, x1: jax.Array,
                 ssm_state: jax.Array, conv_state: jax.Array):
    """One-token recurrent step.  x1: (B,1,D); ssm_state: (B,DI,N) fp32;
    conv_state: (B, CK-1, DI)."""
    xz = x1 @ p["in_proj"].astype(x1.dtype)
    xc, z, dt, Bm, Cm, A, new_conv = _mamba_core(p, cfg, xz, conv_state=conv_state)
    # S == 1: single recurrence step
    dA = jnp.exp(dt[:, 0][..., None] * A)
    dBx = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * Bm[:, 0][:, None, :]
    h = ssm_state * dA + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])[:, None, :]
    y = y + p["D"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x1.dtype)
    return y @ p["out_proj"].astype(x1.dtype), h, new_conv
