"""Unified decoder language model: dense / MoE / SSM (mamba) / hybrid.

One block definition parameterised by ``ModelConfig.block``; the layer stack
is a ``jax.lax.scan`` over stacked per-layer params so HLO size is O(1) in
depth.  Exposes three entry points:

* ``forward``       — full-sequence logits (training).
* ``prefill``       — full-sequence logits + decode cache.
* ``decode_step``   — one token against the cache.

VLM archs reuse these with ``extra_embed`` (stub patch embeddings) prepended.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L

Params = Dict[str, Any]
ConstraintFn = Callable[[jax.Array], jax.Array]
_id = lambda x: x


def _remat(fn):
    """Layer remat with the policy from perf_flags (baseline: full remat)."""
    from repro.perf_flags import FLAGS

    if FLAGS.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ----------------------------------------------------------------------------
# init
# ----------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": L.init_norm(cfg, dtype)}
    if cfg.block in ("attn", "hybrid"):
        p["attn"] = L.init_attention(ks[0], cfg, dtype)
    if cfg.block in ("mamba", "hybrid"):
        p["mamba"] = L.init_mamba(ks[1], cfg, dtype)
    if cfg.d_ff:
        p["norm2"] = L.init_norm(cfg, dtype)
        p["ffn"] = (L.init_moe(ks[2], cfg, dtype) if cfg.is_moe
                    else L.init_mlp(ks[2], cfg, dtype))
    return p


def init_lm(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.num_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg, dtype))(layer_keys)
    p = {
        "embed": L._dense_init(ks[1], (cfg.vocab_size, cfg.d_model), dtype, scale=0.02),
        "blocks": blocks,
        "final_norm": L.init_norm(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L._dense_init(ks[2], (cfg.d_model, cfg.vocab_size), dtype)
    return p


# ----------------------------------------------------------------------------
# full-sequence block application (train / prefill)
# ----------------------------------------------------------------------------

def _block_forward(bp: Params, cfg: ModelConfig, h: jax.Array,
                   positions: jax.Array, constrain: ConstraintFn) -> Tuple[jax.Array, jax.Array]:
    """Returns (h, moe_aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    hin = L.apply_norm(bp["norm1"], cfg, h)
    if cfg.block == "attn":
        h = h + L.attn_forward(bp["attn"], cfg, hin, positions)
    elif cfg.block == "mamba":
        h = h + L.mamba_forward(bp["mamba"], cfg, hin)
    else:  # hybrid: parallel attention + mamba heads, averaged (Hymba)
        a = L.attn_forward(bp["attn"], cfg, hin, positions)
        m = L.mamba_forward(bp["mamba"], cfg, hin)
        h = h + 0.5 * (a + m)
    h = constrain(h)
    if cfg.d_ff:
        hin = L.apply_norm(bp["norm2"], cfg, h)
        if cfg.is_moe:
            y, aux = L.apply_moe(bp["ffn"], cfg, hin)
        else:
            y = L.apply_mlp(bp["ffn"], cfg, hin)
        h = constrain(h + y)
    return h, aux


def _embed(params: Params, cfg: ModelConfig, tokens: jax.Array,
           extra_embed: Optional[jax.Array], pos_offset: int = 0):
    h = params["embed"][tokens].astype(L.COMPUTE_DTYPE)
    if extra_embed is not None:          # VLM: prepend stub patch embeddings
        h = jnp.concatenate([extra_embed.astype(h.dtype), h], axis=1)
    S = h.shape[1]
    positions = jnp.arange(pos_offset, pos_offset + S, dtype=jnp.int32)
    if not cfg.rope_theta:               # learned/absolute-position families
        h = h + L.sinusoidal_positions(positions, cfg.d_model).astype(h.dtype)
    return h, positions


def _unembed(params: Params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    h = L.apply_norm(params["final_norm"], cfg, h)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return h @ head.astype(h.dtype)


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array,
            extra_embed: Optional[jax.Array] = None,
            remat: bool = False,
            return_hidden: bool = False,
            constrain: ConstraintFn = _id) -> Tuple[jax.Array, jax.Array]:
    """Training forward.  tokens: (B, S_text) -> (logits (B,S,V), moe_aux).

    ``return_hidden=True`` skips the unembed and returns the final-normed
    hidden states instead (the chunked CE loss computes logits per-chunk to
    avoid materialising (B, S, V))."""
    h, positions = _embed(params, cfg, tokens, extra_embed)

    def body(carry, bp):
        hh, _ = carry
        hh, aux = _block_forward(bp, cfg, hh, positions, constrain)
        return (hh, aux), aux

    body_fn = _remat(body) if remat else body
    (h, _), auxs = lax.scan(body_fn, (h, jnp.zeros((), jnp.float32)),
                            params["blocks"])
    if return_hidden:
        return L.apply_norm(params["final_norm"], cfg, h), auxs.sum()
    return _unembed(params, cfg, h), auxs.sum()


def head_weights(params: Params, cfg: ModelConfig) -> jax.Array:
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


# ----------------------------------------------------------------------------
# decode cache
# ----------------------------------------------------------------------------

def cache_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.sliding_window:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16) -> Params:
    """Empty decode cache sized for a context of ``seq_len`` tokens."""
    Lc, hd = cfg.num_layers, cfg.resolved_head_dim
    cache: Params = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.has_attention:
        Sc = cache_len(cfg, seq_len)
        cache["k"] = jnp.zeros((Lc, batch, Sc, cfg.num_kv_heads, hd), dtype)
        cache["v"] = jnp.zeros((Lc, batch, Sc, cfg.num_kv_heads, hd), dtype)
        cache["kpos"] = jnp.full((Sc,), -1, jnp.int32)
    if cfg.has_ssm:
        cache["ssm"] = jnp.zeros((Lc, batch, cfg.d_inner, cfg.ssm_state), jnp.float32)
        cache["conv"] = jnp.zeros((Lc, batch, cfg.ssm_conv - 1, cfg.d_inner), dtype)
    return cache


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array,
            extra_embed: Optional[jax.Array] = None,
            cache_dtype=jnp.bfloat16,
            max_len: Optional[int] = None,
            constrain: ConstraintFn = _id) -> Tuple[jax.Array, Params]:
    """Process the full prompt; return (last-position logits (B,V), cache).

    ``max_len`` sizes the cache for subsequent decode (>= prompt length for
    full-attention archs; windowed archs clamp to the window)."""
    h, positions = _embed(params, cfg, tokens, extra_embed)
    B, S = h.shape[0], h.shape[1]
    Sc = cache_len(cfg, max(S, max_len or S))
    keep = min(S, Sc)

    def body(h, bp):
        out: Params = {}
        hin = L.apply_norm(bp["norm1"], cfg, h)
        if cfg.has_attention:
            a, k, v = L.attn_forward(bp["attn"], cfg, hin, positions, return_kv=True)
            out["k"] = k[:, -keep:].astype(cache_dtype)
            out["v"] = v[:, -keep:].astype(cache_dtype)
        if cfg.has_ssm:
            m, ssm_h, conv_state = L.mamba_prefill(bp["mamba"], cfg, hin)
            out["ssm"] = ssm_h
            out["conv"] = conv_state.astype(cache_dtype)
        if cfg.block == "attn":
            h = h + a
        elif cfg.block == "mamba":
            h = h + m
        else:
            h = h + 0.5 * (a + m)
        h = constrain(h)
        if cfg.d_ff:
            hin = L.apply_norm(bp["norm2"], cfg, h)
            y = (L.apply_moe(bp["ffn"], cfg, hin)[0] if cfg.is_moe
                 else L.apply_mlp(bp["ffn"], cfg, hin))
            h = constrain(h + y)
        return h, out

    h, layer_cache = lax.scan(body, h, params["blocks"])
    cache = dict(layer_cache)
    cache["pos"] = jnp.asarray(S, jnp.int32)
    if cfg.has_attention:
        # slot layout: slot i holds absolute position (S - keep + i), then
        # (windowed archs) rotated so decode's ring write (pos % Sc) lines up.
        kp = jnp.arange(S - keep, S, dtype=jnp.int32)
        if Sc > keep:  # room left for decode: pad empty slots at the end
            pad = Sc - keep
            cache["k"] = jnp.pad(cache["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            cache["v"] = jnp.pad(cache["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            kp = jnp.concatenate([kp, jnp.full((pad,), -1, jnp.int32)])
        elif cfg.sliding_window:
            roll = S % Sc
            cache["k"] = jnp.roll(cache["k"], roll, axis=2)
            cache["v"] = jnp.roll(cache["v"], roll, axis=2)
            kp = jnp.roll(kp, roll)
        cache["kpos"] = kp
    logits = _unembed(params, cfg, h[:, -1:])[:, 0]
    return logits, cache


def decode_step(params: Params, cfg: ModelConfig, token: jax.Array,
                cache: Params,
                constrain: ConstraintFn = _id,
                shard_ctx=None) -> Tuple[jax.Array, Params]:
    """One decode step.  token: (B,) int32.  Returns (logits (B,V), cache).

    ``shard_ctx=(mesh, dp, seq_axes)`` activates the shard_map flash-decode
    attention path (perf flag decode_shard_map)."""
    pos = cache["pos"]
    h, _ = _embed(params, cfg, token[:, None], None, pos_offset=0)
    if not cfg.rope_theta:
        # _embed added position 0; replace with the true position encoding
        h = params["embed"][token[:, None]].astype(L.COMPUTE_DTYPE)
        h = h + L.sinusoidal_positions(pos[None], cfg.d_model).astype(h.dtype)

    new_kpos = None
    if cfg.has_attention:
        Sc = cache["k"].shape[2]
        slot = L.cache_slot(cfg, pos, Sc)
        new_kpos = lax.dynamic_update_slice_in_dim(
            cache["kpos"], pos[None].astype(jnp.int32), slot, axis=0)

    def layer(bp, h, state):
        """One decoder layer at decode time.  state: per-layer cache slices."""
        out: Params = {}
        hin = L.apply_norm(bp["norm1"], cfg, h)
        if cfg.has_attention:
            a, nk, nv, = L.attn_decode(bp["attn"], cfg, hin, pos,
                                       state["k"], state["v"], new_kpos)[:3]
            out["k"], out["v"] = nk, nv
        if cfg.has_ssm:
            m, nh, nconv = L.mamba_decode(bp["mamba"], cfg, hin,
                                          state["ssm"],
                                          state["conv"].astype(hin.dtype))
            out["ssm"], out["conv"] = nh, nconv.astype(state["conv"].dtype)
        if cfg.block == "attn":
            h = h + a
        elif cfg.block == "mamba":
            h = h + m
        else:
            h = h + 0.5 * (a + m)
        if cfg.d_ff:
            hin = L.apply_norm(bp["norm2"], cfg, h)
            y = (L.apply_moe(bp["ffn"], cfg, hin)[0] if cfg.is_moe
                 else L.apply_mlp(bp["ffn"], cfg, hin))
            h = h + y
        h = constrain(h)
        return h, out

    from repro.perf_flags import FLAGS

    cache_keys = [k for k in ("k", "v", "ssm", "conv") if k in cache]
    if FLAGS.decode_shard_map and shard_ctx is not None and cfg.has_attention:
        # §Perf: flash-decode — seq-sharded cache attended via shard_map with
        # partial-softmax psum combine; owner shard writes the new token.
        mesh, dp, seq_axes = shard_ctx

        def body(i, carry):
            h, st = carry
            bp = jax.tree.map(
                lambda a: lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                params["blocks"])
            st = dict(st)
            hin = L.apply_norm(bp["norm1"], cfg, h)
            a = m = None
            ks = lax.dynamic_index_in_dim(st["k"], i, 0, keepdims=False)
            vs = lax.dynamic_index_in_dim(st["v"], i, 0, keepdims=False)
            a, nk, nv = L.attn_decode_sharded(bp["attn"], cfg, hin, pos,
                                              ks, vs, new_kpos,
                                              mesh, dp, seq_axes)
            st["k"] = lax.dynamic_update_index_in_dim(st["k"], nk, i, 0)
            st["v"] = lax.dynamic_update_index_in_dim(st["v"], nv, i, 0)
            if cfg.has_ssm:
                ssm = lax.dynamic_index_in_dim(st["ssm"], i, 0, keepdims=False)
                conv = lax.dynamic_index_in_dim(st["conv"], i, 0, keepdims=False)
                m, nh, nconv = L.mamba_decode(bp["mamba"], cfg, hin, ssm,
                                              conv.astype(hin.dtype))
                st["ssm"] = lax.dynamic_update_index_in_dim(
                    st["ssm"], nh.astype(st["ssm"].dtype), i, 0)
                st["conv"] = lax.dynamic_update_index_in_dim(
                    st["conv"], nconv.astype(st["conv"].dtype), i, 0)
            h = h + (a if cfg.block == "attn" else 0.5 * (a + m))
            if cfg.d_ff:
                hin = L.apply_norm(bp["norm2"], cfg, h)
                y = (L.apply_moe(bp["ffn"], cfg, hin)[0] if cfg.is_moe
                     else L.apply_mlp(bp["ffn"], cfg, hin))
                h = h + y
            h = constrain(h)
            return h, st

        h, new_layers = lax.fori_loop(
            0, cfg.num_layers, body, (h, {k: cache[k] for k in cache_keys}))
    elif FLAGS.decode_fori:
        # §Perf: fori_loop carrying the stacked cache; the ONLY write into
        # the big k/v buffers is the current token's (B, 1, KV, hd) slice at
        # (layer, :, slot) — the scan-ys path below makes XLA rewrite the
        # FULL stacked cache (with a bf16->f32 roundtrip) per layer.
        zero = jnp.zeros((), jnp.int32)

        def body(i, carry):
            h, st = carry
            bp = jax.tree.map(
                lambda a: lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                params["blocks"])
            st = dict(st)
            hin = L.apply_norm(bp["norm1"], cfg, h)
            a = m = None
            if cfg.has_attention:
                slot = L.cache_slot(cfg, pos, st["k"].shape[2])
                nk, nv = L.attn_decode_kv(bp["attn"], cfg, hin, pos)
                # write ONLY the new token: update shape (1, B, 1, KV, hd)
                st["k"] = lax.dynamic_update_slice(
                    st["k"], nk[None].astype(st["k"].dtype),
                    (i, zero, slot, zero, zero))
                st["v"] = lax.dynamic_update_slice(
                    st["v"], nv[None].astype(st["v"].dtype),
                    (i, zero, slot, zero, zero))
                ks = lax.dynamic_index_in_dim(st["k"], i, 0, keepdims=False)
                vs = lax.dynamic_index_in_dim(st["v"], i, 0, keepdims=False)
                a = L.attn_decode_read(bp["attn"], cfg, hin, pos, ks, vs,
                                       new_kpos)
            if cfg.has_ssm:
                ssm = lax.dynamic_index_in_dim(st["ssm"], i, 0, keepdims=False)
                conv = lax.dynamic_index_in_dim(st["conv"], i, 0, keepdims=False)
                m, nh, nconv = L.mamba_decode(bp["mamba"], cfg, hin, ssm,
                                              conv.astype(hin.dtype))
                st["ssm"] = lax.dynamic_update_index_in_dim(
                    st["ssm"], nh.astype(st["ssm"].dtype), i, 0)
                st["conv"] = lax.dynamic_update_index_in_dim(
                    st["conv"], nconv.astype(st["conv"].dtype), i, 0)
            if cfg.block == "attn":
                h = h + a
            elif cfg.block == "mamba":
                h = h + m
            else:
                h = h + 0.5 * (a + m)
            if cfg.d_ff:
                hin = L.apply_norm(bp["norm2"], cfg, h)
                y = (L.apply_moe(bp["ffn"], cfg, hin)[0] if cfg.is_moe
                     else L.apply_mlp(bp["ffn"], cfg, hin))
                h = h + y
            h = constrain(h)
            return h, st

        h, new_layers = lax.fori_loop(
            0, cfg.num_layers, body, (h, {k: cache[k] for k in cache_keys}))
    else:
        xs = {"bp": params["blocks"]}
        for key in cache_keys:
            xs[key] = cache[key]

        def body(h, x):
            return layer(x["bp"], h, x)

        h, new_layers = lax.scan(body, h, xs)

    new_cache = dict(cache)
    new_cache.update(new_layers)
    new_cache["pos"] = pos + 1
    if new_kpos is not None:
        new_cache["kpos"] = new_kpos
    logits = _unembed(params, cfg, h)[:, 0]
    return logits, new_cache
