"""Bidirectional text embedder — the paper's own model family.

bge-large-zh-v1.5 (326M, CLS pooling, 1024-d output) and jina-v2 (mean
pooling) style: BERT-like encoder stack + pooling + L2 normalisation.  This
is the model WindVE serves; its forward pass is what the queue manager's
CPU/NPU instances execute per batch of queries.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.kernels.pool_norm import pool_norm
from repro.models import layers as L

Params = Dict[str, Any]


def init_embedder(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    layer_keys = jax.random.split(ks[0], cfg.num_layers)

    def blk(k):
        k1, k2 = jax.random.split(k)
        return {
            "norm1": L.init_norm(cfg, dtype),
            "attn": L.init_attention(k1, cfg, dtype),
            "norm2": L.init_norm(cfg, dtype),
            "ffn": L.init_mlp(k2, cfg, dtype),
        }

    return {
        "embed": L._dense_init(ks[1], (cfg.vocab_size, cfg.d_model), dtype, scale=0.02),
        "blocks": jax.vmap(blk)(layer_keys),
        "final_norm": L.init_norm(cfg, dtype),
    }


def embed(params: Params, cfg: ModelConfig, tokens: jax.Array,
          mask: jax.Array | None = None, *,
          compute_dtype: Any = None, act_quant: bool = False) -> jax.Array:
    """tokens: (B, S) int32; mask: (B, S) 1=real token.  Returns (B, embed_dim)
    L2-normalised embeddings (the paper's 1024-d fp32 output vector).

    The mask is honoured END TO END: padded positions are excluded from every
    attention softmax (``kv_mask``), not just from pooling, so an embedding
    is invariant to how far its batch was padded — the property that lets
    the shape-bucketed backend (``repro.core.bucketing``) pad to the bucket
    instead of the global max and still serve identical vectors.  The
    pooling + L2-normalise epilogue runs through the fused
    ``repro.kernels.pool_norm`` op (Pallas kernel on TPU, jnp oracle here)
    and accumulates in fp32 for ANY compute dtype, so served vectors are
    always fp32 unit vectors.

    ``compute_dtype`` pins the trunk's activation dtype (every weight is cast
    to the activation dtype at use, see ``models.layers``): the serving
    backends pass ``jnp.float32`` for the precision oracle and
    ``jnp.bfloat16`` for bf16-resident serving; None keeps the global
    ``layers.COMPUTE_DTYPE`` default.  ``act_quant`` turns on W8A8
    projections (dynamic per-row int8 activation quantization against an
    int8-quantized param tree — see ``models.layers.dense_apply``); it is a
    no-op on float trees, and the pool epilogue stays fp32 regardless.
    """
    B, S = tokens.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    cdt = L.COMPUTE_DTYPE if compute_dtype is None else compute_dtype
    h = params["embed"][tokens].astype(cdt)
    h = h + L.sinusoidal_positions(positions, cfg.d_model).astype(h.dtype)
    kv_mask = mask          # None -> every position is a real token

    def body(h, bp):
        hin = L.apply_norm(bp["norm1"], cfg, h)
        h = h + L.attn_forward(bp["attn"], cfg, hin, positions, causal=False,
                               kv_mask=kv_mask, act_quant=act_quant)
        hin = L.apply_norm(bp["norm2"], cfg, h)
        h = h + L.apply_mlp(bp["ffn"], cfg, hin, act_quant=act_quant)
        return h, None

    h, _ = lax.scan(body, h, params["blocks"])
    h = L.apply_norm(params["final_norm"], cfg, h)

    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    return pool_norm(h, mask, pool="mean" if cfg.pool == "mean" else "cls")
