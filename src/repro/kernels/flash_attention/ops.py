"""Backend-dispatching jit wrapper for flash attention.

* TPU backend       -> compiled Pallas kernel
* everything else   -> chunked pure-JAX flash (models.layers) — same math
* tests             -> Pallas interpret mode vs ref.py oracle
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "backend",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    backend: str = "auto", block_q: int = 128,
                    block_k: int = 128, kv_len=None):
    """q: (B, H, Sq, hd); k, v: (B, KV, Sk, hd) -> (B, H, Sq, hd).

    ``kv_len`` (optional, (B,) int32): per-example valid-key prefix — the
    ragged-batch masking the bucketed embedder needs."""
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend == "pallas":
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      block_q=block_q, block_k=block_k,
                                      interpret=False, kv_len=kv_len)
    if backend == "interpret":
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      block_q=block_q, block_k=block_k,
                                      interpret=True, kv_len=kv_len)
    from repro.models.layers import flash_attention_jnp

    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    kv_mask = None
    if kv_len is not None:
        kv_mask = jnp.arange(Sk, dtype=jnp.int32)[None, :] < kv_len[:, None]
    out = flash_attention_jnp(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
        jnp.arange(Sq, dtype=jnp.int32), jnp.arange(Sk, dtype=jnp.int32),
        causal=causal, window=window, kv_mask=kv_mask)
    return jnp.moveaxis(out, 2, 1)


__all__ = ["flash_attention", "flash_attention_pallas", "attention_ref"]
