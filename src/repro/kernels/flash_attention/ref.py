"""Pure-jnp oracle for flash attention: naive full-score attention.

Deliberately the SIMPLEST correct implementation (materialises the (Sq, Sk)
score matrix) — used only at test sizes.  The production pure-JAX path is
``repro.models.layers.flash_attention_jnp`` (chunked online softmax) and the
TPU path is the Pallas kernel; both are validated against this."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True, window: int = 0,
                  sq_valid: int | None = None, sk_valid: int | None = None,
                  kv_len: jax.Array | None = None) -> jax.Array:
    """q: (B, H, Sq, hd); k, v: (B, KV, Sk, hd).  GQA via H = KV * G.
    ``kv_len`` (optional, (B,)): per-example valid-key prefix length.
    Returns (B, H, Sq, hd) fp32-accurate attention output."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, G, Sq, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqh,bksh->bkgqs", qf, kf) / math.sqrt(hd)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    valid = jnp.ones((Sq, Sk), bool)
    if sq_valid is not None:
        valid &= qp < sq_valid
    if sk_valid is not None:
        valid &= kp < sk_valid
    if causal:
        valid &= kp <= qp
    if window:
        valid &= kp > qp - window
    s = jnp.where(valid[None, None, None], s, -jnp.inf)
    if kv_len is not None:
        kvalid = jnp.arange(Sk)[None, :] < kv_len[:, None]          # (B, Sk)
        s = jnp.where(kvalid[:, None, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)
    out = jnp.einsum("bkgqs,bksh->bkgqh", w, vf)
    return out.reshape(B, H, Sq, hd).astype(q.dtype)
