"""Flash attention Pallas TPU kernel: blockwise online softmax.

TPU adaptation of the attention hot-spot (DESIGN.md §6):
* grid = (B, H, num_q_blocks, num_kv_blocks); the kv dim is the innermost
  (sequential) axis so the (block_q, hd) accumulator, running max and
  denominator live in VMEM scratch across kv steps — score blocks NEVER
  touch HBM (the pure-JAX path materialises them; see §Roofline notes).
* BlockSpecs tile q/o as (1, 1, block_q, head_dim) and k/v as
  (1, 1, block_k, head_dim) — MXU-aligned when block_* are multiples of 128
  and head_dim is 64/128.
* GQA is expressed in the k/v index_map (kv_head = head // group_size), so
  grouped queries reuse the same k/v VMEM tile with no gather.
* causal / sliding-window masks come from program-id iota — no mask tensor.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, kvl_ref, o_ref, acc_ref, m_ref, d_ref,
                  *, scale: float, causal: bool, window: int,
                  sq: int, block_q: int, block_k: int, nk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref)

    q = q_ref[0, 0]                                      # (bq, hd)
    k = k_ref[0, 0]                                      # (bk, hd)
    v = v_ref[0, 0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qp = qi * block_q + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kp = ki * block_k + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    # per-example valid-key prefix (ragged batches: bucketed embedder pads
    # each row to the bucket; padded keys must not enter the softmax)
    valid = (qp < sq) & (kp < kvl_ref[0, 0])
    if causal:
        valid &= kp <= qp
    if window:
        valid &= kp > qp - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    d_ref[...] = d_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jnp.dot(p.astype(v.dtype), v,
                              preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(d_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True,
                           kv_len: jax.Array | None = None) -> jax.Array:
    """q: (B, H, Sq, hd); k, v: (B, KV, Sk, hd) -> (B, H, Sq, hd).

    ``kv_len`` (optional, (B,) int32): per-example count of valid keys —
    keys at positions >= kv_len[b] are masked out (ragged/bucketed batches
    where each row is left-aligned and padded to the bucket).  Defaults to
    all Sk keys valid.  On this container the kernel body executes via
    interpret=True (CPU); on TPU pass interpret=False for the compiled MXU
    path."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    assert H % KV == 0, "num_heads must be a multiple of num_kv_heads"
    G = H // KV
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    nq = -(-Sq // bq)
    nk = -(-Sk // bk)
    pq, pk = nq * bq - Sq, nk * bk - Sk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    if kv_len is None:
        kv_len = jnp.full((B,), Sk, jnp.int32)
    # (B, 1) scalar-per-block in SMEM: one bound per batch row
    kvl = jnp.minimum(kv_len.astype(jnp.int32), Sk).reshape(B, 1)

    # the per-example kvl bound (clamped to the unpadded Sk) also masks the
    # block-padding key tail, so no separate `kp < Sk` guard is needed
    kernel = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(hd), causal=causal,
        window=window, sq=Sq, block_q=bq, block_k=bk, nk=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1), lambda b, h, qi, ki: (b, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),   # output accumulator
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running denominator
        ],
        interpret=interpret,
    )(q, k, v, kvl)
    return out[:, :, :Sq]
