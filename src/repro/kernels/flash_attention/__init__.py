from repro.kernels.flash_attention.ops import (attention_ref, flash_attention,
                                               flash_attention_pallas)

__all__ = ["flash_attention", "flash_attention_pallas", "attention_ref"]
