from repro.kernels.rmsnorm.ops import rmsnorm, rmsnorm_pallas, rmsnorm_ref

__all__ = ["rmsnorm", "rmsnorm_pallas", "rmsnorm_ref"]
