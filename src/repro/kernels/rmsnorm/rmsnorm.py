"""Fused RMSNorm Pallas TPU kernel.

One pass over a (block_rows, D) VMEM tile: fp32 mean-square reduce + rsqrt
+ scale, written back in the input dtype.  Unfused XLA does this as three
HBM round-trips on the residual stream; fused it is one read + one write.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)           # (br, D)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x: jax.Array, scale: jax.Array, eps: float = 1e-5, *,
                   block_rows: int = 256, interpret: bool = True) -> jax.Array:
    """x: (..., D); scale: (D,)."""
    orig_shape = x.shape
    D = x.shape[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    br = min(block_rows, R)
    nr = -(-R // br)
    pad = nr * br - R
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((br, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nr * br, D), x.dtype),
        interpret=interpret,
    )(xf, scale)
    return out[:R].reshape(orig_shape)
