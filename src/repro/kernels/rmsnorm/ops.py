"""Backend-dispatching jit wrapper for fused RMSNorm."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.rmsnorm.rmsnorm import rmsnorm_pallas


@functools.partial(jax.jit, static_argnames=("eps", "backend", "block_rows"))
def rmsnorm(x, scale, eps: float = 1e-5, *, backend: str = "auto",
            block_rows: int = 256):
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "pallas":
        return rmsnorm_pallas(x, scale, eps, block_rows=block_rows,
                              interpret=False)
    if backend == "interpret":
        return rmsnorm_pallas(x, scale, eps, block_rows=block_rows,
                              interpret=True)
    return rmsnorm_ref(x, scale, eps)


__all__ = ["rmsnorm", "rmsnorm_pallas", "rmsnorm_ref"]
