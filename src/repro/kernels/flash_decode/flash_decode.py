"""Flash-decode Pallas TPU kernel: one query token against a long KV cache.

This is the kernel twin of the shard_map flash-decode serving path
(models.layers.attn_decode_sharded): each shard's LOCAL cache slice is
attended by this kernel; the cross-shard pmax/psum combine stays in
shard_map.  Design:

* grid = (B, KV, num_k_blocks) with the k-block axis innermost/sequential;
  the (G, hd) accumulator + running max/denom live in VMEM scratch, so the
  (G, block_k) score tile never touches HBM — decode becomes a pure
  cache-streaming workload (the roofline minimum).
* masking uses the kpos slot-position array (ring-buffer aware: slots carry
  absolute positions, so sliding-window archs work unchanged).
* block_k is a multiple of 128 for lane alignment; G x hd output tiles are
  VREG-friendly for every assigned GQA group size (1..8).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fd_kernel(pos_ref, q_ref, k_ref, v_ref, kp_ref, o_ref,
               acc_ref, m_ref, d_ref, *, scale: float, window: int, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref)

    q = q_ref[0, 0]                          # (G, hd)
    k = k_ref[0, :, 0, :]                    # (bk, hd)
    v = v_ref[0, :, 0, :]
    kp = kp_ref[...]                         # (bk,)
    pos = pos_ref[0]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G, bk)
    valid = (kp >= 0) & (kp <= pos)
    if window:
        valid &= kp > pos - window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(valid[None, :], p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    d_ref[...] = d_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jnp.dot(p.astype(v.dtype), v,
                              preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _fin():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(d_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_decode_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                        kpos: jax.Array, pos, *, window: int = 0,
                        block_k: int = 256,
                        interpret: bool = True) -> jax.Array:
    """q: (B, KV, G, hd); k, v: (B, S, KV, hd); kpos: (S,) -> (B, KV, G, hd)."""
    B, KV, G, hd = q.shape
    S = k.shape[1]
    bk = min(block_k, S)
    nk = -(-S // bk)
    pad = nk * bk - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad), constant_values=-1)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    kernel = functools.partial(_fd_kernel, scale=1.0 / math.sqrt(hd),
                               window=window, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, KV, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ki: (0,)),                 # pos
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ki: (b, h, 0, 0)),  # q
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, ki: (b, ki, h, 0)),  # k
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, ki: (b, ki, h, 0)),  # v
            pl.BlockSpec((bk,), lambda b, h, ki: (ki,)),               # kpos
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, q, k, v, kpos)
