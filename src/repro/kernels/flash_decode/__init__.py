from repro.kernels.flash_decode.ops import (decode_attention_ref,
                                            flash_decode, flash_decode_pallas)

__all__ = ["flash_decode", "flash_decode_pallas", "decode_attention_ref"]
