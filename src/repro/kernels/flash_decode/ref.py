"""Pure-jnp oracle for single-token flash-decode attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kpos: jax.Array, pos, window: int = 0) -> jax.Array:
    """q: (B, KV, G, hd); k, v: (B, S, KV, hd); kpos: (S,) absolute position
    per cache slot (-1 = empty); pos: scalar current position.
    Returns (B, KV, G, hd)."""
    hd = q.shape[-1]
    s = jnp.einsum("bkgh,bskh->bkgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    valid = (kpos >= 0) & (kpos <= pos)
    if window:
        valid &= kpos > pos - window
    s = jnp.where(valid[None, None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)
    out = jnp.einsum("bkgs,bskh->bkgh", w, v.astype(jnp.float32))
    return out.astype(q.dtype)
