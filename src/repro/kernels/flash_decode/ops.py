"""Backend-dispatching jit wrapper for flash-decode attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_decode.flash_decode import flash_decode_pallas
from repro.kernels.flash_decode.ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("window", "backend", "block_k"))
def flash_decode(q, k, v, kpos, pos, *, window: int = 0,
                 backend: str = "auto", block_k: int = 256):
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "pallas":
        return flash_decode_pallas(q, k, v, kpos, pos, window=window,
                                   block_k=block_k, interpret=False)
    if backend == "interpret":
        return flash_decode_pallas(q, k, v, kpos, pos, window=window,
                                   block_k=block_k, interpret=True)
    return decode_attention_ref(q, k, v, kpos, pos, window=window)


__all__ = ["flash_decode", "flash_decode_pallas", "decode_attention_ref"]
