"""Pure-jnp oracle for the fused masked-pool + L2-normalize epilogue."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pool_norm_ref(h: jax.Array, mask: jax.Array,
                  pool: str = "mean") -> jax.Array:
    """h: (B, S, D) hidden states; mask: (B, S) 1 = real token.

    pool: "mean" (jina-style masked mean) or "cls" (bge-style first token).
    Returns (B, D) float32 L2-normalised embeddings; a fully-masked row
    (a bucketed batch's padding row) pools to the zero vector.
    """
    hf = h.astype(jnp.float32)
    m = mask.astype(jnp.float32)
    if pool == "mean":
        pooled = (hf * m[..., None]).sum(1) / jnp.maximum(
            m.sum(1, keepdims=True), 1.0)
    elif pool == "cls":
        pooled = hf[:, 0] * jnp.minimum(m[:, :1], 1.0)
    else:
        raise ValueError(f"unknown pool mode {pool!r}")
    return pooled / jnp.maximum(
        jnp.linalg.norm(pooled, axis=-1, keepdims=True), 1e-9)
