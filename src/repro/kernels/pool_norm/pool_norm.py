"""Fused masked-pool + L2-normalize Pallas TPU kernel.

The embedder's serving epilogue: mask-weighted pooling over the sequence
axis and L2 normalisation of the pooled vector, in ONE pass over a
(block_b, S, D) VMEM tile.  Unfused XLA lowers this tail as separate
multiply / reduce / norm / divide HBM round-trips over the (B, S, D)
hidden-state tensor; fused it is one read of the hiddens + one (B, D)
write.  Pooling and the norm both accumulate in fp32 regardless of the
compute dtype (the paper serves fp32 embedding vectors).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pool_norm_kernel(h_ref, m_ref, o_ref, *, pool: str):
    h = h_ref[...].astype(jnp.float32)           # (bb, S, D)
    m = m_ref[...].astype(jnp.float32)           # (bb, S)
    if pool == "mean":
        pooled = (h * m[..., None]).sum(1) / jnp.maximum(
            m.sum(1, keepdims=True), 1.0)
    else:  # cls — zeroed for fully-masked (padding) rows, like the ref
        pooled = h[:, 0] * jnp.minimum(m[:, :1], 1.0)
    nrm = jnp.sqrt(jnp.sum(pooled * pooled, axis=-1, keepdims=True))
    o_ref[...] = pooled / jnp.maximum(nrm, 1e-9)


def pool_norm_pallas(h: jax.Array, mask: jax.Array, pool: str = "mean", *,
                     block_b: int = 8, interpret: bool = True) -> jax.Array:
    """h: (B, S, D); mask: (B, S) -> (B, D) float32, L2-normalised."""
    if pool not in ("mean", "cls"):
        raise ValueError(f"unknown pool mode {pool!r}")
    B, S, D = h.shape
    bb = min(block_b, B)
    nb = -(-B // bb)
    pad = nb * bb - B
    if pad:
        # padding rows carry an all-zero mask -> they pool to zero vectors
        h = jnp.pad(h, ((0, pad), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_pool_norm_kernel, pool=pool),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bb, S, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, S), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb * bb, D), jnp.float32),
        interpret=interpret,
    )(h, mask)
    return out[:B]
