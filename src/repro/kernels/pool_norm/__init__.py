from repro.kernels.pool_norm.ops import (pool_norm, pool_norm_pallas,
                                         pool_norm_ref)

__all__ = ["pool_norm", "pool_norm_pallas", "pool_norm_ref"]
