"""Backend-dispatching jit wrapper for fused masked-pool + L2-normalize."""
from __future__ import annotations

import functools

import jax

from repro.kernels.pool_norm.pool_norm import pool_norm_pallas
from repro.kernels.pool_norm.ref import pool_norm_ref


@functools.partial(jax.jit, static_argnames=("pool", "backend", "block_b"))
def pool_norm(h, mask, pool: str = "mean", *, backend: str = "auto",
              block_b: int = 8):
    """h: (B, S, D); mask: (B, S) -> (B, D) float32 unit vectors."""
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "pallas":
        return pool_norm_pallas(h, mask, pool, block_b=block_b,
                                interpret=False)
    if backend == "interpret":
        return pool_norm_pallas(h, mask, pool, block_b=block_b,
                                interpret=True)
    return pool_norm_ref(h, mask, pool)


__all__ = ["pool_norm", "pool_norm_pallas", "pool_norm_ref"]
