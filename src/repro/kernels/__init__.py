# Pallas TPU kernels for the compute hot-spots (DESIGN.md §6), each with an
# ops.py jit wrapper (backend dispatch) and a ref.py pure-jnp oracle:
#   flash_attention/ — blockwise online-softmax attention (GQA, SWA, ragged)
#   flash_decode/    — single-token decode attention vs a long KV cache
#   ssm_scan/        — mamba-1 selective scan, chunked, state in VMEM
#   rmsnorm/         — fused residual-stream normalisation
#   pool_norm/       — fused masked-pool + L2-normalize embedder epilogue
from repro.kernels import (flash_attention, flash_decode, pool_norm, rmsnorm,
                           ssm_scan)

__all__ = ["flash_attention", "flash_decode", "ssm_scan", "rmsnorm",
           "pool_norm"]
