"""Fused int8-weight x float-activation matmul Pallas TPU kernel.

The serving trunk's dense projections with weight-only quantized params:
each (block_m, block_k) activation tile contracts against a (block_k,
block_n) **int8** weight tile straight out of VMEM — the weights travel
HBM->VMEM at 1 byte/element (4x less traffic than fp32-resident serving,
2x less than bf16) and are widened to the activation dtype only inside the
tile, in registers.  Accumulation is fp32 across the K grid axis in a VMEM
scratch; the per-output-channel dequant scale is applied ONCE in the
epilogue on the final K step, so a dequantized weight matrix never exists
in any memory space.

Tiling note (guide §Tiling Constraints): int8 VMEM tiles want (32, 128)
sublane x lane minima, so the defaults keep ``block_k`` / ``block_n`` at
128 multiples; ragged M/K/N are zero-padded to the block grid (zero rows
contract to zero and the padded output is sliced off).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _quant_matmul_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                   # (bm, bk) activations
    w = w_ref[...].astype(x.dtype)                   # (bk, bn) int8 widened
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        scale = s_ref[...].astype(jnp.float32)       # (bn,) per out channel
        o_ref[...] = (acc_ref[...] * scale[None, :]).astype(o_ref.dtype)


def quant_matmul_pallas(x: jax.Array, w8: jax.Array, scale: jax.Array, *,
                        block_m: int = 128, block_n: int = 128,
                        block_k: int = 128, out_dtype=None,
                        interpret: bool = True) -> jax.Array:
    """x: (..., K) float; w8: (K, N) int8; scale: (N,) -> (..., N)."""
    if w8.dtype != jnp.int8:
        raise TypeError(f"quantized weights must be int8, got {w8.dtype}")
    *lead, K = x.shape
    N = w8.shape[1]
    out_dtype = x.dtype if out_dtype is None else out_dtype
    xf = x.reshape(-1, K)
    M = xf.shape[0]
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    nm, nn, nk = -(-M // bm), -(-N // bn), -(-K // bk)
    pm, pn, pk = nm * bm - M, nn * bn - N, nk * bk - K
    if pm or pk:
        xf = jnp.pad(xf, ((0, pm), (0, pk)))
    if pk or pn:
        w8 = jnp.pad(w8, ((0, pk), (0, pn)))
    if pn:
        scale = jnp.pad(scale, (0, pn), constant_values=1.0)
    out = pl.pallas_call(
        functools.partial(_quant_matmul_kernel, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nm * bm, nn * bn), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xf, w8, scale)
    return out[:M, :N].reshape(*lead, N)
