"""Fused int8 quant matmul Pallas TPU kernels (W8A16/W8A32 and W8A8).

``quant_matmul_pallas`` is the weight-only variant: each (block_m, block_k)
float activation tile contracts against a (block_k, block_n) **int8** weight
tile straight out of VMEM — the weights travel HBM->VMEM at 1 byte/element
(4x less traffic than fp32-resident serving, 2x less than bf16) and are
widened to the activation dtype only inside the tile, in registers.
Accumulation is fp32 across the K grid axis in a VMEM scratch; the
per-output-channel dequant scale is applied ONCE in the epilogue on the
final K step, so a dequantized weight matrix never exists in any memory
space.

``w8a8_matmul_pallas`` goes the rest of the way: int8 activations (produced
by ``quantize_activations``' per-row dynamic symmetric scheme) contract
against the int8 weights with **int32** accumulation
(``preferred_element_type=jnp.int32``) in a VMEM scratch — no int8->float
widening inside the tile, so the contraction is eligible for the MXU's int8
rate and the activation side of HBM traffic shrinks 4x too.  Dequant happens
once in the epilogue as ``act_scale[:, None] * w_scale[None, :]``.

Tiling note (guide §Tiling Constraints): int8 VMEM tiles want (32, 128)
sublane x lane minima, so the defaults keep ``block_k`` / ``block_n`` at
128 multiples; ragged M/K/N are zero-padded to the block grid (zero rows
contract to zero — exactly, in int32 — and the padded output is sliced
off).  Padded scale lanes are 1.0 so the epilogue multiply stays finite.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _default_interpret() -> bool:
    """Interpret everywhere except a real TPU backend (compiled there).

    Mirrors the ``auto`` route in ``ops``: the Mosaic-compiled path only
    exists on TPU; on CPU/GPU hosts the kernels run under the Pallas
    interpreter so tests and smoke benches exercise the same code path.
    """
    return jax.default_backend() != "tpu"


def quantize_activations(x: jax.Array):
    """Per-row dynamic symmetric int8 quantization of ``x: (..., K)``.

    Returns ``(x8, scale)`` with ``x8`` int8 of x's shape and ``scale``
    fp32 of shape ``x.shape[:-1]`` such that ``x8 * scale[..., None] ~= x``.
    The scale divide is guarded twice: all-zero rows get scale 1.0 (their
    quantized row is exactly zero), and subnormal absmax rows clamp the
    scale to the smallest normal fp32 so ``x / scale`` can never overflow
    past the [-127, 127] clip (|x| <= absmax < 127 * tiny => |x/scale| < 127).
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    tiny = jnp.float32(jnp.finfo(jnp.float32).tiny)
    scale = jnp.maximum(amax / 127.0, tiny)
    scale = jnp.where(amax > 0, scale, 1.0)
    x8 = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127).astype(jnp.int8)
    return x8, scale


def _quant_matmul_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                   # (bm, bk) activations
    w = w_ref[...].astype(x.dtype)                   # (bk, bn) int8 widened
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        scale = s_ref[...].astype(jnp.float32)       # (bn,) per out channel
        o_ref[...] = (acc_ref[...] * scale[None, :]).astype(o_ref.dtype)


def quant_matmul_pallas(x: jax.Array, w8: jax.Array, scale: jax.Array, *,
                        block_m: int = 128, block_n: int = 128,
                        block_k: int = 128, out_dtype=None,
                        interpret: bool | None = None) -> jax.Array:
    """x: (..., K) float; w8: (K, N) int8; scale: (N,) -> (..., N).

    ``interpret=None`` resolves from the active backend (compiled on TPU,
    interpreted elsewhere) — never default to the interpreter on hardware
    that has the real lowering.
    """
    if w8.dtype != jnp.int8:
        raise TypeError(f"quantized weights must be int8, got {w8.dtype}")
    if interpret is None:
        interpret = _default_interpret()
    *lead, K = x.shape
    N = w8.shape[1]
    out_dtype = x.dtype if out_dtype is None else out_dtype
    xf = x.reshape(-1, K)
    M = xf.shape[0]
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    nm, nn, nk = -(-M // bm), -(-N // bn), -(-K // bk)
    pm, pn, pk = nm * bm - M, nn * bn - N, nk * bk - K
    if pm or pk:
        xf = jnp.pad(xf, ((0, pm), (0, pk)))
    if pk or pn:
        w8 = jnp.pad(w8, ((0, pk), (0, pn)))
    if pn:
        scale = jnp.pad(scale, (0, pn), constant_values=1.0)
    out = pl.pallas_call(
        functools.partial(_quant_matmul_kernel, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nm * bm, nn * bn), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xf, w8, scale)
    return out[:M, :N].reshape(*lead, N)


def _w8a8_matmul_kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_ref, *,
                        nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # int8 x int8 -> int32: both operands stay int8 into the dot so the
    # contraction is MXU-int8-eligible; the accumulator is exact.
    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _epilogue():
        xs = xs_ref[...].astype(jnp.float32)         # (bm,) per activation row
        ws = ws_ref[...].astype(jnp.float32)         # (bn,) per out channel
        o_ref[...] = (acc_ref[...].astype(jnp.float32)
                      * xs[:, None] * ws[None, :]).astype(o_ref.dtype)


def w8a8_matmul_pallas(x8: jax.Array, w8: jax.Array, x_scale: jax.Array,
                       w_scale: jax.Array, *, block_m: int = 128,
                       block_n: int = 128, block_k: int = 128,
                       out_dtype=jnp.float32,
                       interpret: bool | None = None) -> jax.Array:
    """x8: (..., K) int8; w8: (K, N) int8; x_scale: x8.shape[:-1];
    w_scale: (N,) -> (..., N) float.

    Accumulates int32 in VMEM scratch across the K grid axis and dequantizes
    once in the epilogue by ``x_scale[:, None] * w_scale[None, :]`` — neither
    operand is ever widened to float inside the tile.
    """
    if x8.dtype != jnp.int8:
        raise TypeError(f"quantized activations must be int8, got {x8.dtype}")
    if w8.dtype != jnp.int8:
        raise TypeError(f"quantized weights must be int8, got {w8.dtype}")
    if interpret is None:
        interpret = _default_interpret()
    *lead, K = x8.shape
    N = w8.shape[1]
    xq = x8.reshape(-1, K)
    xs = x_scale.reshape(-1)
    M = xq.shape[0]
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    nm, nn, nk = -(-M // bm), -(-N // bn), -(-K // bk)
    pm, pn, pk = nm * bm - M, nn * bn - N, nk * bk - K
    if pm or pk:
        xq = jnp.pad(xq, ((0, pm), (0, pk)))
    if pm:
        xs = jnp.pad(xs, (0, pm), constant_values=1.0)
    if pk or pn:
        w8 = jnp.pad(w8, ((0, pk), (0, pn)))
    if pn:
        w_scale = jnp.pad(w_scale, (0, pn), constant_values=1.0)
    out = pl.pallas_call(
        functools.partial(_w8a8_matmul_kernel, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm,), lambda i, j, k: (i,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nm * bm, nn * bn), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(xq, w8, xs, w_scale)
    return out[:M, :N].reshape(*lead, N)
