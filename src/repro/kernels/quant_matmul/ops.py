"""Backend-dispatching jit wrappers for the fused int8 quant matmuls.

``_quant_matmul`` / ``_quant_matmul_w8a8`` are the unjitted impls (exposed
so dispatch tests can record which route fires without fighting jit
caches); ``quant_matmul`` / ``quant_matmul_w8a8`` are the jitted entries
every serving call site uses.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.quant_matmul import quant_matmul as _kmod
from repro.kernels.quant_matmul import ref as _rmod
from repro.kernels.quant_matmul.quant_matmul import (quantize_activations,
                                                    quant_matmul_pallas,
                                                    w8a8_matmul_pallas)
from repro.kernels.quant_matmul.ref import quant_matmul_ref, w8a8_matmul_ref


def _resolve_backend(backend: str) -> str:
    """``auto`` routes to the Pallas kernel exactly when running on a TPU
    backend (where int8 VMEM tiles pay off); everywhere else the jnp oracle
    is the same contract, lowered through XLA."""
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return backend


def _quant_matmul(x, w8, scale, *, backend: str = "auto", block_m: int = 128,
                  block_n: int = 128, block_k: int = 128):
    backend = _resolve_backend(backend)
    if backend == "pallas":
        return _kmod.quant_matmul_pallas(x, w8, scale, block_m=block_m,
                                         block_n=block_n, block_k=block_k,
                                         interpret=False)
    if backend == "interpret":
        return _kmod.quant_matmul_pallas(x, w8, scale, block_m=block_m,
                                         block_n=block_n, block_k=block_k,
                                         interpret=True)
    return _rmod.quant_matmul_ref(x, w8, scale)


@functools.partial(jax.jit, static_argnames=("backend", "block_m", "block_n",
                                             "block_k"))
def quant_matmul(x, w8, scale, *, backend: str = "auto", block_m: int = 128,
                 block_n: int = 128, block_k: int = 128):
    """x: (..., K) float; w8: (K, N) int8; scale: (N,) fp32 -> (..., N).

    Weight-only route: float activations, fp32 accumulation, dequant-by-
    weight-scale epilogue (W8A16/W8A32 depending on the activation dtype).
    """
    return _quant_matmul(x, w8, scale, backend=backend, block_m=block_m,
                         block_n=block_n, block_k=block_k)


def _quant_matmul_w8a8(x, w8, w_scale, *, backend: str = "auto",
                       block_m: int = 128, block_n: int = 128,
                       block_k: int = 128):
    x8, x_scale = quantize_activations(x)
    backend = _resolve_backend(backend)
    if backend == "pallas":
        return _kmod.w8a8_matmul_pallas(x8, w8, x_scale, w_scale,
                                        block_m=block_m, block_n=block_n,
                                        block_k=block_k, out_dtype=x.dtype,
                                        interpret=False)
    if backend == "interpret":
        return _kmod.w8a8_matmul_pallas(x8, w8, x_scale, w_scale,
                                        block_m=block_m, block_n=block_n,
                                        block_k=block_k, out_dtype=x.dtype,
                                        interpret=True)
    return _rmod.w8a8_matmul_ref(x8, w8, x_scale, w_scale, out_dtype=x.dtype)


@functools.partial(jax.jit, static_argnames=("backend", "block_m", "block_n",
                                             "block_k"))
def quant_matmul_w8a8(x, w8, w_scale, *, backend: str = "auto",
                      block_m: int = 128, block_n: int = 128,
                      block_k: int = 128):
    """x: (..., K) float; w8: (K, N) int8; w_scale: (N,) fp32 -> (..., N).

    W8A8 route: quantizes the activations on the fly (per-row dynamic
    symmetric absmax — fused into the same jit so the int8 activations are
    produced right where the kernel consumes them), contracts int8 x int8
    with int32 accumulation, and dequantizes once in the epilogue by
    ``act_scale[:, None] * w_scale[None, :]``.
    """
    return _quant_matmul_w8a8(x, w8, w_scale, backend=backend,
                              block_m=block_m, block_n=block_n,
                              block_k=block_k)


__all__ = ["quant_matmul", "quant_matmul_w8a8", "quant_matmul_pallas",
           "w8a8_matmul_pallas", "quant_matmul_ref", "w8a8_matmul_ref",
           "quantize_activations"]
