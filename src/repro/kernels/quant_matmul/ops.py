"""Backend-dispatching jit wrapper for the fused int8 quant matmul."""
from __future__ import annotations

import functools

import jax

from repro.kernels.quant_matmul.quant_matmul import quant_matmul_pallas
from repro.kernels.quant_matmul.ref import quant_matmul_ref


@functools.partial(jax.jit, static_argnames=("backend", "block_m", "block_n",
                                             "block_k"))
def quant_matmul(x, w8, scale, *, backend: str = "auto", block_m: int = 128,
                 block_n: int = 128, block_k: int = 128):
    """x: (..., K) float; w8: (K, N) int8; scale: (N,) fp32 -> (..., N).

    ``auto`` routes to the Pallas kernel exactly when running on a TPU
    backend (where int8 VMEM tiles pay off); everywhere else the jnp
    oracle is the same contract — fp32 accumulation, dequant-by-scale
    epilogue — lowered through XLA.
    """
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "pallas":
        return quant_matmul_pallas(x, w8, scale, block_m=block_m,
                                   block_n=block_n, block_k=block_k,
                                   interpret=False)
    if backend == "interpret":
        return quant_matmul_pallas(x, w8, scale, block_m=block_m,
                                   block_n=block_n, block_k=block_k,
                                   interpret=True)
    return quant_matmul_ref(x, w8, scale)


__all__ = ["quant_matmul", "quant_matmul_pallas", "quant_matmul_ref"]
