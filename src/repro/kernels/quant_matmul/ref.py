"""Pure-jnp oracles for the fused int8 quant matmuls.

The contract every backend route must honour — weight-only
(``quant_matmul_ref``): int8 weights x float activations, fp32 MXU
accumulation, and the per-output-channel dequant scale applied ONCE in the
epilogue (weight-only symmetric quantization has no zero point, so
``x @ (w8 * s) == (x @ w8) * s`` exactly in real arithmetic — applying the
scale after the contraction is what makes the kernel "fused": the
dequantized fp32/bf16 weight matrix is never materialised).

W8A8 (``w8a8_matmul_ref``): int8 activations x int8 weights with **int32**
accumulation (exact — 2^31 comfortably covers K * 127^2 for any K the trunk
contracts), dequantized once by the outer product of the per-row activation
scale and the per-output-channel weight scale.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def quant_matmul_ref(x: jax.Array, w8: jax.Array,
                     scale: jax.Array) -> jax.Array:
    """x: (..., K) float; w8: (K, N) int8; scale: (N,) fp32 per-out-channel.

    Returns (..., N) in ``x.dtype``.  Every int8 value in [-127, 127] is
    exactly representable in bf16 (8 mantissa bits cover integers to 256),
    so casting the weights to the activation dtype loses nothing; the
    contraction accumulates fp32 via ``preferred_element_type``.
    """
    if w8.dtype != jnp.int8:
        raise TypeError(f"quantized weights must be int8, got {w8.dtype}")
    acc = lax.dot_general(x, w8.astype(x.dtype),
                          (((x.ndim - 1,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32)
    return (acc * scale.astype(jnp.float32)).astype(x.dtype)


def w8a8_matmul_ref(x8: jax.Array, w8: jax.Array, x_scale: jax.Array,
                    w_scale: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    """x8: (..., K) int8; w8: (K, N) int8; x_scale: x8.shape[:-1] fp32;
    w_scale: (N,) fp32 -> (..., N) in ``out_dtype``.

    Both operands enter the contraction as int8 and accumulate int32
    (``preferred_element_type=jnp.int32``), matching the Pallas kernel's
    exact integer arithmetic; dequant is the single epilogue multiply
    ``acc * x_scale[..., None] * w_scale``.
    """
    if x8.dtype != jnp.int8:
        raise TypeError(f"quantized activations must be int8, got {x8.dtype}")
    if w8.dtype != jnp.int8:
        raise TypeError(f"quantized weights must be int8, got {w8.dtype}")
    acc = lax.dot_general(x8, w8, (((x8.ndim - 1,), (0,)), ((), ())),
                          preferred_element_type=jnp.int32)
    out = (acc.astype(jnp.float32)
           * x_scale[..., None].astype(jnp.float32)
           * w_scale.astype(jnp.float32))
    return out.astype(out_dtype)
