"""Pure-jnp oracle for the fused int8 weight-only quant matmul.

The contract every backend route must honour: int8 weights x float
activations, fp32 MXU accumulation, and the per-output-channel dequant
scale applied ONCE in the epilogue (weight-only symmetric quantization has
no zero point, so ``x @ (w8 * s) == (x @ w8) * s`` exactly in real
arithmetic — applying the scale after the contraction is what makes the
kernel "fused": the dequantized fp32/bf16 weight matrix is never
materialised).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def quant_matmul_ref(x: jax.Array, w8: jax.Array,
                     scale: jax.Array) -> jax.Array:
    """x: (..., K) float; w8: (K, N) int8; scale: (N,) fp32 per-out-channel.

    Returns (..., N) in ``x.dtype``.  Every int8 value in [-127, 127] is
    exactly representable in bf16 (8 mantissa bits cover integers to 256),
    so casting the weights to the activation dtype loses nothing; the
    contraction accumulates fp32 via ``preferred_element_type``.
    """
    if w8.dtype != jnp.int8:
        raise TypeError(f"quantized weights must be int8, got {w8.dtype}")
    acc = lax.dot_general(x, w8.astype(x.dtype),
                          (((x.ndim - 1,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32)
    return (acc * scale.astype(jnp.float32)).astype(x.dtype)
