from repro.kernels.quant_matmul.ops import (quant_matmul, quant_matmul_pallas,
                                            quant_matmul_ref,
                                            quant_matmul_w8a8,
                                            quantize_activations,
                                            w8a8_matmul_pallas,
                                            w8a8_matmul_ref)

__all__ = ["quant_matmul", "quant_matmul_pallas", "quant_matmul_ref",
           "quant_matmul_w8a8", "w8a8_matmul_pallas", "w8a8_matmul_ref",
           "quantize_activations"]
