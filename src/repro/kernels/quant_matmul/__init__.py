from repro.kernels.quant_matmul.ops import (quant_matmul, quant_matmul_pallas,
                                            quant_matmul_ref)

__all__ = ["quant_matmul", "quant_matmul_pallas", "quant_matmul_ref"]
