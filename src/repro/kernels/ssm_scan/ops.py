"""Backend-dispatching jit wrapper for the selective scan."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssm_scan.ref import ssm_scan_ref
from repro.kernels.ssm_scan.ssm_scan import ssm_scan_pallas


@functools.partial(jax.jit, static_argnames=("backend", "chunk", "block_di"))
def ssm_scan(x, dt, Bm, Cm, A, *, backend: str = "auto", chunk: int = 128,
             block_di: int = 512):
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "pallas":
        return ssm_scan_pallas(x, dt, Bm, Cm, A, chunk=chunk,
                               block_di=block_di, interpret=False)
    if backend == "interpret":
        return ssm_scan_pallas(x, dt, Bm, Cm, A, chunk=chunk,
                               block_di=block_di, interpret=True)
    return ssm_scan_ref(x, dt, Bm, Cm, A)


__all__ = ["ssm_scan", "ssm_scan_pallas", "ssm_scan_ref"]
