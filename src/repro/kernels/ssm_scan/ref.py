"""Pure-jnp oracle for the mamba-1 selective scan (sequential, fp32)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def ssm_scan_ref(x: jax.Array, dt: jax.Array, Bm: jax.Array, Cm: jax.Array,
                 A: jax.Array, h0: jax.Array | None = None):
    """x, dt: (B, S, DI); Bm, Cm: (B, S, N); A: (DI, N).

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) * B_t
    y_t = <h_t, C_t>

    Returns (y: (B, S, DI) fp32, h_final: (B, DI, N) fp32)."""
    B, S, DI = x.shape
    N = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    h = jnp.zeros((B, DI, N), jnp.float32) if h0 is None else h0

    def step(h, inp):
        dt_t, x_t, b_t, c_t = inp
        h = h * jnp.exp(dt_t[..., None] * Af) \
            + (dt_t * x_t)[..., None] * b_t[:, None, :]
        return h, jnp.einsum("bdn,bn->bd", h, c_t)

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (dtf, xf, Bf, Cf))
    h, ys = lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1), h
