"""Mamba-1 selective-scan Pallas TPU kernel, chunked over sequence.

TPU adaptation (DESIGN.md §6): the scan is sequential in time but fully
parallel over (batch, d_inner) — so:

* grid = (B, DI / block_di, S / chunk) with the chunk axis innermost
  (sequential); the (block_di, N) hidden state lives in VMEM scratch and is
  carried across chunk steps without ever visiting HBM.
* each grid step streams a (chunk, block_di) tile of x/dt and a (chunk, N)
  tile of B/C into VMEM and runs the recurrence with a fori_loop in
  registers/VMEM; y is written back tile-by-tile.
* the elementwise recurrence runs on the VPU; N=16 keeps the per-step state
  update (block_di x 16) VREG-friendly.

This removes the per-timestep HBM round-trip of the lax.scan reference —
the roofline memory term for mamba prefill is dominated by exactly that
traffic (see EXPERIMENTS.md §Roofline for falcon-mamba-7b x prefill_32k).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(dt_ref, x_ref, b_ref, c_ref, A_ref, y_ref, hout_ref, h_ref,
                *, chunk: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    dt = dt_ref[0].astype(jnp.float32)      # (chunk, dib)
    x = x_ref[0].astype(jnp.float32)        # (chunk, dib)
    Bm = b_ref[0].astype(jnp.float32)       # (chunk, N)
    Cm = c_ref[0].astype(jnp.float32)       # (chunk, N)
    A = A_ref[...].astype(jnp.float32)      # (dib, N)

    def step(t, h):
        dA = jnp.exp(dt[t][:, None] * A)                    # (dib, N)
        h = h * dA + (dt[t] * x[t])[:, None] * Bm[t][None, :]
        y_ref[0, t] = (h * Cm[t][None, :]).sum(-1).astype(y_ref.dtype)
        return h

    h = lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ci == nc - 1)
    def _finalize():
        hout_ref[0] = h_ref[...]


def ssm_scan_pallas(x: jax.Array, dt: jax.Array, Bm: jax.Array,
                    Cm: jax.Array, A: jax.Array, *,
                    chunk: int = 128, block_di: int = 512,
                    interpret: bool = True):
    """x, dt: (B, S, DI); Bm, Cm: (B, S, N); A: (DI, N).
    Returns (y (B, S, DI) fp32, h_final (B, DI, N) fp32)."""
    B, S, DI = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    block_di = min(block_di, DI)
    assert S % chunk == 0, "S must be a multiple of chunk"
    assert DI % block_di == 0, "DI must be a multiple of block_di"
    nc = S // chunk
    ndi = DI // block_di

    kernel = functools.partial(_ssm_kernel, chunk=chunk, nc=nc)
    y, h = pl.pallas_call(
        kernel,
        grid=(B, ndi, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_di), lambda b, d, c: (b, c, d)),  # dt
            pl.BlockSpec((1, chunk, block_di), lambda b, d, c: (b, c, d)),  # x
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),         # B
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),         # C
            pl.BlockSpec((block_di, N), lambda b, d, c: (d, 0)),            # A
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_di), lambda b, d, c: (b, c, d)),  # y
            pl.BlockSpec((1, block_di, N), lambda b, d, c: (b, d, 0)),      # h
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, DI), jnp.float32),
            jax.ShapeDtypeStruct((B, DI, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_di, N), jnp.float32)],
        interpret=interpret,
    )(dt, x, Bm, Cm, A)
    return y, h
